"""Fleet-simulator queueing sanity (ISSUE 6): the discrete-event replay in
``serve.fleet`` against M/D/1-style ground truths — empty-queue latency is
exactly the isolated placement estimate, latency grows with arrival rate,
replicas and autoscaling relieve queueing."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve.fleet import (
    AutoscalePolicy,
    FleetSimulator,
    WorkloadClass,
    poisson_arrivals,
    simulate_queue,
)

HWS = ["tpu-v5e", "tpu-v6e"]


@pytest.fixture(scope="module")
def sim():
    cfg = get_arch("qwen3-0.6b").smoke()
    return FleetSimulator(
        [WorkloadClass("chat", cfg, B=1, lin=32, lout=8)],
        hws=HWS, backend="oracle", replicas=2,
    )


# ----------------------------------------------------------------------
# simulate_queue unit truths
# ----------------------------------------------------------------------


def test_single_server_fifo_hand_computed():
    starts, traj, capacity = simulate_queue(
        np.array([0.0, 1.0, 2.0]), np.array([2.0, 2.0, 2.0]), replicas=1
    )
    assert list(starts) == [0.0, 2.0, 4.0]
    assert traj == [(0.0, 1)]
    assert capacity == 6.0  # 1 replica x horizon (last completion at 6)


def test_extra_replicas_absorb_overlap():
    starts, _, _ = simulate_queue(
        np.array([0.0, 1.0, 2.0]), np.array([2.0, 2.0, 2.0]), replicas=2
    )
    assert list(starts) == [0.0, 1.0, 2.0]  # never waits


def test_poisson_arrivals_scale_with_rate():
    a1 = poisson_arrivals(10.0, 1000, seed=7)
    a2 = poisson_arrivals(20.0, 1000, seed=7)
    # common random numbers: doubling the rate halves every arrival time
    np.testing.assert_allclose(a2, a1 / 2.0, rtol=1e-12)
    assert np.all(np.diff(a1) > 0)


# ----------------------------------------------------------------------
# fleet replay sanity
# ----------------------------------------------------------------------


def test_empty_fleet_latency_is_isolated_estimate(sim):
    """A request entering an idle fleet waits zero, so its simulated
    latency is the placement row's total_s bit-for-bit — the acceptance
    anchor (<= 1e-9, actually exact)."""
    report = sim.replay(arrivals=np.array([0.0]))
    svc = sim.service_s("chat")
    assert abs(report.latency_p50_s - svc) <= 1e-9
    assert report.per_hw[sim.assignment["chat"]].wait_mean_s == 0.0


def test_latency_monotone_in_arrival_rate(sim):
    sat = sim.saturation_rate_rps()
    p95 = [
        sim.replay(rate_rps=f * sat, n_requests=20_000, seed=3).latency_p95_s
        for f in (0.3, 0.6, 0.9)
    ]
    assert p95[0] <= p95[1] <= p95[2]
    assert p95[2] > p95[0]  # queueing genuinely bites near saturation


def test_more_replicas_cut_waiting():
    cfg = get_arch("qwen3-0.6b").smoke()
    wc = WorkloadClass("chat", cfg, B=1, lin=32, lout=8)
    small = FleetSimulator([wc], hws=HWS, backend="oracle", replicas=1)
    big = FleetSimulator([wc], hws=HWS, backend="oracle", replicas=4)
    rate = 0.8 * small.saturation_rate_rps()
    hw = small.assignment["chat"]
    wait_small = small.replay(rate_rps=rate, n_requests=10_000, seed=5).per_hw[hw].wait_mean_s
    wait_big = big.replay(rate_rps=rate, n_requests=10_000, seed=5).per_hw[hw].wait_mean_s
    assert wait_big < wait_small


def test_replay_is_deterministic_and_conserves_requests(sim):
    r1 = sim.replay(rate_rps=100.0, n_requests=5_000, seed=11)
    r2 = sim.replay(rate_rps=100.0, n_requests=5_000, seed=11)
    assert r1.latency_p95_s == r2.latency_p95_s
    assert r1.n_requests == 5_000
    assert sum(l.n_requests for l in r1.per_hw.values()) == 5_000
    hw = sim.assignment["chat"]
    assert 0.0 < r1.per_hw[hw].utilization <= 1.0
    assert np.all(r1.latencies >= sim.service_s("chat") - 1e-12)


def test_recorded_arrivals_any_order(sim):
    arr = poisson_arrivals(200.0, 2_000, seed=2)
    shuffled = arr.copy()
    np.random.default_rng(0).shuffle(shuffled)
    a = sim.replay(arrivals=arr, class_ids=np.zeros(len(arr), int))
    b = sim.replay(arrivals=shuffled, class_ids=np.zeros(len(arr), int))
    assert a.latency_p95_s == b.latency_p95_s


def test_assignment_follows_router(sim):
    cls = sim.classes[0]
    placement = sim.router.route(
        cls.calls(), objective="latency", n_tokens=cls.n_tokens, scale=cls.bubble()
    )
    assert sim.assignment["chat"] == placement.best
    assert sim.service_s("chat") == placement[placement.best].total_s


def test_autoscale_grows_pool_under_load(sim):
    sat = sim.saturation_rate_rps()
    svc = sim.service_s("chat")
    policy = AutoscalePolicy(
        window_s=20 * svc, target_utilization=0.5, min_replicas=2, max_replicas=16
    )
    fixed = sim.replay(rate_rps=0.9 * sat, n_requests=20_000, seed=3)
    scaled = sim.replay(rate_rps=0.9 * sat, n_requests=20_000, seed=3, autoscale=policy)
    hw = sim.assignment["chat"]
    assert scaled.per_hw[hw].final_replicas > scaled.per_hw[hw].replicas
    assert scaled.latency_p95_s <= fixed.latency_p95_s
    # trajectory is recorded for inspection
    assert len(scaled.per_hw[hw].replica_traj) > 1


def test_multi_class_mix_routes_and_replays():
    cfg = get_arch("qwen3-0.6b").smoke()
    chat = WorkloadClass("chat", cfg, B=1, lin=32, lout=8, weight=3.0)
    bulk = WorkloadClass("bulk", cfg, B=1, lin=96, lout=24, weight=1.0)
    sim = FleetSimulator([chat, bulk], hws=HWS, backend="oracle", replicas=2)
    assert set(sim.assignment) == {"chat", "bulk"}
    assert sim.service_s("bulk") > sim.service_s("chat")
    report = sim.replay(rate_rps=0.5 * sim.saturation_rate_rps(),
                        n_requests=8_000, seed=1)
    # the 3:1 mix shows up in the replayed stream
    names = [n for load in report.per_hw.values() for n in load.classes]
    assert "chat" in names and "bulk" in names
    assert report.table()


def test_simulate_fleet_convenience():
    from repro.core.e2e import simulate_fleet

    cfg = get_arch("qwen3-0.6b").smoke()
    report = simulate_fleet(
        cfg, 1, 32, 8, rate_rps=50.0, n_requests=2_000,
        hws=HWS, backend="oracle", replicas=2, seed=0,
    )
    assert report.n_requests == 2_000
    assert report.latency_p99_s >= report.latency_p95_s >= report.latency_p50_s > 0
