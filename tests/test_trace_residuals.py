"""Trace-residual round trip (ISSUE 9): the wall-clock the engines stamp
onto recorded steps (``StepMeta.measured_s``) plus the recorded call
groups feed the residual monitor, and re-lowering a step's recorded
shapes (``step_predicted_s``) reproduces the live prediction exactly —
for both engines, including mesh-inherited parallel degrees."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.hardware import get_hw
from repro.predict import get_predictor
from repro.serve.engine import ContinuousBatchingEngine, Request, ServeEngine
from repro.serve.monitor import (
    ResidualMonitor,
    step_predicted_s,
    trace_residuals,
)
from repro.serve.trace import TraceRecorder

HW = get_hw("tpu-v5e")


@pytest.fixture(scope="module")
def predictor():
    return get_predictor("oracle", HW)


@pytest.fixture(scope="module")
def cfg():
    return get_arch("qwen3-0.6b").smoke()


@pytest.fixture(scope="module")
def served(cfg):
    """One recorded ServeEngine run: (recorder, results)."""
    rec = TraceRecorder()
    eng = ServeEngine(cfg, max_batch=2, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(1, 13, dtype=np.int32), max_new=3))
    eng.submit(Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32), max_new=3))
    return rec, eng.step_batch()


# ----------------------------------------------------------------------
# engines stamp wall-clock onto every recorded step
# ----------------------------------------------------------------------


def test_serve_engine_stamps_every_step(served):
    rec, results = served
    # 1 prefill + (max_new - 1) decode steps, all measured
    assert rec.n_steps == 3
    assert rec.phases() == ["prefill", "decode", "decode"]
    assert all(m.measured_s > 0 for m in rec.meta)
    # the prefill stamp *is* the Result's prefill_s — same float
    assert rec.meta[0].measured_s == results[0].prefill_s


def test_continuous_engine_stamps_every_step(cfg):
    rec = TraceRecorder()
    eng = ContinuousBatchingEngine(cfg, slots=2, max_len=48, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(1, 11, dtype=np.int32), max_new=3))
    results = eng.run_to_completion()
    assert all(m.measured_s > 0 for m in rec.meta)
    # the admit step's stamp == the slot's (hence the Result's) prefill_s
    admit = next(m for m in rec.meta if m.phase == "prefill")
    assert admit.measured_s == results[0].prefill_s
    assert results[0].latency_s > 0


def test_mark_measured_guards():
    rec = TraceRecorder()
    with pytest.raises(RuntimeError):
        rec.mark_measured(0.1)
    rec.record_step("s", get_arch("qwen3-0.6b").smoke(), 1, 4, 4)
    with pytest.raises(ValueError):
        rec.mark_measured(-1.0)


# ----------------------------------------------------------------------
# StepMeta re-lowering round trip
# ----------------------------------------------------------------------


def test_relowered_meta_predicts_exactly_like_recorded_calls(served, cfg, predictor):
    # step_calls is the single lowering record_step and step_predicted_s
    # share, so the round trip is float-exact, step by step
    rec, _ = served
    for (_, _, calls), meta in zip(rec.steps, rec.meta):
        live = predictor.predict(calls).total_s
        relowered = step_predicted_s(meta, cfg, predictor)
        assert live > 0
        assert relowered == live


def test_round_trip_at_declared_degrees(predictor):
    # tp/pp ride along in StepMeta: a trace recorded at declared degrees
    # re-lowers with its collectives and PP boundary traffic included
    cfg = get_arch("dbrx-132b").smoke()
    rec = TraceRecorder(tp=2, pp=2)
    rec.record_step("prefill", cfg, 2, 16, 16, phase="prefill")
    rec.record_step("decode", cfg, 2, 1, 17, phase="decode")
    for (_, _, calls), meta in zip(rec.steps, rec.meta):
        assert meta.tp == 2 and meta.pp == 2
        assert step_predicted_s(meta, cfg, predictor) == \
            predictor.predict(calls).total_s


def test_continuous_engine_mesh_inherited_degrees(cfg, predictor):
    # a mesh-native engine binds the recorder to its mesh axes; the
    # recorded meta carries those degrees and still round-trips
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rec = TraceRecorder()
    eng = ContinuousBatchingEngine(cfg, slots=2, max_len=48,
                                   recorder=rec, mesh=mesh)
    eng.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32), max_new=2))
    eng.run_to_completion()
    assert rec.resolved_tp == eng.tp == 1  # inherited, not declared
    assert all(m.tp == eng.tp and m.pp == eng.pp for m in rec.meta)
    assert all(m.measured_s > 0 for m in rec.meta)
    for (_, _, calls), meta in zip(rec.steps, rec.meta):
        assert step_predicted_s(meta, cfg, predictor) == \
            predictor.predict(calls).total_s


# ----------------------------------------------------------------------
# residual extraction feeds the monitor
# ----------------------------------------------------------------------


def test_trace_residuals_reproduce_live_measurements(served, predictor):
    rec, _ = served
    res = trace_residuals(rec, predictor)
    assert len(res) == rec.n_steps  # every step was measured
    assert [r.label for r in res] == rec.labels()
    assert [r.measured_s for r in res] == [m.measured_s for m in rec.meta]
    for r in res:
        assert r.hw == HW.name  # defaulted from the predictor's hardware
        assert r.predicted_s > 0 and np.isfinite(r.ratio) and r.ratio > 0
    # timestamps are the cumulative measured clock, strictly increasing
    ts = [r.t for r in res]
    assert all(b > a for a, b in zip(ts, ts[1:]))
    assert ts[-1] == pytest.approx(sum(m.measured_s for m in rec.meta))


def test_unmeasured_steps_are_skipped(cfg, predictor):
    rec = TraceRecorder()
    rec.record_step("measured", cfg, 1, 8, 8, phase="prefill")
    rec.mark_measured(0.25)
    rec.record("pre-lowered", [], phase="other")  # never stamped
    rec.record_step("also-unmeasured", cfg, 1, 1, 9, phase="decode")
    res = trace_residuals(rec, predictor)
    assert [r.label for r in res] == ["measured"]
    assert res[0].measured_s == 0.25


def test_monitor_observe_trace(served, predictor):
    rec, _ = served
    mon = ResidualMonitor()
    mon.observe_trace(rec, predictor)
    assert mon.n_observed == rec.n_steps
    assert mon.keys() == [("trace", HW.name)]
    assert mon.ewma("trace", HW.name) > 0


def test_monitor_observe_results(served):
    rec, results = served
    # predicted at 10x the measured request latency: ratio 0.1, deviation
    # 0.9 — an immediate-trip monitor fires on the first result
    mon = ResidualMonitor(window=4, threshold=0.5, sustain=1, min_samples=1)
    events = mon.observe_results(
        results, predicted_s=results[0].latency_s * 10.0,
        cls="chat", hw=HW.name,
    )
    assert len(events) == len(results)
    assert mon.events == events
    # timestamps accumulate the per-result latencies
    assert events[0].t == pytest.approx(results[0].latency_s)
