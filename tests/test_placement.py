"""Prediction-guided fleet placement (ISSUE 4): FleetRouter picks the
analytically-optimal hardware on synthetic registries, skips unpriceable
entries with a warning, predicted admission honors its decode SLO on a
recorded trace, and falls back cleanly when the predictor is unfitted."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.dataset import build_dataset
from repro.core.e2e import model_calls, place_request
from repro.core.estimator import train_pipeweave
from repro.core.hardware import REGISTRY, _mk, get_hw
from repro.predict import (
    CommRegressor,
    FeatureCache,
    KernelCall,
    SweepPredictor,
    UnpricedHardwareError,
    get_objective,
    get_predictor,
    trace_cost_usd,
)
from repro.serve.placement import FleetRouter


# synthetic two-device registry with an analytically-known ranking: the
# workload below is HBM-bound under the roofline and launch overhead is
# zeroed, so latency scales as 1/hbm_gbps exactly — "fast" halves the
# latency but costs 4x per chip-hour, so "slow" wins on cost while
# "fast" wins on latency.
FAST = _mk("syn-fast", "syn", 8, 1.0, 200, 1600, 128, True, usd=4.0, launch=0.0)
SLOW = _mk("syn-slow", "syn", 8, 1.0, 200, 800, 128, True, usd=1.0, launch=0.0)

# rmsnorm streams bytes: hbm-dominant on every spec above
HBM_TRACE = [KernelCall("rmsnorm", {"seq": 4096, "dim": 4096}, count=8)]


@pytest.fixture(scope="module")
def pw_gemm_only():
    """A PipeWeave trained on gemm only — triggers UntrainedFamilyError
    under the default fallback="error" for any other family."""
    return train_pipeweave(
        {"gemm": build_dataset("gemm", n_workloads=8, seed=0)}, max_epochs=2
    )


# ----------------------------------------------------------------------
# routing: objectives + analytically-known rankings
# ----------------------------------------------------------------------


def test_router_picks_analytically_optimal_hw():
    router = FleetRouter([FAST, SLOW], backend="roofline")
    by_lat = router.route(HBM_TRACE, objective="latency")
    assert by_lat.best == "syn-fast"
    # bandwidth halves the roofline latency exactly
    assert np.isclose(
        by_lat["syn-slow"].total_s, 2 * by_lat["syn-fast"].total_s, rtol=1e-9
    )
    by_cost = router.route(HBM_TRACE, objective="cost")
    assert by_cost.best == "syn-slow"
    # fast: half the time at 4x the rate -> exactly 2x the cost
    assert np.isclose(
        by_cost["syn-fast"].score, 2 * by_cost["syn-slow"].score, rtol=1e-9
    )
    # ranking + table surface both entries
    assert by_cost.ranking() == ["syn-slow", "syn-fast"]
    assert "syn-fast" in by_cost and "nope" not in by_cost
    assert len(by_cost.table().splitlines()) == 3


def test_slo_cheapest_objective():
    router = FleetRouter([FAST, SLOW], backend="roofline")
    lat = {r.hw: r.total_s for r in router.route(HBM_TRACE).rows}
    # SLO between the two latencies: only the fast device is feasible, so
    # it wins despite being the pricier one
    slo = (lat["syn-fast"] + lat["syn-slow"]) / 2
    tight = router.route(HBM_TRACE, objective=get_objective("slo_cheapest", slo_s=slo))
    assert tight.best == "syn-fast"
    assert tight["syn-fast"].feasible and not tight["syn-slow"].feasible
    assert "NO" in tight.table()
    # loose SLO: both feasible -> cheapest wins
    loose = router.route(
        HBM_TRACE, objective=get_objective("slo_cheapest", slo_s=10 * lat["syn-slow"])
    )
    assert loose.best == "syn-slow"
    assert all(r.feasible for r in loose.rows)


def test_cost_per_token_needs_n_tokens():
    router = FleetRouter([FAST, SLOW], backend="roofline", objective="cost_per_token")
    # a missing n_tokens is a workload-metadata error, not a per-hardware
    # gap: it must propagate with its actionable message, not be laundered
    # into one skip warning per fleet entry
    with pytest.raises(ValueError, match="needs n_tokens"):
        router.route(HBM_TRACE)  # no n_tokens
    pl = router.route(HBM_TRACE, n_tokens=64)
    assert pl.best == "syn-slow"
    assert np.isclose(
        pl.rows[0].score, trace_cost_usd(SLOW, pl["syn-slow"].estimate) / 64
    )


def test_unpriced_hw_is_skipped_under_cost_with_warning():
    unpriced = dataclasses.replace(FAST, name="syn-unpriced", usd_per_chip_hour=None)
    router = FleetRouter([SLOW, unpriced], backend="roofline")
    with pytest.warns(UserWarning, match="skipping syn-unpriced"):
        pl = router.route(HBM_TRACE, objective="cost")
    assert pl.best == "syn-slow"
    assert "syn-unpriced" in pl.skipped
    assert "skipped" in pl.table() and "syn-unpriced" in pl.table()
    # latency objective doesn't need the price: nothing skipped
    assert router.route(HBM_TRACE, objective="latency").skipped == {}
    with pytest.raises(UnpricedHardwareError):
        trace_cost_usd(unpriced, pl["syn-slow"].estimate)


def test_commless_registry_entry_skipped_mid_sweep(pw_gemm_only):
    """The small fix: a backend whose CommRegressor was never fitted must
    be skipped with a warning — not abort the whole fleet pass — and the
    skip must be surfaced in the result."""
    trace = [(f"s", 1.0, [KernelCall("gemm", {"M": 256, "N": 256, "K": 256})]),
             ("comm", 1.0, model_calls(get_arch("qwen3-0.6b"), 2, 1, 64, tp=2))]
    predictors = {
        "tpu-v5e": get_predictor("oracle", get_hw("tpu-v5e")),
        # unfitted CommRegressor: raises RuntimeError on the first CommCall
        "tpu-v6e": get_predictor("roofline", get_hw("tpu-v6e"), comm=CommRegressor()),
    }
    router = FleetRouter(sweep=SweepPredictor(predictors=predictors))
    with pytest.warns(UserWarning, match="skipping tpu-v6e"):
        pl = router.route(trace)
    assert pl.best == "tpu-v5e"
    assert list(pl.skipped) == ["tpu-v6e"]
    assert "no fitted coefficients" in pl.skipped["tpu-v6e"]
    assert "tpu-v6e" in pl.table()


def test_router_every_hw_skipped_raises(pw_gemm_only):
    # gemm-only estimator, fallback="error": attention has no model on
    # any device -> every entry skipped -> actionable error, not an empty
    # placement
    router = FleetRouter(
        ["tpu-v5e", "tpu-v6e"], estimator=pw_gemm_only, cache=FeatureCache()
    )
    trace = [("d", 1.0, model_calls(get_arch("qwen3-0.6b"), 2, 1, 64, tp=1))]
    with pytest.raises(RuntimeError, match="every hardware was skipped"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            router.route(trace)


def test_router_rejects_ambiguous_construction():
    sp = SweepPredictor(["tpu-v5e"], backend="roofline")
    with pytest.raises(TypeError, match="not both"):
        FleetRouter(["tpu-v5e"], sweep=sp)
    with pytest.raises(KeyError, match="unknown objective"):
        FleetRouter(["tpu-v5e"], backend="roofline", objective="speed")


# ----------------------------------------------------------------------
# split-fleet assignment
# ----------------------------------------------------------------------


def test_split_fleet_prefers_different_devices():
    """Prefill-heavy (compute-bound) and decode-heavy (bandwidth-bound)
    classes must route to different synthetic devices when one has the
    MXU edge and the other the HBM edge."""
    mxu_rich = _mk("syn-mxu", "syn", 8, 1.0, 400, 800, 128, True, usd=2.0, launch=0.0)
    hbm_rich = _mk("syn-hbm", "syn", 8, 1.0, 100, 3200, 128, True, usd=2.0, launch=0.0)
    router = FleetRouter([mxu_rich, hbm_rich], backend="roofline")
    split = router.route_split(
        {
            # big square gemm: mxu-dominant on both specs
            "prefill": [KernelCall("gemm", {"M": 4096, "N": 4096, "K": 4096})],
            # byte-streaming: hbm-dominant on both specs
            "decode": [KernelCall("rmsnorm", {"seq": 4096, "dim": 4096})],
        }
    )
    assert split.assignment == {"prefill": "syn-mxu", "decode": "syn-hbm"}
    assert split.is_split
    assert split["prefill"].best == "syn-mxu"
    assert "-- prefill" in split.table() and "-- decode" in split.table()


def test_route_split_from_recorder_and_route_trace():
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder()
    eng = ServeEngine(cfg, max_batch=2, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(1, 13, dtype=np.int32), max_new=3))
    eng.step_batch()
    assert rec.phases() == ["prefill", "decode", "decode"]
    assert rec.decode_tokens == 2  # two decode ticks, one active row each
    assert rec.prefill_tokens == 1  # the prefill-sampled first token
    assert rec.generated_tokens == 3  # == the request's max_new

    router = FleetRouter(["tpu-v5e", "tpu-v6e"], backend="oracle")
    split = router.route_split(rec)
    assert set(split.parts) == {"prefill", "decode"}
    # per-class token counts: per-token objectives work on either side
    split_cpt = router.route_split(rec, objective="cost_per_token")
    assert split_cpt["prefill"].n_tokens == 1
    assert split_cpt["decode"].n_tokens == 2
    # route_trace wires the generated-token count through
    pl = router.route_trace(rec, objective="cost_per_token")
    assert pl.n_tokens == 3
    with pytest.raises(TypeError, match="TraceRecorder or a"):
        router.route_split([("s", 1.0, [])])
    with pytest.raises(ValueError, match="empty trace"):
        router.route_split({})


def test_decode_tokens_with_heterogeneous_max_new():
    """A short-max_new request riding in a padded batch must stop counting
    toward `active` once it stops accepting tokens: generated_tokens ==
    the true output-token count, not ticks x batch."""
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder()
    eng = ServeEngine(cfg, max_batch=2, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32), max_new=2))
    eng.submit(Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32), max_new=6))
    results = eng.step_batch()
    true_tokens = sum(len(r.tokens) for r in results)  # 2 + 6 = 8
    assert true_tokens == 8
    assert rec.generated_tokens == true_tokens
    # the launched batch stays padded at B=2 even after rid=0 finishes
    decode_meta = [m for m in rec.meta if m.phase == "decode"]
    assert all(m.B == 2 for m in decode_meta)
    assert [m.active for m in decode_meta] == [2, 1, 1, 1, 1]


# ----------------------------------------------------------------------
# place_request
# ----------------------------------------------------------------------


def test_place_request_over_registry():
    pl = place_request(get_arch("qwen3-0.6b"), 4, 64, 8, backend="roofline",
                       objective="cost")
    assert set(pl.ranking()) == set(REGISTRY)
    assert pl.n_tokens == 4 * 8
    # scores are genuine costs and the ranking is sorted
    scores = [r.score for r in pl.rows]
    assert scores == sorted(scores) and scores[0] > 0
    with pytest.raises(TypeError, match="not both"):
        place_request(get_arch("qwen3-0.6b"), 4, 64, 8, backend="roofline",
                      router=FleetRouter(backend="roofline"))


def test_place_request_pp_applies_bubble():
    cfg = get_arch("qwen3-0.6b")
    router = FleetRouter(["tpu-v5e"], backend="oracle")
    flat = place_request(cfg, 2, 64, 8, router=router)
    pp = place_request(cfg, 2, 64, 8, pp=2, router=router)
    # pp=2 adds boundary comms and the (1 + 0.5*(pp-1)/pp) bubble scale
    assert pp["tpu-v5e"].total_s > flat["tpu-v5e"].total_s * 1.25


# ----------------------------------------------------------------------
# predicted admission
# ----------------------------------------------------------------------


def _reqs(cfg, n, max_new=3, L=10):
    from repro.serve.engine import Request

    return [
        Request(rid=i, prompt=np.arange(1, L + 1, dtype=np.int32), max_new=max_new)
        for i in range(n)
    ]


def test_predicted_admission_never_exceeds_slo():
    """Every admission decision and every *executed* decode tick of the
    recorded trace prices under the SLO (worst-case full-pool tick plus
    quantization headroom)."""
    from repro.serve.engine import ContinuousBatchingEngine
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    pred = get_predictor("oracle", get_hw("tpu-v5e"), cache=FeatureCache())
    slots, max_len = 2, 48
    slo = pred.predict(model_calls(cfg, slots, 1, max_len, tp=1)).total_s * 1.05

    rec = TraceRecorder()
    eng = ContinuousBatchingEngine(
        cfg, slots=slots, max_len=max_len, recorder=rec,
        admission="predicted", predictor=pred, decode_slo_s=slo,
    )
    for r in _reqs(cfg, 4):
        eng.submit(r)
    out = eng.run_to_completion()
    assert sorted(r.rid for r in out) == [0, 1, 2, 3]
    assert eng.slo_forced_admits == 0
    assert len(eng.admission_log) >= 4
    for d in eng.admission_log:
        assert d["admitted"] and not d["forced"]
        assert d["predicted_s"] <= slo
    # the recorded decode ticks — what actually ran — also meet the SLO
    ticks = [s for s, m in zip(rec.steps, rec.meta) if m.phase == "decode"]
    assert ticks
    assert max(pred.predict([t]).total_s for t in ticks) <= slo


def test_predicted_admission_defers_but_makes_progress():
    """An SLO no single request can meet forces progress-guarantee
    admissions (warned + counted) instead of deadlocking the queue."""
    from repro.serve.engine import ContinuousBatchingEngine

    cfg = get_arch("qwen3-0.6b").smoke()
    pred = get_predictor("oracle", get_hw("tpu-v5e"), cache=FeatureCache())
    eng = ContinuousBatchingEngine(
        cfg, slots=2, max_len=48,
        admission="predicted", predictor=pred, decode_slo_s=1e-9,
    )
    for r in _reqs(cfg, 3):
        eng.submit(r)
    with pytest.warns(UserWarning, match="admitting anyway"):
        out = eng.run_to_completion()
    assert sorted(r.rid for r in out) == [0, 1, 2]
    assert eng.slo_forced_admits == 3  # each admitted alone, one at a time
    deferred = [d for d in eng.admission_log if not d["admitted"]]
    assert deferred  # companions were actually held back


def test_predicted_admission_falls_back_when_unfitted(pw_gemm_only):
    """An estimator with no model for the step's families (fallback=
    "error") must demote the engine to fixed admission with a warning —
    serving continues, nothing raises."""
    from repro.serve.engine import ContinuousBatchingEngine

    cfg = get_arch("qwen3-0.6b").smoke()
    sp = get_predictor("synperf", get_hw("tpu-v5e"), estimator=pw_gemm_only)
    eng = ContinuousBatchingEngine(
        cfg, slots=2, max_len=48,
        admission="predicted", predictor=sp, decode_slo_s=1.0,
    )
    for r in _reqs(cfg, 3):
        eng.submit(r)
    with pytest.warns(UserWarning, match="falling back to fixed"):
        out = eng.run_to_completion()
    assert sorted(r.rid for r in out) == [0, 1, 2]
    assert eng.admission == "fixed"
    assert "UntrainedFamilyError" in eng.admission_fallback_reason
    assert eng.admission_log == []  # no decision was ever scored


def test_predicted_admission_requires_predictor_and_slo():
    from repro.serve.engine import ContinuousBatchingEngine

    cfg = get_arch("qwen3-0.6b").smoke()
    with pytest.raises(ValueError, match="admission="):
        ContinuousBatchingEngine(cfg, admission="predicted")
    with pytest.raises(ValueError, match="'fixed' or 'predicted'"):
        ContinuousBatchingEngine(cfg, admission="adaptive")
