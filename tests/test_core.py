"""PipeWeave core unit + property tests: decomposer invariants, scheduler
partition laws, feature monotonicity, oracle sanity, estimator round-trip."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hwsim
from repro.core.dataset import featurize, sample_workload
from repro.core.decomposer import (
    SCHED_POLICY,
    decompose,
    gemm_tile_heuristic,
    routing_counts,
)
from repro.core.hardware import REGISTRY, get_hw, seen_hw, unseen_hw
from repro.core.scheduler import schedule_static, schedule_workqueue

HW = get_hw("tpu-v5e")


# ----------------------------------------------------------------------
# decomposer invariants (property-based)
# ----------------------------------------------------------------------


@settings(deadline=None, max_examples=40)
@given(
    M=st.integers(1, 8192),
    N=st.sampled_from([128, 384, 1024, 4096]),
    K=st.sampled_from([128, 256, 2048]),
)
def test_gemm_decomposition_conserves_work(M, N, K):
    """Sum of per-task MXU ops == 2*M*N*K regardless of tiling."""
    tasks = decompose("gemm", {"M": M, "N": N, "K": K}, HW)
    assert np.isclose(tasks.mxu.sum(), 2.0 * M * N * K, rtol=1e-9)
    assert (tasks.align > 0).all() and (tasks.align <= 1).all()


@settings(deadline=None, max_examples=30)
@given(
    qlen=st.integers(1, 4096),
    extra=st.integers(0, 4096),
    bs=st.integers(1, 4),
    nkv=st.integers(1, 4),
    group=st.integers(1, 4),
)
def test_attention_causal_work_is_half_of_full(qlen, extra, bs, nkv, group):
    """Causal total ops equal the exact masked sum (paper Eq. 3, alpha=4)."""
    kvlen = qlen + extra
    X = dict(bs=bs, nkv=nkv, group=group, hd=64, qlen=qlen, kvlen=kvlen)
    full = decompose("attention", {**X, "causal": 0}, HW)
    causal = decompose("attention", {**X, "causal": 1}, HW)
    assert causal.mxu.sum() <= full.mxu.sum() + 1e-6
    # exact: sum over rows of (offset + i + 1) kv positions
    offset = kvlen - qlen
    exact = sum(min(kvlen, offset + i + 1) for i in range(qlen))
    exact_ops = 4.0 * group * exact * 64 * bs * nkv
    # block-level counting rounds kv_eff up to the block edge
    assert causal.mxu.sum() >= exact_ops - 1e-6
    blocked = causal.mxu.sum()
    assert blocked <= exact_ops * 2.0 + 1e-6


@settings(deadline=None, max_examples=25)
@given(
    M=st.integers(8, 4096),
    E=st.sampled_from([8, 16, 64]),
    topk=st.integers(1, 8),
    skew=st.floats(0.0, 0.7),
    seed=st.integers(0, 10_000),
)
def test_moe_routing_counts_conserve_tokens(M, E, topk, skew, seed):
    counts = routing_counts(M, E, topk, skew, seed)
    assert counts.sum() == M * topk
    assert (counts >= 0).all()


# ----------------------------------------------------------------------
# scheduler laws
# ----------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(M=st.integers(1, 2048), N=st.sampled_from([384, 4096]))
def test_static_schedule_is_partition(M, N):
    tasks = decompose("gemm", {"M": M, "N": N, "K": 512}, HW)
    chip_of = schedule_static(tasks, HW)
    assert len(chip_of) == len(tasks)
    counts = np.bincount(chip_of, minlength=HW.num_chips)
    assert counts.max() - counts.min() <= 1  # round-robin balance


def test_workqueue_beats_static_on_skewed_moe():
    """The work-queue scheduler should balance ragged expert loads better
    than a static split (the FA3/fused-MoE scheduling story)."""
    X = {"M": 2048, "E": 16, "topk": 4, "H": 1024, "N": 1024, "skew": 0.65, "seed": 3}
    tasks = decompose("fused_moe", X, HW)
    from repro.core.scheduler import task_weights

    w = task_weights(tasks, HW)
    for sched in (schedule_static, schedule_workqueue):
        chip_of = sched(tasks, HW)
        loads = np.bincount(chip_of, weights=w, minlength=HW.num_chips)
        if sched is schedule_static:
            static_max = loads.max()
        else:
            wq_max = loads.max()
    assert wq_max <= static_max + 1e-9


# ----------------------------------------------------------------------
# features + oracle
# ----------------------------------------------------------------------


def test_feature_vector_shape_and_finite():
    from repro.core.features import FEATURE_DIM

    for kind in ("gemm", "attention", "rmsnorm", "silu_mul", "scaled_mm", "fused_moe"):
        rng = np.random.default_rng(0)
        w = sample_workload(kind, rng)
        fs = featurize(kind, w, HW)
        v = fs.vector(HW)
        assert v.shape == (FEATURE_DIM,), (kind, v.shape)
        assert np.all(np.isfinite(v))


def test_oracle_never_beats_theoretical():
    """hwsim latency >= dominant-pipe theoretical time (roofline is a true
    lower bound modulo the 3% noise)."""
    rng = np.random.default_rng(1)
    for kind in ("gemm", "attention", "fused_moe", "rmsnorm"):
        for _ in range(10):
            w = sample_workload(kind, rng)
            for hw in (get_hw("tpu-v5e"), get_hw("tpu-v4"), get_hw("tpu-v7p")):
                fs = featurize(kind, w, hw)
                actual = hwsim.simulate(kind, w, hw)
                assert actual >= fs.theoretical_s * 0.9, (kind, w, hw.name)


def test_oracle_monotone_in_gemm_size():
    base = {"M": 1024, "N": 1024, "K": 1024}
    bigger = {"M": 4096, "N": 1024, "K": 1024}
    assert hwsim.simulate("gemm", bigger, HW) > hwsim.simulate("gemm", base, HW)


def test_comm_oracle_scales_with_bytes():
    t1 = hwsim.simulate_comm("all_reduce", 1e6, 8, HW)
    t2 = hwsim.simulate_comm("all_reduce", 1e8, 8, HW)
    assert t2 > t1 > 0


def test_hw_registry_split():
    assert len(REGISTRY) == 11
    assert len(seen_hw()) == 6 and len(unseen_hw()) == 5


# ----------------------------------------------------------------------
# estimator quick round-trip (small budget)
# ----------------------------------------------------------------------


def test_estimator_learns_gemm_quickly():
    from repro.core.dataset import SEEN, build_dataset, mape
    from repro.core.estimator import train_pipeweave

    ds = build_dataset("gemm", n_workloads=110, seed=5)
    pw = train_pipeweave({"gemm": ds}, max_epochs=250)
    pred = pw.predict_dataset(ds)
    seen = np.array([h in SEEN for h in ds.hw_names])
    m = mape(pred[seen], ds.actual_s[seen])
    roofline = mape(ds.theoretical_s[seen], ds.actual_s[seen])
    assert m < roofline, (m, roofline)
    assert m < 20.0, m


def test_quantile_ceiling_above_median_eff():
    from repro.core.dataset import build_dataset
    from repro.core.quantile import perf_gap, train_ceiling

    ds = build_dataset("fused_moe", n_workloads=50, seed=6)
    ceiling = train_ceiling(ds, max_epochs=200)
    report = perf_gap(ceiling, ds)
    # ceiling should sit above actual efficiency for most points
    frac_above = float((report.gaps > -0.05).mean())
    assert frac_above > 0.6, frac_above


def test_tuner_improves_underperformers():
    from repro.core.tuner import tune_one

    X = {"M": 512, "E": 64, "topk": 2, "H": 2048, "N": 1024, "skew": 0.5, "seed": 9}
    r = tune_one(X, get_hw("tpu-v4"))
    assert r.speedup >= 1.0
