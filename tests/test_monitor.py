"""Residual-monitor math regressions (ISSUE 9): EWMA window edge cases
(window longer than the stream, single-sample classes, all-identical
residuals), the threshold exactly at the boundary, streak/sustain
behavior, drift-injection specs, and a golden re-route log on a fixed
seed through ``FleetSimulator.replay``."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve.fleet import FleetSimulator, WorkloadClass
from repro.serve.monitor import (
    DriftSpec,
    ResidualMonitor,
    drift_factor,
    resolve_drift,
)

HWS = ["tpu-v5e", "tpu-v6e"]


# ----------------------------------------------------------------------
# EWMA edge cases
# ----------------------------------------------------------------------


def test_all_identical_residuals_ewma_is_exact():
    # seeded with the first sample, an all-identical stream's EWMA is that
    # value *exactly* — no asymptotic convergence, bit-for-bit
    mon = ResidualMonitor(window=64)
    for _ in range(10):
        mon.observe("c", "h", measured_s=2.5, predicted_s=1.0)
    assert mon.ewma("c", "h") == 2.5
    assert mon.deviation("c", "h") == 1.5


def test_window_longer_than_stream():
    mon = ResidualMonitor(window=1000)
    for _ in range(7):
        mon.observe("c", "h", measured_s=3.0, predicted_s=1.0)
    assert mon.n_samples("c", "h") == 7
    assert len(mon.window_samples("c", "h")) == 7  # deque never filled
    assert mon.ewma("c", "h") == 3.0


def test_single_sample_class_never_trips():
    # min_samples (defaults to sustain) keeps a one-observation class from
    # tripping on its first residual, however large
    mon = ResidualMonitor()
    ev = mon.observe("once", "h", measured_s=100.0, predicted_s=1.0)
    assert ev is None
    assert mon.events == []
    assert mon.n_samples("once", "h") == 1


def test_unseen_key_accessors():
    mon = ResidualMonitor()
    assert mon.ewma("x", "y") is None
    assert mon.deviation("x", "y") is None
    assert mon.n_samples("x", "y") == 0
    assert mon.window_samples("x", "y") == []
    assert mon.keys() == []
    assert mon.corrections() == {}


def test_window_deque_keeps_last_n():
    mon = ResidualMonitor(window=3, threshold=10.0)  # threshold: never trip
    for r in (1.0, 2.0, 3.0, 4.0, 5.0):
        mon.observe("c", "h", measured_s=r, predicted_s=1.0)
    assert mon.window_samples("c", "h") == [3.0, 4.0, 5.0]
    assert mon.n_samples("c", "h") == 5


# ----------------------------------------------------------------------
# threshold / sustain behavior
# ----------------------------------------------------------------------


def test_threshold_exactly_at_boundary_trips():
    # the comparison is >=: a residual pinned exactly at 1 + threshold
    # counts as over-threshold (0.25 is exact in binary floats)
    mon = ResidualMonitor(window=8, threshold=0.25, sustain=3, min_samples=1)
    events = [
        mon.observe("c", "h", measured_s=1.25, predicted_s=1.0)
        for _ in range(3)
    ]
    assert events[0] is None and events[1] is None
    assert events[2] is not None
    assert events[2].deviation == 0.25
    assert mon.events == [events[2]]


def test_just_below_threshold_never_trips():
    mon = ResidualMonitor(window=8, threshold=0.25, sustain=3, min_samples=1)
    for _ in range(50):
        assert mon.observe("c", "h", 1.2499, 1.0) is None
    assert mon.events == []


def test_one_under_threshold_observation_resets_streak():
    # window=1 makes the EWMA the last raw ratio exactly (alpha = 1), so
    # the streak is driven by the raw sequence: every third observation
    # dips under threshold and the trip never completes
    mon = ResidualMonitor(window=1, threshold=0.5, sustain=3, min_samples=1)
    for _ in range(6):
        assert mon.observe("c", "h", 2.0, 1.0) is None
        assert mon.observe("c", "h", 2.0, 1.0) is None
        assert mon.observe("c", "h", 1.0, 1.0) is None
    # three consecutive over-threshold observations then trip
    assert mon.observe("c", "h", 2.0, 1.0) is None
    assert mon.observe("c", "h", 2.0, 1.0) is None
    assert mon.observe("c", "h", 2.0, 1.0) is not None


def test_transient_spike_never_trips_defaults():
    # one 5x outlier in a calm stream moves the EWMA by alpha*(5-1) ~ 0.12
    # < threshold 0.25 — the sustained-residual design goal
    mon = ResidualMonitor()
    for _ in range(100):
        mon.observe("c", "h", 1.0, 1.0)
    mon.observe("c", "h", 5.0, 1.0)
    for _ in range(100):
        mon.observe("c", "h", 1.0, 1.0)
    assert mon.events == []


def test_speedup_drift_trips_too():
    # |ewma - 1| is two-sided: a 2x *speedup* (ratio 0.5) is drift as well
    mon = ResidualMonitor()  # sustain=8, min_samples=8 -> trips at n=15
    events = [mon.observe("c", "h", 0.5, 1.0) for _ in range(15)]
    assert events[-1] is not None
    assert events[-1].deviation == 0.5
    assert events[-1].n_samples == 15
    assert all(e is None for e in events[:-1])


def test_trip_repeats_without_reset():
    # uncorrected sustained drift re-trips every `sustain` observations
    mon = ResidualMonitor(window=4, threshold=0.5, sustain=3, min_samples=1)
    events = [mon.observe("c", "h", 2.0, 1.0) for _ in range(9)]
    assert [e is not None for e in events] == [False, False, True] * 3
    assert len(mon.events) == 3


def test_corrections_window_count_weighted_mean():
    mon = ResidualMonitor(window=64, threshold=10.0)
    for _ in range(3):
        mon.observe("a", "hw0", 2.0, 1.0)
    mon.observe("b", "hw0", 1.0, 1.0)
    for _ in range(2):
        mon.observe("c", "hw1", 3.0, 1.0)
    corr = mon.corrections()
    assert corr["hw0"] == pytest.approx((2.0 * 3 + 1.0 * 1) / 4)
    assert corr["hw1"] == 3.0
    assert set(corr) == {"hw0", "hw1"}


def test_reset_drops_state_keeps_events():
    mon = ResidualMonitor(window=1, threshold=0.5, sustain=1, min_samples=1)
    assert mon.observe("c", "h", 2.0, 1.0) is not None
    assert mon.n_observed == 1
    mon.reset()
    assert mon.keys() == []
    assert mon.n_observed == 0
    assert len(mon.events) == 1  # trip history survives reset
    mon.reset(clear_events=True)
    assert mon.events == []


def test_observe_rejects_nonpositive_and_nonfinite():
    mon = ResidualMonitor()
    for bad in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError):
            mon.observe("c", "h", bad, 1.0)
        with pytest.raises(ValueError):
            mon.observe("c", "h", 1.0, bad)
    assert mon.n_observed == 0


def test_monitor_parameter_validation():
    with pytest.raises(ValueError):
        ResidualMonitor(window=0)
    with pytest.raises(ValueError):
        ResidualMonitor(threshold=0.0)
    with pytest.raises(ValueError):
        ResidualMonitor(threshold=float("nan"))
    with pytest.raises(ValueError):
        ResidualMonitor(sustain=0)


# ----------------------------------------------------------------------
# drift injection
# ----------------------------------------------------------------------


def test_drift_step_factor_at():
    d = DriftSpec(hw="h", factor=3.0, t_start=10.0)
    assert d.factor_at(9.999) == 1.0
    assert d.factor_at(10.0) == 3.0
    assert d.factor_at(1e9) == 3.0


def test_drift_ramp_factor_at():
    d = DriftSpec(hw="h", factor=3.0, t_start=10.0, mode="ramp", t_end=20.0)
    assert d.factor_at(5.0) == 1.0
    assert d.factor_at(10.0) == 1.0  # ramp starts *from* 1.0
    assert d.factor_at(15.0) == pytest.approx(2.0)
    assert d.factor_at(20.0) == 3.0
    assert d.factor_at(25.0) == 3.0  # holds after t_end


def test_drift_spec_validation():
    with pytest.raises(ValueError):
        DriftSpec(hw="h", factor=0.0)
    with pytest.raises(ValueError):
        DriftSpec(hw="h", factor=float("inf"))
    with pytest.raises(ValueError):
        DriftSpec(hw="h", factor=2.0, mode="pulse")
    with pytest.raises(ValueError):
        DriftSpec(hw="h", factor=2.0, mode="ramp")  # no t_end
    with pytest.raises(ValueError):
        DriftSpec(hw="h", factor=2.0, mode="ramp", t_start=5.0, t_end=5.0)


def test_resolve_drift_shorthands():
    assert resolve_drift(None) == {}
    spec = DriftSpec(hw="h", factor=2.0)
    assert resolve_drift(spec) == {"h": [spec]}
    out = resolve_drift({"a": 2.0, "b": 0.5})
    assert set(out) == {"a", "b"}
    assert out["a"][0].factor == 2.0 and out["a"][0].mode == "step"
    assert out["b"][0].factor == 0.5
    with pytest.raises(TypeError):
        resolve_drift(["not a spec"])


def test_drift_factor_composes_multiplicatively():
    specs = resolve_drift(
        [DriftSpec(hw="h", factor=2.0), DriftSpec(hw="h", factor=3.0, t_start=10.0)]
    )
    assert drift_factor(specs, "h", 0.0) == 2.0
    assert drift_factor(specs, "h", 10.0) == 6.0
    assert drift_factor(specs, "other", 10.0) == 1.0


# ----------------------------------------------------------------------
# golden re-route log through the fleet replay (fixed seed)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim():
    cfg = get_arch("qwen3-0.6b").smoke()
    chat = WorkloadClass("chat", cfg, B=1, lin=256, lout=32, weight=3.0)
    bulk = WorkloadClass("bulk", cfg, B=1, lin=1024, lout=64, weight=1.0)
    return FleetSimulator([chat, bulk], hws=HWS, backend="oracle", replicas=2)


def test_golden_reroute_log(sim):
    # oracle backend, fixed seed, 3x step drift on the assigned hardware:
    # the whole control loop is deterministic, so the log is pinnable
    assert sim.assignment == {"chat": "tpu-v6e", "bulk": "tpu-v6e"}
    rate = 0.5 * sim.saturation_rate_rps()
    mon = ResidualMonitor()
    report = sim.replay(
        rate_rps=rate, n_requests=4000, seed=7,
        drift=DriftSpec(hw="tpu-v6e", factor=3.0), monitor=mon,
    )
    assert len(report.reroutes) == 1
    ev = report.reroutes[0]
    # chat (weight 3) reaches min_samples + sustain = 15 observations
    # first, on the 19th request of the stream
    assert ev.index == 18
    assert ev.cls == "chat"
    assert ev.hw == "tpu-v6e"
    # all residual ratios are identically 3.0, so the EWMA is *exactly* 3
    assert ev.deviation == 2.0
    assert set(ev.corrections) == {"tpu-v6e"}
    assert ev.corrections["tpu-v6e"] == pytest.approx(3.0, rel=1e-12)
    assert ev.old_assignment == {"chat": "tpu-v6e", "bulk": "tpu-v6e"}
    assert ev.new_assignment == {"chat": "tpu-v5e", "bulk": "tpu-v5e"}
    assert ev.changed
    # the report carries the assignment in effect at the end of the replay
    assert report.assignment == ev.new_assignment
    assert mon.events[0].deviation == 2.0


def test_golden_reroute_log_is_reproducible(sim):
    kw = dict(rate_rps=0.5 * sim.saturation_rate_rps(), n_requests=4000,
              seed=7, drift=DriftSpec(hw="tpu-v6e", factor=3.0))
    r1 = sim.replay(monitor=ResidualMonitor(), **kw)
    r2 = sim.replay(monitor=ResidualMonitor(), **kw)
    assert r1.reroutes == r2.reroutes  # frozen dataclass equality
    assert np.array_equal(r1.latencies, r2.latencies)


def test_monitored_undrifted_replay_is_bit_identical(sim):
    rate = 0.5 * sim.saturation_rate_rps()
    frozen = sim.replay(rate_rps=rate, n_requests=1500, seed=7)
    ctl = sim.replay(rate_rps=rate, n_requests=1500, seed=7,
                     monitor=ResidualMonitor())
    assert ctl.reroutes == []
    assert ctl.assignment == frozen.assignment
    assert np.array_equal(frozen.latencies, ctl.latencies)


def test_drift_rejects_unknown_hardware(sim):
    with pytest.raises(ValueError, match="no placement prices"):
        sim.replay(rate_rps=1.0, n_requests=10, seed=0,
                   drift={"tpu-v99": 2.0})


def test_drift_replay_composes_with_autoscale(sim):
    """Drift + autoscale in one controlled replay (ISSUE 10): the pool
    under 2x drift resizes for the *measured* load, every request is
    served exactly once, and utilization stays <= 1 against the
    trajectory-integrated capacity."""
    from repro.serve.fleet import AutoscalePolicy

    rate = 0.8 * sim.saturation_rate_rps()
    # ~10 resize windows across the stream's expected span
    pol = AutoscalePolicy(window_s=2000 / rate / 10, target_utilization=0.6,
                          min_replicas=1, max_replicas=16)
    rep = sim.replay(rate_rps=rate, n_requests=2000, seed=11,
                     drift={"tpu-v6e": 2.0}, autoscale=pol)
    assert sum(l.n_requests for l in rep.per_hw.values()) == 2000
    load = rep.per_hw["tpu-v6e"]
    assert 0.0 < load.utilization <= 1.0 + 1e-9
    # drifted load at 0.8x nominal saturation exceeds the 2-replica pool's
    # capacity, so the policy must have grown it
    assert load.final_replicas > load.replicas
    assert len(load.replica_traj) > 1
    assert load.replica_traj[-1][1] == load.final_replicas


def test_autoscaled_quiet_monitor_matches_vectorized_autoscale(sim):
    """With a quiet monitor and no drift, the controlled autoscale path
    reproduces the vectorized ``simulate_queue`` resize arithmetic
    bit-for-bit — trajectory, capacity integral and latencies."""
    from repro.serve.fleet import AutoscalePolicy

    rate = 0.8 * sim.saturation_rate_rps()
    pol = AutoscalePolicy(window_s=1500 / rate / 10, target_utilization=0.6,
                          min_replicas=1, max_replicas=16)
    kw = dict(rate_rps=rate, n_requests=1500, seed=5, autoscale=pol)
    vec = sim.replay(**kw)
    ctl = sim.replay(monitor=ResidualMonitor(), **kw)
    assert ctl.reroutes == []
    assert np.array_equal(vec.latencies, ctl.latencies)
    for hw, load in vec.per_hw.items():
        assert ctl.per_hw[hw].replica_traj == load.replica_traj
        assert ctl.per_hw[hw].final_replicas == load.final_replicas
        assert ctl.per_hw[hw].utilization == pytest.approx(
            load.utilization, rel=1e-12)
