"""Distribution tests. Multi-device cases run in subprocesses so the host
test process keeps a single CPU device (device count locks at first jax
init; the dry-run spec forbids a global XLA_FLAGS override)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from jax.sharding import PartitionSpec as P


def _run_sub(script: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ----------------------------------------------------------------------
# sharding rule unit tests (no devices needed beyond 1)
# ----------------------------------------------------------------------


def test_resolve_pspec_divisibility_fallback():
    from jax.sharding import AbstractMesh

    from repro.dist.sharding import resolve_pspec

    # rule logic only reads mesh.shape — test on the production geometry
    mesh = AbstractMesh((16, 16), ("data", "model"))
    # batch=1 cannot shard -> None; vocab-sized dim shards on model
    assert resolve_pspec((1, 128), ("batch", "tp"), mesh) == P(None, "model")
    # odd head count (hymba's 25) cannot shard on a 16-way model axis
    assert resolve_pspec((25, 64), ("tp", None), mesh) == P(None, None)
    # fsdp falls back to replication when the dim doesn't divide
    assert resolve_pspec((24, 48), ("fsdp", "tp"), mesh) == P(None, "model")
    # multi-pod batch uses (pod, data) jointly when divisible
    mesh3 = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
    assert resolve_pspec((64, 10), ("batch", None), mesh3) == P(("pod", "data"), None)
    # batch divisible by pod but not pod*data -> greedy keeps pod only
    assert resolve_pspec((8, 10), ("batch", None), mesh3) == P(("pod",), None) or \
        resolve_pspec((8, 10), ("batch", None), mesh3) == P("pod", None)


def test_param_rules_cover_all_archs():
    """Every parameter leaf of every arch resolves to a valid PartitionSpec
    on the production mesh geometry (checked symbolically on a 1x1 mesh with
    divisibility against 16/16 sizes via a fake mesh shape) — and every leaf
    *name* is in the audited rule set, so a new model family cannot silently
    ride the generic matrix fallback (ISSUE 3 sharding-rule audit)."""
    from repro.configs import get_arch, list_archs
    from repro.dist.sharding import AUDITED_PARAM_LEAVES, _path_names, param_pspecs
    from repro.models.registry import build_model

    def leaf_names(shapes):
        names = set()

        def one(path, leaf):
            # same path parsing param_pspecs itself uses, so the audit sees
            # exactly the names the rules resolve
            parts = _path_names(path)
            names.add(parts[-1] if parts else "")
            return leaf

        jax.tree_util.tree_map_with_path(one, shapes)
        return names

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in list_archs():
        cfg = get_arch(name).smoke()
        api = build_model(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        specs = param_pspecs(shapes, mesh)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert leaves, name
        unaudited = leaf_names(shapes) - AUDITED_PARAM_LEAVES
        assert not unaudited, (
            f"{name}: param leaves {sorted(unaudited)} have no audited "
            "sharding rule — add them to dist.sharding._PARAM_RULES"
        )


# ----------------------------------------------------------------------
# multi-device integration (subprocess)
# ----------------------------------------------------------------------


def test_sharded_train_step_matches_single_device():
    """One fsdp+tp train step on a 2x2 mesh reproduces the single-device
    loss (numerical equivalence of the distribution strategy)."""
    _run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.models.registry import build_model, materialize_batch
        from repro.dist.sharding import param_pspecs, batch_pspecs, to_named, use_mesh
        cfg = get_arch("qwen3-0.6b").smoke()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = materialize_batch(cfg, 4, 32)
        loss_single, _ = jax.jit(api.loss)(params, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh):
            p_sh = to_named(param_pspecs(params, mesh), mesh)
            b_sh = to_named(batch_pspecs(batch, mesh), mesh)
            params_s = jax.device_put(params, p_sh)
            batch_s = jax.device_put(batch, b_sh)
            loss_dist, _ = jax.jit(api.loss, in_shardings=(p_sh, b_sh))(params_s, batch_s)
        np.testing.assert_allclose(float(loss_single), float(loss_dist), rtol=2e-3)
        print("OK", float(loss_single), float(loss_dist))
        """,
        devices=4,
    )


def test_moe_expert_parallel_matches_single_device():
    _run_sub(
        """
        import jax, numpy as np
        from repro.configs import get_arch
        from repro.models.registry import build_model, materialize_batch
        from repro.dist.sharding import param_pspecs, batch_pspecs, to_named, use_mesh
        import dataclasses
        cfg = dataclasses.replace(get_arch("dbrx-132b").smoke(), capacity_factor=8.0)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = materialize_batch(cfg, 4, 32)
        loss_single, _ = jax.jit(api.loss)(params, batch)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh):
            p_sh = to_named(param_pspecs(params, mesh), mesh)
            b_sh = to_named(batch_pspecs(batch, mesh), mesh)
            loss_dist, _ = jax.jit(api.loss, in_shardings=(p_sh, b_sh))(
                jax.device_put(params, p_sh), jax.device_put(batch, b_sh))
        np.testing.assert_allclose(float(loss_single), float(loss_dist), rtol=2e-3)
        print("OK")
        """,
        devices=4,
    )


def test_pipeline_parallel_matches_sequential():
    """GPipe shard_map pipeline == sequential layer stack."""
    _run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from repro.dist.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("pipe",))
        n_layers, micro, mb, d = 8, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), n_layers)
        params = {"w": jax.vmap(lambda k: 0.3*jax.random.normal(k, (d, d)))(ks)}
        x = jax.random.normal(jax.random.PRNGKey(1), (micro, mb, d))
        layer_fn = lambda lp, h: jnp.tanh(h @ lp["w"])
        out_pp = pipeline_forward(layer_fn, params, x, mesh)
        def seq(x):
            def body(c, lp):
                return layer_fn(lp, c), None
            y, _ = lax.scan(body, x, params)
            return y
        out_ref = jax.vmap(seq)(x)
        np.testing.assert_allclose(np.asarray(out_pp), np.asarray(out_ref), rtol=2e-5, atol=2e-5)
        print("OK bubble", (4-1)/(4+4-1))
        """,
        devices=4,
    )


def test_pipeline_1f1b_matches_sequential_at_exact_tick_count():
    """Interleaved 1F1B == sequential layer stack, and the analytical
    ``schedule_ticks`` is *minimal*: the executed shard_map schedule run
    one tick short must fail to complete the last microbatch. Covers a
    non-divisible microbatch count (M=6 on S=4) and the divisible case."""
    _run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from repro.dist.pipeline import pipeline_forward, schedule_ticks
        mesh = jax.make_mesh((4,), ("pipe",))
        layer_fn = lambda lp, h: jnp.tanh(h @ lp["w"])
        def seq(params, x):
            def body(c, lp):
                return layer_fn(lp, c), None
            return jax.vmap(lambda xx: lax.scan(body, xx, params)[0])(x)
        for n_layers, micro, V in ((16, 8, 2), (16, 6, 2), (8, 1, 2)):
            ks = jax.random.split(jax.random.PRNGKey(0), n_layers)
            params = {"w": jax.vmap(lambda k: 0.3*jax.random.normal(k, (16, 16)))(ks)}
            x = jax.random.normal(jax.random.PRNGKey(1), (micro, 2, 16))
            out = pipeline_forward(layer_fn, params, x, mesh,
                                   schedule="1f1b", interleave=V)
            ref = seq(params, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            t = schedule_ticks(4, micro, "1f1b", V)
            short = pipeline_forward(layer_fn, params, x, mesh,
                                     schedule="1f1b", interleave=V, ticks=t - 1)
            assert not np.allclose(np.asarray(short), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5), (micro, V)
        # same minimality statement for GPipe's M + S - 1
        params = {"w": jax.vmap(lambda k: 0.3*jax.random.normal(k, (16, 16)))(
            jax.random.split(jax.random.PRNGKey(0), 8))}
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 16))
        ref = seq(params, x)
        out = pipeline_forward(layer_fn, params, x, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
        short = pipeline_forward(layer_fn, params, x, mesh,
                                 ticks=schedule_ticks(4, 4, "gpipe") - 1)
        assert not np.allclose(np.asarray(short), np.asarray(ref), rtol=2e-5, atol=2e-5)
        print("OK 1f1b ticks exact")
        """,
        devices=4,
    )


def test_pipeline_zb_h1_matches_sequential_at_exact_tick_count():
    """Executed ZB-H1 == sequential layer stack at exactly
    ``schedule_ticks`` ring ticks, and one tick short fails — the
    three-phase (F/B/W) slot lifecycle really occupies the ring for the
    ticks the closed form counts. Covers divisible and straggler
    microbatch counts, the degenerate M=1 fill/drain, and V=1."""
    _run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from repro.dist.pipeline import pipeline_forward, schedule_ticks
        mesh = jax.make_mesh((4,), ("pipe",))
        layer_fn = lambda lp, h: jnp.tanh(h @ lp["w"])
        def seq(params, x):
            def body(c, lp):
                return layer_fn(lp, c), None
            return jax.vmap(lambda xx: lax.scan(body, xx, params)[0])(x)
        for n_layers, micro, V in ((16, 8, 2), (16, 6, 2), (8, 1, 2), (8, 4, 1)):
            ks = jax.random.split(jax.random.PRNGKey(0), n_layers)
            params = {"w": jax.vmap(lambda k: 0.3*jax.random.normal(k, (16, 16)))(ks)}
            x = jax.random.normal(jax.random.PRNGKey(1), (micro, 2, 16))
            out = pipeline_forward(layer_fn, params, x, mesh,
                                   schedule="zb-h1", interleave=V)
            ref = seq(params, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            t = schedule_ticks(4, micro, "zb-h1", V)
            short = pipeline_forward(layer_fn, params, x, mesh,
                                     schedule="zb-h1", interleave=V, ticks=t - 1)
            assert not np.allclose(np.asarray(short), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5), (micro, V)
        print("OK zb-h1 ticks exact")
        """,
        devices=4,
    )


def test_bucketed_ef_allreduce_transport_matches_sync():
    """Bucketed EF with a per-bucket psum transport inside shard_map ==
    synchronous compress-then-tree-psum, bit for bit, on 8 forced host
    devices — the overlapped launch schedule changes nothing numerically
    even with the collective on the wire."""
    _run_sub(
        """
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.collectives import ef_compress_grads, ef_compress_grads_bucketed
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        grads = {
            "w1": jnp.asarray(rng.standard_normal((8, 64, 16)), jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((8, 33)), jnp.float32),
            "w3": jnp.asarray(rng.standard_normal((8, 5, 3)), jnp.float32),
        }
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        psum = lambda ls: [jax.lax.psum(x, "data") for x in ls]

        @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")))
        def bucketed(g, e):
            deq, new_err, _ = ef_compress_grads_bucketed(
                g, e, bucket_bytes=600, all_reduce=psum)
            return deq, new_err

        @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")))
        def sync(g, e):
            deq, new_err = ef_compress_grads(g, e)
            deq = jax.tree.map(lambda x: jax.lax.psum(x, "data"), deq)
            return deq, new_err

        db, eb = jax.jit(bucketed)(grads, err)
        ds, es = jax.jit(sync)(grads, err)
        for a, b in zip(jax.tree.leaves((db, eb)), jax.tree.leaves((ds, es))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the reduced grads really aggregated across devices: every
        # device's slice of the psum'd output is the same
        blocks = np.asarray(db["w2"])
        for i in range(1, 8):
            np.testing.assert_array_equal(blocks[i], blocks[0])
        print("OK bucketed transport")
        """,
        devices=8,
    )


def test_elastic_restart_across_device_counts():
    """Checkpoint written under a 4-device mesh restores into a 2-device
    mesh (elastic scaling)."""
    _run_sub(
        """
        import jax, numpy as np, tempfile, os
        from repro.configs import get_arch
        from repro.data.pipeline import DataConfig
        from repro.train.step import TrainConfig
        from repro.train.trainer import Trainer, TrainerConfig
        d = tempfile.mkdtemp()
        cfg = get_arch("qwen3-0.6b").smoke()
        def mk(total):
            return Trainer(cfg, DataConfig(batch=4, seq_len=32),
                           TrainConfig(total_steps=total, warmup=1),
                           TrainerConfig(total_steps=total, ckpt_every=2, ckpt_dir=d, log_every=100))
        t = mk(2); t.run(seed=0)
        # "restart" with a different sharded mesh
        from repro.dist.sharding import param_pspecs, to_named, use_mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        from repro.train.step import init_train_state, make_optimizer
        from repro.optim.adamw import AdamWState
        from jax.sharding import PartitionSpec as P
        with use_mesh(mesh):
            api = t.api
            opt = t.optimizer
            state = init_train_state(api, opt, jax.random.PRNGKey(0))
            sh = {
              "params": to_named(param_pspecs(state["params"], mesh), mesh),
              "opt": AdamWState(step=to_named(P(), mesh),
                                mu=to_named(param_pspecs(state["opt"].mu, mesh), mesh),
                                nu=to_named(param_pspecs(state["opt"].nu, mesh), mesh)),
              "step": to_named(P(), mesh),
              "err": None,
            }
            restored = t.ckpt.restore_latest(state, sh)
            assert restored is not None
            step, new_state, _ = restored
            assert step == 2
            # leaves actually live on the new mesh
            leaf = jax.tree.leaves(new_state["params"])[0]
            assert len(leaf.sharding.device_set) >= 1
        print("OK elastic")
        """,
        devices=4,
    )
