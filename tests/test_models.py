"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape checks, no NaNs, and prefill->decode consistency with the
training-mode forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.transformer as T
from repro.configs import get_arch, list_archs
from repro.models.registry import build_model, materialize_batch

ARCHS = list_archs()


def smoke_cfg(name):
    cfg = get_arch(name).smoke()
    if cfg.n_experts:
        # capacity-based MoE drops tokens depending on grouping; give the
        # smoke tests unbounded capacity so train/prefill/decode agree exactly
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def seq_for(cfg):
    return 24 if cfg.meta_tokens else 32


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_shapes_and_finite(name):
    cfg = smoke_cfg(name)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = materialize_batch(cfg, 2, seq_for(cfg))
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))
    hidden, _, _ = T.forward(params, cfg, batch, "train")
    logits = T.full_logits(params, cfg, hidden)
    assert logits.shape == (2, seq_for(cfg), cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ARCHS)
def test_grads_finite(name):
    cfg = smoke_cfg(name)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = materialize_batch(cfg, 2, seq_for(cfg))
    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    # at least the embedding grads must be non-zero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_matches_train_forward(name):
    cfg = smoke_cfg(name)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    batch = materialize_batch(cfg, 2, seq_for(cfg))
    hidden, _, _ = T.forward(params, cfg, batch, "train")
    logits_train = T.full_logits(params, cfg, hidden)
    logits_pre, _ = api.prefill(params, batch)
    # prefill uses the triangular flash schedule (train does not): online
    # softmax reaccumulation differs at bf16 resolution (~0.008/attention,
    # ~0.04 at the logits after 2 layers) — numerically equivalent, not equal
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_train[:, -1, :]), rtol=8e-2, atol=8e-2
    )


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_prefill(name):
    """prefill(S-1 tokens) + decode(token S-1) == prefill(S tokens)[:, -1]."""
    cfg = smoke_cfg(name)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(2))
    S = seq_for(cfg)
    batch = materialize_batch(cfg, 2, S)
    logits_last, _ = api.prefill(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : S - 1]
    _, caches = api.prefill(params, pre)
    caches = T.pad_cache(caches, cfg, S)
    positions = jnp.full((2,), S - 1, jnp.int32)
    logits_dec, _ = api.decode(params, caches, batch["tokens"][:, S - 1], positions)
    # bf16 flash-reaccumulation tolerance (see test_prefill_matches_train)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_last), rtol=8e-2, atol=8e-2
    )


@pytest.mark.parametrize("name", ARCHS)
def test_multi_token_decode_chain(name):
    """Greedy-decode 4 tokens sequentially; all logits finite, cache updates
    don't corrupt earlier state (re-decode of same position is deterministic)."""
    cfg = smoke_cfg(name)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    S = seq_for(cfg)
    batch = materialize_batch(cfg, 2, S)
    _, caches = api.prefill(params, batch)
    caches = T.pad_cache(caches, cfg, S + 4)
    tok = batch["tokens"][:, -1]
    decode = jax.jit(api.decode)
    for i in range(4):
        pos = jnp.full((2,), S + i, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        assert np.all(np.isfinite(np.asarray(logits)))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)


def test_param_counts_match_analytical():
    """n_params() analytical count tracks the real init within 2% (smoke)."""
    for name in ARCHS:
        cfg = smoke_cfg(name)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.n_params()
        assert abs(real - approx) / real < 0.15, (name, real, approx)
