"""Property suite for the drift control loop (ISSUE 9, hypothesis; falls
back to tests/_hypothesis_stub.py when the real package is absent):

  * an undrifted monitored replay is bit-identical to the frozen
    vectorized path and trips zero re-routes (false-positive bound);
  * an injected step drift well over threshold trips exactly one
    sustained re-route — after correction the residual returns to 1;
  * on a drifted single-class stream, the re-routed replay's p95 never
    exceeds the frozen assignment's;
  * conservation (every admitted request completes, once) and
    utilization <= 1 hold across random class mixes, seeds, loads, and
    drift factors on the event-by-event controlled path.
"""
from functools import lru_cache

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.serve.fleet import FleetSimulator, WorkloadClass
from repro.serve.monitor import DriftSpec, ResidualMonitor

HWS = ["tpu-v5e", "tpu-v6e"]

#: (name, lin, lout, weight) per class — hashable so sims memoize per mix
MIXES = (
    (("chat", 256, 32, 3.0), ("bulk", 1024, 64, 1.0)),
    (("solo", 512, 48, 1.0),),
    (("a", 128, 16, 1.0), ("b", 384, 32, 2.0), ("c", 768, 8, 1.0)),
)
SINGLE = MIXES[1]
N = 400  # requests per replayed stream (event-by-event path: keep small)


@lru_cache(maxsize=None)
def _cfg():
    return get_arch("qwen3-0.6b").smoke()


@lru_cache(maxsize=None)
def _sim(mix):
    # module-level cache instead of pytest fixtures: @given hides the test
    # signature (both real hypothesis and the stub), so fixtures can't mix
    classes = [
        WorkloadClass(name, _cfg(), B=1, lin=lin, lout=lout, weight=w)
        for name, lin, lout, w in mix
    ]
    return FleetSimulator(classes, hws=HWS, backend="oracle", replicas=2)


@settings(deadline=None, max_examples=8)
@given(
    mix=st.sampled_from(MIXES),
    seed=st.integers(0, 3),
    frac=st.floats(min_value=0.3, max_value=0.7),
)
def test_no_drift_means_zero_reroutes_and_exact_replay(mix, seed, frac):
    sim = _sim(mix)
    rate = frac * sim.saturation_rate_rps()
    frozen = sim.replay(rate_rps=rate, n_requests=N, seed=seed)
    ctl = sim.replay(rate_rps=rate, n_requests=N, seed=seed,
                     monitor=ResidualMonitor())
    assert ctl.reroutes == []
    assert ctl.assignment == sim.assignment
    assert np.array_equal(frozen.latencies, ctl.latencies)
    assert set(ctl.per_hw) == set(frozen.per_hw)
    for hw, load in ctl.per_hw.items():
        assert load.n_requests == frozen.per_hw[hw].n_requests


@settings(deadline=None, max_examples=8)
@given(
    mix=st.sampled_from(MIXES),
    seed=st.integers(0, 3),
    factor=st.floats(min_value=1.6, max_value=4.0),
)
def test_step_drift_trips_exactly_one_reroute(mix, seed, factor):
    # deviation factor-1 >= 0.6 is far over the 0.25 threshold, so the
    # monitor must trip; corrected predictions then bring the residual
    # back to ~1, so it must trip exactly once
    sim = _sim(mix)
    drift_hw = sim.assignment[mix[0][0]]
    report = sim.replay(
        rate_rps=0.5 * sim.saturation_rate_rps(), n_requests=N, seed=seed,
        drift=DriftSpec(hw=drift_hw, factor=factor),
        monitor=ResidualMonitor(),
    )
    assert len(report.reroutes) == 1
    ev = report.reroutes[0]
    assert ev.hw == drift_hw
    assert ev.deviation >= 0.25
    assert ev.corrections[drift_hw] > 1.0
    assert report.assignment == ev.new_assignment


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 5), factor=st.floats(min_value=2.0, max_value=4.0))
def test_rerouted_p95_never_exceeds_frozen_on_drifted_stream(seed, factor):
    sim = _sim(SINGLE)
    rate = 0.5 * sim.saturation_rate_rps()
    drift = DriftSpec(hw=sim.assignment["solo"], factor=factor)
    frozen = sim.replay(rate_rps=rate, n_requests=N, seed=seed, drift=drift)
    routed = sim.replay(rate_rps=rate, n_requests=N, seed=seed, drift=drift,
                        monitor=ResidualMonitor())
    assert len(routed.reroutes) == 1
    # either the corrected route moved the class off the drifted pool
    # (strictly faster service from an empty pool) or it stayed put (the
    # replays coincide) — in both cases p95 cannot regress
    assert routed.latency_p95_s <= frozen.latency_p95_s * (1 + 1e-12)


@settings(deadline=None, max_examples=10)
@given(
    mix=st.sampled_from(MIXES),
    seed=st.integers(0, 3),
    factor=st.floats(min_value=1.0, max_value=3.0),
)
def test_conservation_and_utilization(mix, seed, factor):
    sim = _sim(mix)
    report = sim.replay(
        rate_rps=0.5 * sim.saturation_rate_rps(), n_requests=N, seed=seed,
        drift={sim.assignment[mix[0][0]]: factor},
        monitor=ResidualMonitor(),
    )
    # every admitted request completes exactly once, on exactly one pool
    assert report.n_requests == N
    assert len(report.latencies) == N
    assert sum(l.n_requests for l in report.per_hw.values()) == N
    assert np.all(report.latencies > 0)
    assert np.isfinite(report.latencies).all()
    for load in report.per_hw.values():
        assert 0.0 <= load.utilization <= 1.0 + 1e-9
        assert load.busy_s >= 0.0
    assert report.horizon_s >= float(report.latencies[0])
    classes = {c for l in report.per_hw.values() for c in l.classes}
    assert classes == {m[0] for m in mix}
