"""Docs CI: every fenced ``python`` block in ``docs/*.md`` must run.

Each guide's blocks execute top-to-bottom in one shared namespace (a
guide is a script told in prose), on CPU, against the seed registry —
snippets carry their own smoke-mode sizes. A block can opt out with an
HTML comment on the line directly above its fence::

    <!-- docs-ci: skip -->
    ```python
    cluster.deploy()   # illustrative only
    ```

Non-``python`` fences (``text``, ``pycon``, shell) are never executed.
This is the tier-1 step that keeps the guides from rotting against the
API they describe; CI runs it as its own named step (see ci.yml).
"""
import glob
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))

SKIP_MARK = "<!-- docs-ci: skip -->"


def extract_blocks(path):
    """[(first_code_lineno, source)] for every runnable ```python fence."""
    lines = open(path, encoding="utf-8").read().splitlines()
    blocks = []
    i = 0
    while i < len(lines):
        m = re.match(r"^\s*```(\w*)\s*$", lines[i])
        if m and m.group(1) == "python":
            skip = any(
                SKIP_MARK in prev
                for prev in lines[max(i - 2, 0):i]
                if prev.strip()
            )
            start = i + 1
            j = start
            while j < len(lines) and not re.match(r"^\s*```\s*$", lines[j]):
                j += 1
            if j >= len(lines):
                raise AssertionError(f"{path}:{i + 1}: unterminated ```python fence")
            if not skip:
                blocks.append((start + 1, "\n".join(lines[start:j])))
            i = j
        elif m:
            # skip over a non-python fence so its body can't open a fence
            j = i + 1
            while j < len(lines) and not re.match(r"^\s*```\s*$", lines[j]):
                j += 1
            i = j
        i += 1
    return blocks


def test_docs_exist_and_have_snippets():
    names = {os.path.basename(p) for p in DOCS}
    assert {"predict.md", "serving.md", "architecture.md"} <= names
    for required in ("serving.md", "architecture.md", "predict.md"):
        assert extract_blocks(os.path.join(ROOT, "docs", required)), (
            f"docs/{required} has no runnable python blocks"
        )


@pytest.mark.parametrize("path", DOCS, ids=[os.path.basename(p) for p in DOCS])
def test_docs_snippets_run(path):
    blocks = extract_blocks(path)
    if not blocks:
        pytest.skip(f"{os.path.basename(path)} has no runnable python blocks")
    ns = {"__name__": f"docs_{os.path.basename(path).replace('.', '_')}"}
    for lineno, src in blocks:
        code = compile(src, f"{path}:{lineno}", "exec")
        try:
            exec(code, ns)  # noqa: S102 — executing our own documentation
        except Exception as e:
            raise AssertionError(
                f"{os.path.basename(path)} block at line {lineno} failed: "
                f"{type(e).__name__}: {e}"
            ) from e
