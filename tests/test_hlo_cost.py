"""Validate the loop-aware HLO cost walker against known workloads."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.roofline.hlo_cost import analyze_hlo


def _cost(fn, *specs, **jit_kw):
    compiled = jax.jit(fn, **jit_kw).lower(*specs).compile()
    return analyze_hlo(compiled.as_text()), compiled


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    s, _ = _cost(lambda a, b: a @ b, x, w)
    expect = 2 * 128 * 256 * 512
    assert abs(s.dot_flops - expect) / expect < 0.01


def test_scan_multiplies_by_trip_count():
    """THE fix over XLA cost_analysis: a scanned matmul counts trip times."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=13)
        return y

    s, compiled = _cost(f, x, w)
    one = 2 * 64 * 64 * 64
    assert abs(s.dot_flops - 13 * one) / (13 * one) < 0.01, s.dot_flops
    # XLA's own counter misses the loop:
    xla_flops = compiled.cost_analysis().get("flops", 0)
    assert xla_flops < 2 * one
    # transcendentals: 13 tanh of 64*64
    assert s.transcendentals >= 13 * 64 * 64


def test_nested_scan():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = lax.scan(outer, x, None, length=5)
        return y

    s, _ = _cost(f, x)
    one = 2 * 32 * 32 * 32
    assert abs(s.dot_flops - 15 * one) / (15 * one) < 0.01, s.dot_flops


def test_batched_dot_contracting_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    s, _ = _cost(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    expect = 2 * 4 * 64 * 32 * 16
    assert abs(s.dot_flops - expect) / expect < 0.01, s.dot_flops


def test_hbm_bytes_reasonable():
    """Bytes of a simple matmul ~ inputs + output (within fusion slack)."""
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    s, _ = _cost(lambda a, b: a @ b, x, w)
    expect = 3 * 512 * 512 * 4
    assert expect * 0.5 <= s.hbm_bytes <= expect * 3, s.hbm_bytes


def test_collectives_counted_under_sharding():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return x.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s, _ = _cost(f, x, in_shardings=NamedSharding(mesh, P("d", None)))
    # single-device mesh: no collectives expected — just exercise the path
    assert s.collective_bytes >= 0


def test_no_unknown_heavy_ops_on_model_step():
    """The walker recognizes every op the real models emit (no silent
    undercount): compile a tiny model train step and check unknowns."""
    from repro.configs import get_arch
    from repro.models.registry import build_model

    cfg = get_arch("qwen3-0.6b").smoke()
    api = build_model(cfg)
    params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    compiled = jax.jit(lambda p, b: api.loss(p, b)[0]).lower(params, batch).compile()
    s = analyze_hlo(compiled.as_text())
    assert s.dot_flops > 0
    assert not s.unknown_ops, s.unknown_ops
