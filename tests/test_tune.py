"""Autotuner tests (``repro.tune``): signature-derived search spaces, the
SP2xx prefilter guarantee (nothing the static lint rejects is ever
launched, and every *selected* config is clean on every registry device),
deterministic ranking, and the TunedConfigs -> e2e plumbing."""
import math

import pytest

from repro.core import hwsim
from repro.core.e2e import apply_tuned, model_calls, step_estimate
from repro.core.hardware import REGISTRY
from repro.predict.api import CommCall, KernelCall
from repro.predict.backends import get_predictor
from repro.tune import (
    BLOCK_VALUES,
    DEFAULT_WORKLOADS,
    TUNABLE_KERNELS,
    TunedConfigs,
    UnknownKnobError,
    block_params,
    decomposer_workload,
    enumerate_candidates,
    predict_kind,
    prefilter,
    tune,
    tune_workload,
    validate_space,
)

HW = REGISTRY["tpu-v4"]

#: small deterministic space keeping stub-measured tune() runs fast
SMALL_SPACE = {"fused_moe": {"block_m": (64, 128, 256), "block_f": (128, 256)}}


def stub_measure(kernel, kw, blocks, *, args=None, repeats=1, interpret=None):
    """Deterministic fake wall-clock: monotone in grid steps (the real
    interpret-mode behaviour) with a block-dependent epsilon tiebreak."""
    from repro.tune import grid_steps

    steps = grid_steps(kernel, kw, blocks)
    return steps * 1e-4 + sum(blocks.values()) * 1e-9


# ----------------------------------------------------------------------
# search space is the signature, not a hard-coded guess
# ----------------------------------------------------------------------


def test_space_is_signature_derived():
    for kernel in TUNABLE_KERNELS:
        knobs = block_params(kernel)
        assert knobs, kernel
        assert all(k.startswith("block_") for k in knobs)
        assert all(isinstance(v, int) for v in knobs.values())
        # the old core.tuner bug: a `stages` knob no kernel accepts
        assert "stages" not in knobs


def test_fused_moe_knobs_match_ops():
    assert block_params("fused_moe") == {"block_m": 128, "block_f": 256}


def test_unknown_knob_raises():
    with pytest.raises(UnknownKnobError, match="stages"):
        validate_space("fused_moe", {"stages": (1, 2), "block_m": (128,)})
    # error names what IS tunable
    with pytest.raises(UnknownKnobError, match="block_f"):
        enumerate_candidates("fused_moe", {"stages": (1, 2)})


def test_enumerate_is_full_cross_product():
    cands = enumerate_candidates("fused_moe", SMALL_SPACE["fused_moe"])
    assert len(cands) == 6
    assert all(set(c) == {"block_m", "block_f"} for c in cands)
    assert len({tuple(sorted(c.items())) for c in cands}) == 6


# ----------------------------------------------------------------------
# the SP2xx guarantee
# ----------------------------------------------------------------------


def test_prefilter_default_registry_is_every_device():
    """A surviving candidate passes the static lint on EVERY registry
    device, so a tuned table is safe to apply fleet-wide."""
    from repro.analysis.kernels import check_blocks

    kw = DEFAULT_WORKLOADS["fused_moe"]
    survivors, rejected = prefilter(
        "fused_moe", kw, enumerate_candidates("fused_moe")
    )
    assert survivors
    for c in survivors:
        for hw in REGISTRY.values():
            assert not check_blocks("fused_moe", kw, c.blocks, hws=[hw]), (
                c.blocks, hw.name)
    # every rejection carries its diagnostics
    for blocks, diags in rejected:
        assert diags


@pytest.mark.parametrize("kernel", sorted(TUNABLE_KERNELS))
def test_selected_config_passes_sp2xx_everywhere(kernel):
    """Property: whatever config tune() selects is clean on every registry
    device (measurement stubbed; the selection path is the real one)."""
    from repro.analysis.kernels import check_blocks

    report = tune(
        kernel, HW,
        predictor=get_predictor("roofline", HW),
        top_k=3,
        measure_fn=stub_measure,
    )
    kw = report.workload
    assert report.n_candidates == len(report.survivors) + report.n_rejected
    for c in report.measured:
        assert not check_blocks(kernel, kw, c.blocks), c.blocks
    assert not check_blocks(kernel, kw, report.best.blocks)
    assert report.best.measured_s is not None
    assert report.speedup >= 1.0 or math.isclose(report.speedup, 1.0)


def test_nondivisible_blocks_are_rejected_not_launched():
    """A block that cannot tile the workload dims (after the kernels'
    ``min(block, dim)`` clamp) must be filtered, not measured — launching
    it would trip the kernel's divisibility assert (SP202)."""
    kw = {"E": 2, "C": 96, "D": 128, "F": 192}
    space = {"block_m": (32, 64, 96), "block_f": (64, 192)}
    survivors, rejected = prefilter(
        "fused_moe", kw, enumerate_candidates("fused_moe", space)
    )
    assert rejected  # 64 does not divide C=96 / F=192 evenly everywhere
    bad = {blocks["block_m"] for blocks, _ in rejected}
    assert 64 in bad
    for c in survivors:
        assert kw["C"] % min(c.blocks["block_m"], kw["C"]) == 0
        assert kw["F"] % min(c.blocks["block_f"], kw["F"]) == 0


# ----------------------------------------------------------------------
# deterministic ranking under a fixed predictor
# ----------------------------------------------------------------------


def test_ranking_is_deterministic():
    pred = get_predictor("roofline", HW)
    runs = [
        tune("fused_moe", HW, predictor=pred, top_k=4, measure_fn=stub_measure)
        for _ in range(2)
    ]
    order0 = [tuple(sorted(c.blocks.items())) for c in runs[0].survivors]
    order1 = [tuple(sorted(c.blocks.items())) for c in runs[1].survivors]
    assert order0 == order1
    assert runs[0].best.blocks == runs[1].best.blocks
    # ranked ascending by predicted time, ties toward larger blocks
    pred_times = [c.predicted_s for c in runs[0].survivors]
    assert pred_times == sorted(pred_times)


def test_blocks_change_the_prediction():
    """Block keys ride into the decomposer: the predictor is config-aware
    (otherwise ranking would be vacuous)."""
    X = decomposer_workload("fused_moe", DEFAULT_WORKLOADS["fused_moe"])
    times = {
        bf: hwsim.simulate("fused_moe", X, HW, config={"block_m": 128, "block_f": bf})
        for bf in (64, 512)
    }
    assert times[64] != times[512]


def test_hwsim_rejects_unknown_config_key():
    X = decomposer_workload("fused_moe", DEFAULT_WORKLOADS["fused_moe"])
    # `stages` exists in hwsim's simulated world but e.g. attention knobs
    # don't belong on a fused_moe call — phantom keys raise, not no-op
    with pytest.raises(ValueError, match="unknown config"):
        hwsim.simulate("fused_moe", X, HW, config={"block_q": 128})


def test_tune_workload_oracle_never_slows_down():
    X = {"M": 512, "E": 8, "topk": 2, "H": 512, "N": 512, "skew": 0.2, "seed": 3}
    r = tune_workload(X, HW, top_k=8)
    assert r.speedup >= 1.0
    if r.best_config:  # a winning config must itself be lint-clean
        from repro.analysis.kernels import check_blocks
        from repro.tune.tuner import _moe_helper_kwargs

        kw = _moe_helper_kwargs(X, r.best_config)
        assert not check_blocks("fused_moe", kw, r.best_config, hws=[HW])


# ----------------------------------------------------------------------
# TunedConfigs -> e2e plumbing
# ----------------------------------------------------------------------


def test_tuned_configs_roundtrip(tmp_path):
    tc = TunedConfigs()
    report = tune("fused_moe", HW, predictor=get_predictor("roofline", HW),
                  top_k=2, measure_fn=stub_measure)
    tc.add_report(report)
    tc.set("tpu-v5p", "attention", {"block_q": 256, "block_k": 512})
    p = tmp_path / "tuned.json"
    tc.save(str(p))
    back = TunedConfigs.load(str(p))
    assert back.configs == tc.configs
    assert back.for_hw(HW) == {predict_kind("fused_moe"): report.best.blocks}
    assert back.for_hw("tpu-v5p") == {"attention": {"block_q": 256, "block_k": 512}}
    assert back.for_hw("tpu-v6e") == {}


def test_apply_tuned_explicit_x_wins():
    calls = [
        KernelCall("gemm", {"M": 64, "N": 64, "K": 64, "block_m": 32}),
        CommCall("all_reduce", 1024, 2),
        ("grp", 2, [KernelCall("gemm", {"M": 8, "N": 8, "K": 8})]),
    ]
    tuned = {"gemm": {"block_m": 256, "block_n": 128}}
    out = apply_tuned(calls, tuned)
    # explicit per-call X keys are never overridden; missing keys merge in
    assert out[0].X["block_m"] == 32
    assert out[0].X["block_n"] == 128
    assert isinstance(out[1], CommCall)
    assert out[2][2][0].X == {"M": 8, "N": 8, "K": 8,
                              "block_m": 256, "block_n": 128}
    # untuned / empty tables are identity
    assert apply_tuned(calls, None) == calls
    assert apply_tuned(calls, {}) == calls


def test_step_estimate_responds_to_tuned_table():
    from repro.configs import get_arch

    cfg = get_arch("dbrx-132b").smoke()
    pred = get_predictor("oracle", HW)
    base = step_estimate(cfg, B=2, qlen=64, kvlen=64, tp=1, predictor=pred)
    tuned = {"fused_moe": {"block_m": 256, "block_f": 512},
             "attention": {"block_q": 256, "block_k": 256}}
    t = step_estimate(cfg, B=2, qlen=64, kvlen=64, tp=1, predictor=pred,
                      tuned=tuned)
    assert t.kernel_s != base.kernel_s
    # same call structure either way
    assert len(model_calls(cfg, 2, 64, 64, 1, tuned)) == \
        len(model_calls(cfg, 2, 64, 64, 1))


def test_core_tuner_shim_reexports():
    """The old import surface keeps working (one release of grace)."""
    from repro.core import tuner as shim

    assert shim.tune_workload is tune_workload
    for name in ("TuneResult", "geomean_speedup", "pearson", "spearman",
                 "tune_underperformers", "tune_one"):
        assert hasattr(shim, name), name


def test_block_values_lattice_sane():
    assert BLOCK_VALUES == tuple(sorted(BLOCK_VALUES))
    assert all(v % 32 == 0 for v in BLOCK_VALUES)
