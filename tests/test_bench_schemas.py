"""Bench artifact schemas (ISSUE 9): every metric the perf-trajectory
gate (``benchmarks.compare``) reads must be *declared* by the writer that
produces it (the module's ``BENCH_KEYS``), and ``write_bench_json``
must refuse payloads that silently drop a declared key — so renaming a
metric breaks the writer loudly instead of un-gating the trajectory."""
import importlib
import json
import os

import pytest

from benchmarks.common import Csv, write_bench_json
from benchmarks.compare import GATED_METRICS

BASELINE = os.path.join(
    os.path.dirname(__file__), "..", "results", "bench_baseline", "metrics.json"
)


def _writer_module(artifact: str):
    """``BENCH_fleet.json`` -> ``benchmarks.bench_fleet``."""
    assert artifact.startswith("BENCH_") and artifact.endswith(".json")
    name = artifact[len("BENCH_"):-len(".json")]
    return importlib.import_module(f"benchmarks.bench_{name}")


def test_every_gated_metric_is_declared_by_its_writer():
    for m in GATED_METRICS:
        mod = _writer_module(m["file"])
        keys = getattr(mod, "BENCH_KEYS", None)
        assert keys is not None, (
            f"{mod.__name__} writes gated artifact {m['file']} but declares "
            "no BENCH_KEYS schema"
        )
        assert m["key"] in keys, (
            f"compare.py gates {m['file']}::{m['key']} but {mod.__name__}."
            f"BENCH_KEYS does not declare it — the gate would silently SKIP"
        )


def test_declared_schemas_have_no_duplicates():
    for artifact in {m["file"] for m in GATED_METRICS}:
        keys = _writer_module(artifact).BENCH_KEYS
        assert len(keys) == len(set(keys)), f"duplicate keys in {artifact}"


def test_gate_directions_and_tolerances_are_sane():
    for m in GATED_METRICS:
        assert m["direction"] in ("higher", "lower")
        assert 0.0 < m["rel_tol"] < 1.0


def test_baseline_snapshot_matches_gated_metrics():
    # the committed snapshot and GATED_METRICS must agree entry for entry:
    # a gate without a baseline never fires, a baseline without a gate is
    # dead weight that --write-baseline would drop
    with open(BASELINE) as f:
        baseline = json.load(f)["metrics"]
    gated = {f"{m['file']}::{m['key']}": m for m in GATED_METRICS}
    assert set(baseline) == set(gated)
    for name, entry in baseline.items():
        m = gated[name]
        assert entry["file"] == m["file"] and entry["key"] == m["key"]
        assert entry["direction"] == m["direction"]
        assert entry["rel_tol"] == m["rel_tol"]
        assert isinstance(entry["value"], (int, float))


# ----------------------------------------------------------------------
# write_bench_json declared-schema validation
# ----------------------------------------------------------------------


def test_write_bench_json_rejects_missing_declared_keys(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    with pytest.raises(KeyError, match="missing declared schema keys"):
        write_bench_json(path, Csv(), declared=("a", "b"), a=1)
    assert not os.path.exists(path)


def test_write_bench_json_accepts_complete_payload(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    csv = Csv()
    csv.add("x/metric", 1.0, "derived")
    write_bench_json(path, csv, declared=("a", "b"), a=1, b=2.5, extra="ok")
    with open(path) as f:
        payload = json.load(f)
    assert payload["a"] == 1 and payload["b"] == 2.5 and payload["extra"] == "ok"
    assert payload["rows"][0]["name"] == "x/metric"


def test_write_bench_json_error_payload_skips_validation(tmp_path):
    # smoke-failure artifacts are intentionally partial
    path = str(tmp_path / "BENCH_x.json")
    write_bench_json(path, Csv(), declared=("a", "b"), error="boom",
                     passed=False)
    with open(path) as f:
        assert json.load(f)["error"] == "boom"
