"""Static-auditor tests: the current repo audits clean, and each check
family provably fires on a seeded re-introduction of its bug class."""
import copy
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (
    AuditError,
    AuditShape,
    Diagnostic,
    audit_comm_regressor,
    audit_predictor,
    check_coverage,
    check_head_accounting,
    check_kernel_resources,
    check_sharding,
    check_task_conservation,
    json_report,
    render_report,
    run_audit,
    sort_diagnostics,
    worst_severity,
)
from repro.configs import get_arch, list_archs
from repro.core.e2e import model_calls
from repro.core.hardware import get_hw
from repro.predict.api import CommCall, KernelCall
from repro.predict.backends import get_predictor
from repro.predict.comm import CommRegressor

MOE = "dbrx-132b"  # smallest MoE arch in the registry
DENSE = "qwen3-0.6b"


def _run_cli(*argv):
    """Run ``python -m repro.analysis`` from the repo root (src/ layout)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )


# ---------------------------------------------------------------------------
# diagnostics model


def test_diagnostic_severity_validated():
    with pytest.raises(ValueError):
        Diagnostic(code="SP999", severity="fatal", check="x", message="m")


def test_report_ordering_and_tally():
    diags = [
        Diagnostic(code="SP105", severity="info", check="c", message="i"),
        Diagnostic(code="SP201", severity="error", check="k", message="e"),
        Diagnostic(code="SP304", severity="warning", check="s", message="w"),
    ]
    ordered = sort_diagnostics(diags)
    assert [d.severity for d in ordered] == ["error", "warning", "info"]
    assert worst_severity(diags) == "error"
    assert worst_severity([]) is None
    report = render_report(diags)
    assert "1 error, 1 warning, 1 info" in report
    parsed = json.loads(json_report(diags))
    assert [p["code"] for p in parsed] == ["SP201", "SP304", "SP105"]


# ---------------------------------------------------------------------------
# the current repo audits clean


def test_full_registry_audit_is_clean():
    diags = run_audit()
    errors = [d for d in diags if d.severity in ("error", "warning")]
    assert not errors, render_report(errors)
    # conservation reports the artifact-gated skip for every arch
    assert {d.arch for d in diags if d.code == "SP105"} == set(list_archs())


def test_cli_strict_exits_zero():
    proc = _run_cli("--arch", DENSE, "--strict", "--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    parsed = json.loads(proc.stdout)
    assert all(d["severity"] == "info" for d in parsed)


def test_cli_rejects_unknown_arch():
    proc = _run_cli("--arch", "nope")
    assert proc.returncode == 2


# ---------------------------------------------------------------------------
# seeded bugs: each family fires


def _mutate_head_gemm(calls, **overrides):
    calls = copy.deepcopy(calls)
    for item in calls:
        if not isinstance(item, (KernelCall, CommCall)) and item[0] == "head":
            for c in item[2]:
                if isinstance(c, KernelCall) and c.kind == "gemm":
                    c.X.update(overrides)
    return calls


def test_seeded_lm_head_undercount_fires_sp103():
    """Re-introduce the PR 2 bug: the head GEMM prices B rows during a
    B*qlen prefill."""
    cfg = get_arch(DENSE)
    B, qlen, tp = 2, 128, 4
    calls = model_calls(cfg, B, qlen, qlen, tp)
    assert check_head_accounting(cfg, B=B, qlen=qlen, tp=tp, calls=calls) == []
    bugged = _mutate_head_gemm(calls, M=B)  # last-token-only accounting
    diags = check_head_accounting(cfg, B=B, qlen=qlen, tp=tp, calls=bugged)
    assert [d.code for d in diags] == ["SP103"]
    assert diags[0].data["expected"]["M"] == B * qlen


def test_seeded_head_gather_drift_fires_sp104():
    cfg = get_arch(DENSE)
    B, qlen, tp = 2, 128, 4
    calls = copy.deepcopy(model_calls(cfg, B, qlen, qlen, tp))
    for item in calls:
        if not isinstance(item, (KernelCall, CommCall)) and item[0] == "head":
            for c in item[2]:
                if isinstance(c, CommCall) and c.op == "all_gather":
                    c.nbytes /= 2  # bf16-sized gather of an f32 logit shard
    diags = check_head_accounting(cfg, B=B, qlen=qlen, tp=tp, calls=calls)
    assert [d.code for d in diags] == ["SP104"]


def test_seeded_decomposer_drift_fires_sp102(monkeypatch):
    """Emulate a decomposer regression: tasks account for half the GEMM
    MXU demand. The conservation sum catches it on every gemm call."""
    import repro.analysis.conservation as cons

    cfg = get_arch(DENSE)
    real = cons.decompose

    def lossy(kind, X, hw):
        t = real(kind, X, hw)
        if kind == "gemm":
            t.mxu = t.mxu * 0.5
        return t

    assert check_task_conservation(cfg, B=2, lin=512, lout=64, tp=4) == []
    monkeypatch.setattr(cons, "decompose", lossy)
    diags = check_task_conservation(cfg, B=2, lin=512, lout=64, tp=4)
    assert diags and all(d.code == "SP102" for d in diags)
    assert all(d.data["kind"] == "gemm" for d in diags)


def test_seeded_vmem_overflow_fires_sp201():
    """An autotuning candidate block that cannot fit: fused_moe with
    block_f=4096 double-buffers ~hundreds of MiB."""
    cfg = get_arch(MOE)
    clean = check_kernel_resources(cfg)
    assert [d for d in clean if d.severity == "error"] == []
    diags = check_kernel_resources(cfg, block_overrides={"fused_moe": {"block_f": 4096}})
    codes = {d.code for d in diags}
    assert "SP201" in codes or "SP202" in codes
    overflows = [d for d in diags if d.code == "SP201"]
    if overflows:
        assert all(d.data["footprint_bytes"] > d.data["vmem_bytes"] for d in overflows)


def test_seeded_bad_tiling_fires_sp202():
    cfg = get_arch(DENSE)
    diags = check_kernel_resources(
        cfg,
        workloads=[("flash_attention", {"B": 1, "S": 192, "Skv": 192, "Hq": 4, "Hkv": 4, "D": 64})],
    )
    assert [d.code for d in diags] == ["SP202"]  # 192 % min(128,192) != 0


def test_seeded_unaudited_leaf_fires_sp301():
    """A new parameter leaf that rides the generic fallback instead of an
    audited sharding rule."""
    import jax

    cfg = get_arch(DENSE)
    from repro.models.registry import build_model

    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    assert [d for d in check_sharding(cfg, param_shapes=shapes) if d.severity == "error"] == []
    bugged = dict(shapes)
    bugged["mystery_adapter"] = jax.ShapeDtypeStruct((4096, 4096), "float32")
    diags = check_sharding(cfg, param_shapes=bugged)
    assert "SP301" in {d.code for d in diags}
    sp301 = [d for d in diags if d.code == "SP301"]
    assert any(d.data["leaf"] == "mystery_adapter" for d in sp301)


def test_coverage_static_clean_and_seeded_sp401_sp402():
    cfg = get_arch(MOE)
    assert check_coverage(cfg) == []
    bugged = [
        KernelCall("conv3d", {"M": 1}),
        CommCall("all_to_one", 1e6, 8),
    ]
    diags = check_coverage(cfg, calls=bugged)
    assert {d.code for d in diags} == {"SP401", "SP402"}


# ---------------------------------------------------------------------------
# instance audits + the serve pre-flight hooks (satellite e)


def _stale_regressor(hw):
    """A regressor fitted before 'all_to_all' joined CommRegressor.OPS."""
    c = CommRegressor().fit(hw)
    for k in [k for k in c.theta if k[0] == "all_to_all"]:
        del c.theta[k]
    return c


def test_stale_comm_regressor_fires_sp401():
    hw = get_hw("tpu-v5e")
    assert audit_comm_regressor(None) == []
    assert audit_comm_regressor(CommRegressor().fit(hw)) == []
    diags = audit_comm_regressor(_stale_regressor(hw), hw_name=hw.name)
    assert [d.code for d in diags] == ["SP401"]
    assert diags[0].data["missing_ops"] == ["all_to_all"]


def test_audit_predictor_clean():
    hw = get_hw("tpu-v5e")
    assert audit_predictor(get_predictor("roofline", hw)) == []


def test_fleet_router_audit_catches_stale_regressor_at_init():
    from repro.serve.placement import FleetRouter

    hw = get_hw("tpu-v5e")
    stale = _stale_regressor(hw)
    # without audit: constructs fine (the stale regressor would surface
    # later, as a mid-sweep skip warning)
    FleetRouter(["tpu-v5e"], "roofline", comm=stale)
    with pytest.raises(AuditError) as ei:
        FleetRouter(["tpu-v5e"], "roofline", audit=True, comm=stale)
    assert [d.code for d in ei.value.diagnostics] == ["SP401"]
    assert "all_to_all" in str(ei.value)
    # a fitted fleet passes the same audit
    FleetRouter(["tpu-v5e"], "roofline", audit=True)


def test_engine_predicted_admission_audit():
    from repro.serve.engine import ContinuousBatchingEngine

    hw = get_hw("tpu-v5e")
    cfg = get_arch(DENSE)
    bad = get_predictor("roofline", hw, comm=_stale_regressor(hw))
    with pytest.raises(AuditError):
        ContinuousBatchingEngine(
            cfg, admission="predicted", predictor=bad, decode_slo_s=0.5, audit=True
        )
    good = get_predictor("roofline", hw)
    eng = ContinuousBatchingEngine(
        cfg, admission="predicted", predictor=good, decode_slo_s=0.5, audit=True
    )
    assert eng.admission == "predicted"


# ---------------------------------------------------------------------------
# audit shape knobs


def test_audit_shape_is_divisibility_safe():
    shape = AuditShape()
    assert shape.lin % 128 == 0 and shape.tp == 16
