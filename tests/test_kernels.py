"""Per-kernel validation: Pallas (interpret=True, the CPU-executable path of
the TPU kernels) vs pure-jnp oracles, swept over shapes/dtypes/block sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.fused_moe import ops as moe_ops
from repro.kernels.fused_moe.ref import fused_moe_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.silu_mul import ops as silu_ops
from repro.kernels.silu_mul.ref import silu_mul_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

FA_CASES = [
    # (B, S, Skv, Hq, Hkv, D, causal, window, softcap)
    (1, 64, 64, 2, 2, 16, True, None, None),
    (2, 128, 128, 4, 2, 32, True, None, None),
    (1, 64, 64, 2, 1, 16, True, 32, None),  # sliding window
    (1, 64, 64, 2, 2, 16, True, None, 30.0),  # softcap (gemma2)
    (2, 64, 64, 4, 4, 16, False, None, None),  # bidirectional (whisper enc)
    (1, 32, 128, 2, 2, 16, False, None, None),  # cross-attn shape
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, S, Skv, Hq, Hkv, D, causal, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D)).astype(dtype)
    out_k = fa_ops.attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=32, block_k=32, interpret=True, use_pallas=True,
    )
    out_r = fa_ops.attention(
        q, k, v, causal=causal, window=window, softcap=softcap, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("block", [(16, 16), (32, 64), (64, 32)])
def test_flash_attention_block_size_sweep(block):
    bq, bk = block
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out_k = fa_ops.attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    out_r = fa_ops.attention(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model stack's chunked_attention."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_model = chunked_attention(q, k, v, pos, pos, causal=True, q_block=16)
    out_kernel = fa_ops.attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out_model), np.asarray(out_kernel), rtol=1e-4, atol=1e-4
    )


# ----------------------------------------------------------------------
# fused MoE
# ----------------------------------------------------------------------

MOE_CASES = [
    # (E, C, D, F, block_m, block_f)
    (4, 32, 64, 128, 16, 64),
    (2, 64, 32, 64, 32, 32),
    (8, 16, 48, 96, 16, 96),
]


@pytest.mark.parametrize("case", MOE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_moe_matches_ref(case, dtype):
    E, C, D, F, bm, bf = case
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = (0.5 * jax.random.normal(ks[0], (E, C, D))).astype(dtype)
    wg = (0.1 * jax.random.normal(ks[1], (E, D, F))).astype(dtype)
    wu = (0.1 * jax.random.normal(ks[2], (E, D, F))).astype(dtype)
    wd = (0.1 * jax.random.normal(ks[3], (E, F, D))).astype(dtype)
    out_k = moe_ops.fused_moe(x, wg, wu, wd, block_m=bm, block_f=bf)
    out_r = fused_moe_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), **_tol(dtype)
    )


# ----------------------------------------------------------------------
# rmsnorm / silu&mul
# ----------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 32, 64), (2, 7, 48), (128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(k1, shape).astype(dtype)
    w = (0.1 * jax.random.normal(k2, shape[-1:])).astype(dtype)
    out_k = rms_ops.rmsnorm(x, w, block_rows=8)
    out_r = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("act", ["silu", "geglu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_silu_mul_matches_ref(act, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    g = jax.random.normal(k1, (4, 32, 64)).astype(dtype)
    u = jax.random.normal(k2, (4, 32, 64)).astype(dtype)
    out_k = silu_ops.act_mul(g, u, act=act, block_rows=16)
    out_r = silu_mul_ref(g, u, act=act)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), **_tol(dtype)
    )


# ----------------------------------------------------------------------
# property-based: flash attention invariants
# ----------------------------------------------------------------------

from hypothesis import given, settings, strategies as st


@settings(deadline=None, max_examples=10)
@given(
    s=st.sampled_from([32, 64]),
    h=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_convex_combination(s, h, d, seed):
    """Attention output rows are convex combinations of V rows: the output
    must lie inside [min(V), max(V)] per feature."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, d))
    k = jax.random.normal(ks[1], (1, s, h, d))
    v = jax.random.normal(ks[2], (1, s, h, d))
    out = fa_ops.attention(q, k, v, causal=True, block_q=16, block_k=16)
    vmin = np.asarray(v.min())
    vmax = np.asarray(v.max())
    o = np.asarray(out)
    assert o.min() >= vmin - 1e-3 and o.max() <= vmax + 1e-3


# ----------------------------------------------------------------------
# scaled_mm (W8A8)
# ----------------------------------------------------------------------

from repro.kernels.scaled_mm import ops as smm_ops
from repro.kernels.scaled_mm.ref import quantize_rowwise, scaled_mm_ref


@pytest.mark.parametrize("shape", [(64, 128, 96), (128, 64, 128)])
@pytest.mark.parametrize("blocks", [(32, 32, 64), (64, 64, 32)])
def test_scaled_mm_matches_ref(shape, blocks):
    M, K, N = shape
    bm, bn, bk = blocks
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x, sx = quantize_rowwise(jax.random.normal(k1, (M, K)))
    wq, sw = quantize_rowwise(jax.random.normal(k2, (N, K)))
    w = wq.T  # (K, N) with per-col scales sw
    out_k = smm_ops.scaled_mm(x, w, sx, sw, block_m=bm, block_n=bn, block_k=bk)
    out_r = scaled_mm_ref(x, w, sx, sw)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=1e-2, atol=1e-2,
    )


def test_scaled_mm_quantized_approximates_fp():
    """End-to-end W8A8 ~ fp32 matmul within quantization error."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    a = jax.random.normal(k1, (64, 128))
    b = jax.random.normal(k2, (96, 128))
    x, sx = quantize_rowwise(a)
    wq, sw = quantize_rowwise(b)
    out = smm_ops.scaled_mm(x, wq.T, sx, sw, block_m=32, block_n=32, block_k=64)
    ref = a @ b.T
    rel = np.abs(np.asarray(out, np.float32) - np.asarray(ref)) / (np.abs(np.asarray(ref)) + 1e-2)
    assert np.median(rel) < 0.05
