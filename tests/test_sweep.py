"""Multi-hardware sweep + serve-trace capture (ISSUE 3): sweep results
equal independent per-hw predicts, task-signature featurize sharing is
provably safe across every registry entry, and an engine's recorded trace
round-trips through the predict layer."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.dataset import build_dataset, featurize, sample_workload
from repro.core.e2e import model_calls, request_estimate, request_sweep
from repro.core.estimator import train_pipeweave
from repro.core.hardware import REGISTRY, get_hw
from repro.predict import (
    FeatureCache,
    SweepPredictor,
    get_predictor,
    group_calls,
    task_sig,
)

SWEEP_HWS = ["tpu-v5e", "tpu-v4", "tpu-v5p", "tpu-v6e", "tpu-v5e-16", "tpu-v7p"]


@pytest.fixture(scope="module")
def pw():
    ds = {
        "gemm": build_dataset("gemm", n_workloads=15, seed=3),
        "rmsnorm": build_dataset("rmsnorm", n_workloads=10, seed=4),
    }
    return train_pipeweave(ds, max_epochs=10)


@pytest.fixture(scope="module")
def trace():
    cfg = get_arch("qwen3-0.6b")
    return [
        (f"decode@{64 + i}", 1.0, model_calls(cfg, 4, 1, 64 + i, tp=1))
        for i in range(6)
    ]


# ----------------------------------------------------------------------
# sweep == independent per-hw predicts
# ----------------------------------------------------------------------


def test_sweep_matches_independent_predicts(pw, trace):
    sp = SweepPredictor(SWEEP_HWS, estimator=pw, fallback="oracle")
    res = sp.predict(trace)
    assert list(res) == SWEEP_HWS and len(res) == len(SWEEP_HWS)
    for name in SWEEP_HWS:
        ind = get_predictor(
            "synperf", get_hw(name), estimator=pw, fallback="oracle"
        ).predict(trace)
        assert np.isclose(res[name].total_s, ind.total_s, rtol=1e-9), name
        for fam, t in ind.by_family.items():
            assert np.isclose(res[name].by_family[fam], t, rtol=1e-9), (name, fam)


def test_sweep_roofline_matches_independent_full_registry(trace):
    """No-training variant over every registry entry (incl. workqueue
    scheduling via fused_moe elsewhere covered by task_sig test)."""
    sp = SweepPredictor(backend="roofline")  # default: whole registry
    res = sp.predict(trace)
    assert set(res) == set(REGISTRY)
    for name in REGISTRY:
        ind = get_predictor("roofline", get_hw(name)).predict(trace)
        assert np.isclose(res[name].total_s, ind.total_s, rtol=1e-9), name


def test_sweep_rejects_bad_hw_lists():
    with pytest.raises(ValueError, match="duplicate"):
        SweepPredictor(["tpu-v5e", "tpu-v5e"], backend="roofline")
    with pytest.raises(ValueError, match="at least one"):
        SweepPredictor([], backend="roofline")
    with pytest.raises(KeyError):
        SweepPredictor(["not-a-tpu"], backend="roofline")


# ----------------------------------------------------------------------
# task-signature sharing
# ----------------------------------------------------------------------


def test_task_sig_matches_direct_featurize():
    """The shared-task cache path must reproduce ``featurize`` exactly for
    every kernel family on every registry entry — this pins ``task_sig`` to
    the hw fields decompose/schedule actually read."""
    rng = np.random.default_rng(5)
    cache = FeatureCache()
    for kind in ("gemm", "attention", "rmsnorm", "silu_mul", "scaled_mm", "fused_moe"):
        X = sample_workload(kind, rng)
        for hw in REGISTRY.values():
            fs = cache.featureset(kind, X, hw)
            direct = featurize(kind, X, hw)
            assert fs.theoretical_s == direct.theoretical_s, (kind, hw.name)
            assert np.array_equal(fs.vector(hw), direct.vector(hw)), (kind, hw.name)
    # and sharing actually happened: fewer task builds than featuresets
    assert cache.task_misses < cache.misses
    assert cache.task_hits == cache.misses - cache.task_misses


def test_task_cache_shares_across_same_signature_hw():
    """rmsnorm's decompose ignores hw and static scheduling reads only
    num_chips — two 8-chip devices must share one task build."""
    cache = FeatureCache()
    X = {"seq": 512, "dim": 2048}
    a, b = get_hw("tpu-v5e"), get_hw("tpu-v6e")  # both 8 chips
    assert task_sig("rmsnorm", a) == task_sig("rmsnorm", b)
    cache.featureset("rmsnorm", X, a)
    cache.featureset("rmsnorm", X, b)
    assert cache.task_misses == 1 and cache.task_hits == 1
    assert cache.misses == 2  # analyze still runs per hw
    # a 4-chip device has a different signature -> new task build
    cache.featureset("rmsnorm", X, get_hw("tpu-v4i"))
    assert cache.task_misses == 2


def test_gemm_task_sig_tracks_tile_heuristic_inputs():
    """gemm decompose reads (vmem_mb, num_chips); hardware differing in
    either must not share tasks."""
    a, b = get_hw("tpu-v5e"), get_hw("tpu-v5p")  # same vmem + chips
    assert task_sig("gemm", a) == task_sig("gemm", b)
    c = get_hw("tpu-v7p")  # 256 MB vmem
    assert task_sig("gemm", a) != task_sig("gemm", c)
    d = get_hw("tpu-v5e-16")  # 16 chips
    assert task_sig("gemm", a) != task_sig("gemm", d)


def test_workqueue_task_sig_includes_throughputs():
    """fused_moe scheduling weighs tasks by pipe throughputs — equal chip
    counts with different FLOPs must not share a schedule."""
    a, b = get_hw("tpu-v5e"), get_hw("tpu-v6e")
    assert task_sig("fused_moe", a) != task_sig("fused_moe", b)


def test_sweep_shares_grouping_and_tasks(pw, trace):
    """One sweep groups once and re-warms nothing on a second pass."""
    sp = SweepPredictor(SWEEP_HWS, estimator=pw, fallback="oracle")
    sp.predict(trace)
    families, _ = group_calls(trace)
    n_shapes = sum(len(g.workloads) for g in families.values())
    # feature-level entries fan out per hw; task-level entries are shared
    # across equal signatures, so strictly fewer than shapes x hw
    assert sp.cache.misses == n_shapes * len(SWEEP_HWS)
    assert sp.cache.task_misses < n_shapes * len(SWEEP_HWS)
    before = (sp.cache.misses, sp.cache.task_misses)
    sp.predict(trace)  # fully warm: no new featurize or task work
    assert (sp.cache.misses, sp.cache.task_misses) == before


# ----------------------------------------------------------------------
# request-level sweep + comparison protocol
# ----------------------------------------------------------------------


def test_request_sweep_matches_request_estimate(pw):
    cfg = get_arch("qwen3-0.6b")
    res = request_sweep(cfg, 2, 64, 8, tp=1, pp=2, hws=SWEEP_HWS,
                        estimator=pw, fallback="oracle")
    for name in SWEEP_HWS:
        ind = request_estimate(
            cfg, 2, 64, 8, tp=1, pp=2,
            predictor=get_predictor("synperf", get_hw(name), estimator=pw,
                                    fallback="oracle"),
        )
        # same calls, same pp bubble surcharge
        assert np.isclose(res[name].total_s, ind.total_s, rtol=1e-9), name
        assert res[name].comm_s > 0  # pp boundary traffic priced


def test_prebuilt_predictors_must_be_keyed_by_hw_name():
    with pytest.raises(ValueError, match="key the mapping by hw name"):
        SweepPredictor(predictors={"v5e": get_predictor("oracle", get_hw("tpu-v5e"))})
    sp = SweepPredictor(predictors={"tpu-v5e": get_predictor("oracle", get_hw("tpu-v5e"))})
    assert sp.hw_names == ["tpu-v5e"]
    est = sp.predict([("g", 1.0, model_calls(get_arch("qwen3-0.6b"), 1, 1, 8, 1))])
    assert est["tpu-v5e"].total_s > 0


def test_audio_decode_steps_do_not_reprice_encoder():
    """The audio encoder runs once at prefill; decode-step groups (qlen=1)
    must not contain it (TraceRecorder ticks would otherwise inflate every
    generated token by the full encoder stack)."""
    cfg = get_arch("whisper-base")
    labels_prefill = [g[0] for g in model_calls(cfg, 2, cfg.enc_frames, cfg.enc_frames, 1)]
    labels_decode = [g[0] for g in model_calls(cfg, 2, 1, 64, 1)]
    assert "encoder" in labels_prefill
    assert "encoder" not in labels_decode


def test_request_sweep_rejects_ambiguous_arguments(pw):
    cfg = get_arch("qwen3-0.6b")
    sp = SweepPredictor(SWEEP_HWS[:2], backend="oracle")
    with pytest.raises(TypeError, match="not both"):
        request_sweep(cfg, 2, 64, 8, hws=SWEEP_HWS, sweep=sp)
    with pytest.raises(TypeError, match="not both"):
        request_sweep(cfg, 2, 64, 8, sweep=sp, backend="oracle")


def test_compare_all_unseen_sweep_has_no_nan_rows(trace):
    """An all-unseen sweep must omit the seen mean instead of printing
    nan% (and vice versa)."""
    sp = SweepPredictor(["tpu-v6e", "tpu-v7p"], backend="roofline")
    cmp = sp.compare(trace)
    table = cmp.table()
    assert "nan" not in table
    assert "unseen" in table
    split = cmp.split_mape()
    assert np.isnan(split["seen"]) and np.isfinite(split["unseen"])


def test_compare_seen_unseen_protocol(trace):
    """roofline vs oracle comparison over both splits: every row finite,
    split MAPEs aggregate the right hardware."""
    sp = SweepPredictor(SWEEP_HWS, backend="roofline")
    cmp = sp.compare(trace)
    assert set(cmp.totals) == set(SWEEP_HWS)
    for name, (m, p) in cmp.totals.items():
        assert m > 0 and p > 0, name
        assert np.isfinite(cmp.err_pct(name))
    split = cmp.split_mape()
    seen = [n for n in SWEEP_HWS if REGISTRY[n].seen]
    unseen = [n for n in SWEEP_HWS if not REGISTRY[n].seen]
    assert np.isclose(split["seen"], np.mean([cmp.err_pct(n) for n in seen]))
    assert np.isclose(split["unseen"], np.mean([cmp.err_pct(n) for n in unseen]))
    fams = cmp.family_mape()
    assert set(fams) == {"gemm", "attention", "rmsnorm", "silu_mul"}
    assert sp.predictors[SWEEP_HWS[0]].name == "roofline"
    # tables render without error and carry one line per hw
    assert len(cmp.table().splitlines()) >= len(SWEEP_HWS) + 2


def test_sweep_result_table_and_totals(trace):
    res = SweepPredictor(SWEEP_HWS, backend="oracle").predict(trace)
    totals = res.totals()
    assert set(totals) == set(SWEEP_HWS)
    assert all(v > 0 for v in totals.values())
    lines = res.table().splitlines()
    assert len(lines) == len(SWEEP_HWS) + 1  # header + one row per hw
    scaled = res.scaled(2.0)
    assert np.isclose(scaled[SWEEP_HWS[0]].total_s, 2 * res[SWEEP_HWS[0]].total_s)


# ----------------------------------------------------------------------
# serve-trace capture round-trip (tiny configs on CPU)
# ----------------------------------------------------------------------


def test_trace_recorder_roundtrip_serve_engine():
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder()
    eng = ServeEngine(cfg, max_batch=2, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(1, 13, dtype=np.int32), max_new=3))
    results = eng.step_batch()
    assert len(results) == 1 and len(results[0].tokens) == 3
    # one prefill + (max_new - 1) decode steps, in execution order
    assert rec.labels() == ["prefill[b1xL12]", "decode@12", "decode@13"]
    assert rec.n_steps == 3

    # the recorded groups are exactly the decomposer's lowering of the
    # executed shapes, so the priced trace equals hand-built model_calls
    oracle = get_predictor("oracle", get_hw("tpu-v5e"))
    est = oracle.predict(rec.calls())
    manual = [
        ("prefill", 1.0, model_calls(cfg, 1, 12, 12, 1)),
        ("d0", 1.0, model_calls(cfg, 1, 1, 13, 1)),
        ("d1", 1.0, model_calls(cfg, 1, 1, 14, 1)),
    ]
    ref = oracle.predict(manual)
    assert np.isclose(est.total_s, ref.total_s, rtol=1e-12)
    assert est.n_kernel_calls == ref.n_kernel_calls

    rec.clear()
    assert rec.n_steps == 0 and rec.calls() == []


def test_trace_recorder_roundtrip_continuous_engine():
    from repro.serve.engine import ContinuousBatchingEngine, Request
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder()
    eng = ContinuousBatchingEngine(cfg, slots=2, max_len=48, recorder=rec)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(1, 10, dtype=np.int32), max_new=2))
    out = eng.run_to_completion()
    assert sorted(r.rid for r in out) == [0, 1, 2]
    labels = rec.labels()
    # per-slot admission prefills + lock-step decode ticks over the pool
    assert labels.count("admit#0[L9]") == 1
    assert labels.count("admit#2[L9]") == 1
    assert any(l.startswith("tick[") for l in labels)

    # a recorded trace feeds the sweep directly (engine -> trace -> predict)
    res = SweepPredictor(["tpu-v5e", "tpu-v6e"], backend="oracle").predict(rec.calls())
    assert res["tpu-v5e"].total_s > 0 and res["tpu-v6e"].total_s > 0


def test_trace_recorder_untracked_engine_records_nothing():
    """recorder=None engines must not pay any tracing cost or state."""
    from repro.serve.engine import Request, ServeEngine

    cfg = get_arch("qwen3-0.6b").smoke()
    eng = ServeEngine(cfg, max_batch=1)
    assert eng.recorder is None
    eng.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32), max_new=2))
    assert len(eng.step_batch()) == 1
