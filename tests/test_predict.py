"""repro.predict tests: batched-vs-scalar equivalence, featurize-cache
correctness, backend registry round-trips, explicit fallback policy,
versioned estimator pickles, and the e2e legacy-shim equivalence."""
import pickle

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import hwsim
from repro.core.baselines import BASELINES
from repro.core.dataset import build_dataset, featurize
from repro.core.e2e import (
    model_calls,
    oracle_times,
    request_estimate,
    request_latency,
    step_time,
)
from repro.core.estimator import PICKLE_VERSION, PipeWeave, train_pipeweave
from repro.core.hardware import get_hw
from repro.predict import (
    CommCall,
    CommRegressor,
    Estimate,
    FeatureCache,
    KernelCall,
    PREDICTORS,
    UntrainedFamilyError,
    flatten_calls,
    get_predictor,
    group_calls,
)

HW = get_hw("tpu-v5e")


@pytest.fixture(scope="module")
def small_ds():
    return {
        "gemm": build_dataset("gemm", n_workloads=20, seed=3),
        "rmsnorm": build_dataset("rmsnorm", n_workloads=12, seed=4),
    }


@pytest.fixture(scope="module")
def pw(small_ds):
    return train_pipeweave(small_ds, max_epochs=12)


@pytest.fixture(scope="module")
def pw_gemm_only(small_ds):
    return train_pipeweave({"gemm": small_ds["gemm"]}, max_epochs=8)


CALLS = [
    KernelCall("gemm", {"M": 256, "N": 1024, "K": 512}),
    KernelCall("gemm", {"M": 256, "N": 1024, "K": 512}),  # duplicate shape
    KernelCall("gemm", {"M": 8, "N": 2048, "K": 512}, count=3),
    KernelCall("rmsnorm", {"seq": 64, "dim": 1024}),
    ("block", 4, [
        KernelCall("gemm", {"M": 8, "N": 2048, "K": 512}),
        KernelCall("rmsnorm", {"seq": 64, "dim": 1024}),
    ]),
]


# ----------------------------------------------------------------------
# batched == scalar
# ----------------------------------------------------------------------


def test_batched_predict_matches_scalar_sum(pw):
    pred = get_predictor("synperf", HW, estimator=pw)
    est = pred.predict(CALLS)
    scalar = sum(w * pw.predict_latency(c.kind, c.X, HW) for c, w in flatten_calls(CALLS))
    assert np.isclose(est.kernel_s, scalar, rtol=1e-9, atol=0.0), (est.kernel_s, scalar)
    assert est.total_s == est.kernel_s  # no comm calls here
    assert est.n_kernel_calls == 2 + 3 + 1 + 4 * 2
    assert set(est.by_family) == {"gemm", "rmsnorm"}
    assert np.isclose(sum(est.by_family.values()), est.kernel_s, rtol=1e-12)
    assert est.fallbacks == {}


def test_estimate_carries_analytical_ceiling(pw):
    pred = get_predictor("synperf", HW, estimator=pw)
    est = pred.predict(CALLS)
    theo = sum(w * featurize(c.kind, c.X, HW).theoretical_s
               for c, w in flatten_calls(CALLS))
    assert np.isclose(est.theoretical_s, theo, rtol=1e-9)
    # predicted efficiency <= 1, so latency >= ceiling
    assert est.kernel_s >= est.theoretical_s * 0.999


# ----------------------------------------------------------------------
# featurize cache + grouping
# ----------------------------------------------------------------------


def test_featurize_cache_hit_returns_identical_features():
    cache = FeatureCache()
    X = {"M": 128, "N": 512, "K": 256}
    v1 = cache.vector("gemm", X, HW)
    assert cache.misses == 1 and cache.hits == 0
    # key order must not matter
    v2 = cache.vector("gemm", dict(reversed(list(X.items()))), HW)
    assert cache.hits == 1 and cache.misses == 1
    assert np.array_equal(v1, v2)
    fresh = featurize("gemm", X, HW)
    assert np.array_equal(v1, fresh.vector(HW))
    assert cache.featureset("gemm", X, HW).theoretical_s == fresh.theoretical_s


def test_group_calls_dedups_and_accumulates_weights():
    fams, comms = group_calls(CALLS + [CommCall("all_reduce", 1e6, 4, count=2)])
    assert set(fams) == {"gemm", "rmsnorm"}
    gemm = fams["gemm"]
    assert len(gemm.workloads) == 2  # two unique shapes
    assert dict(zip([w["M"] for w in gemm.workloads], gemm.weights)) == {256: 2.0, 8: 7.0}
    assert fams["rmsnorm"].weights == [5.0]
    assert comms == {("all_reduce", 1e6, 4, 0.0): 2.0}


# ----------------------------------------------------------------------
# registry round-trip
# ----------------------------------------------------------------------


def test_registry_roundtrip_all_backends(pw, small_ds):
    calls = [
        KernelCall("gemm", {"M": 64, "N": 512, "K": 256}, count=2),
        CommCall("all_reduce", 1e6, 4),
    ]
    fitted = {"gemm": BASELINES["linear"]().fit(small_ds["gemm"])}
    comm = CommRegressor().fit(HW)
    kwargs = {
        "synperf": dict(estimator=pw, comm=comm),
        "roofline": dict(comm=comm),
        "oracle": {},
        "linear": dict(models=fitted, comm=comm),
        "habitat": dict(models={"gemm": BASELINES["roofline"]().fit(small_ds["gemm"])},
                        comm=comm),
        "neusight": dict(models={"gemm": BASELINES["roofline"]().fit(small_ds["gemm"])},
                         comm=comm),
    }
    assert set(kwargs) == set(PREDICTORS)
    for name in PREDICTORS:
        pred = get_predictor(name, HW, **kwargs[name])
        est = pred.predict(calls)
        assert isinstance(est, Estimate), name
        assert np.isfinite(est.total_s) and est.total_s > 0, name
        assert est.kernel_s > 0 and est.comm_s > 0, name
        assert est.total_s == pytest.approx(est.kernel_s + est.comm_s), name
        # scalar conveniences agree with the batched path
        assert pred.kernel_time("gemm", {"M": 64, "N": 512, "K": 256}) > 0, name


def test_unknown_backend_is_actionable():
    with pytest.raises(KeyError, match="synperf"):
        get_predictor("definitely-not-a-backend", HW)


def test_synperf_without_estimator_is_actionable(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_CACHE", str(tmp_path))
    with pytest.raises(RuntimeError, match="estimator"):
        get_predictor("synperf", HW)


def test_baseline_without_models_is_actionable():
    with pytest.raises(TypeError, match="models"):
        get_predictor("habitat", HW)


def test_oracle_backend_matches_hwsim():
    pred = get_predictor("oracle", HW)
    X = {"M": 64, "N": 512, "K": 256}
    assert pred.kernel_time("gemm", X) == pytest.approx(hwsim.simulate("gemm", X, HW))
    assert pred.comm_time("p2p", 1e6, 2) == pytest.approx(
        hwsim.simulate_comm("p2p", 1e6, 2, HW)
    )


# ----------------------------------------------------------------------
# explicit fallback policy
# ----------------------------------------------------------------------


def test_untrained_family_raises_by_default(pw_gemm_only):
    pred = get_predictor("synperf", HW, estimator=pw_gemm_only)
    with pytest.raises(UntrainedFamilyError, match="rmsnorm"):
        pred.predict(CALLS)


def test_fallback_oracle_is_recorded_not_silent(pw_gemm_only):
    pred = get_predictor("synperf", HW, estimator=pw_gemm_only, fallback="oracle")
    est = pred.predict(CALLS)
    assert est.fallbacks == {"rmsnorm": "oracle"}
    oracle_rms = 5.0 * hwsim.simulate("rmsnorm", {"seq": 64, "dim": 1024}, HW)
    assert est.by_family["rmsnorm"] == pytest.approx(oracle_rms)


def test_fallback_roofline_uses_theoretical(pw_gemm_only):
    pred = get_predictor("synperf", HW, estimator=pw_gemm_only, fallback="roofline")
    est = pred.predict(CALLS)
    assert est.fallbacks == {"rmsnorm": "roofline"}
    theo_rms = 5.0 * featurize("rmsnorm", {"seq": 64, "dim": 1024}, HW).theoretical_s
    assert est.by_family["rmsnorm"] == pytest.approx(theo_rms)


def test_bad_fallback_value_rejected():
    with pytest.raises(ValueError, match="fallback"):
        get_predictor("oracle", HW, fallback="silent")


# ----------------------------------------------------------------------
# comm regressor behind the API
# ----------------------------------------------------------------------


def test_unfitted_comm_regressor_raises_clear_error():
    with pytest.raises(RuntimeError, match="fit"):
        CommRegressor().predict("all_reduce", 1e6, 4)


def test_backend_autofits_comm_lazily():
    pred = get_predictor("roofline", HW)
    assert pred._comm is None  # not fitted until a comm call arrives
    t = pred.comm_time("all_reduce", 1e7, 4)
    assert t > 0 and pred._comm is not None


# ----------------------------------------------------------------------
# versioned estimator pickles
# ----------------------------------------------------------------------


def test_pipeweave_pickle_roundtrip(pw, tmp_path):
    p = str(tmp_path / "pw.pkl")
    pw.save(p)
    loaded = PipeWeave.load(p)
    X = {"M": 128, "N": 512, "K": 256}
    assert loaded.predict_latency("gemm", X, HW) == pw.predict_latency("gemm", X, HW)


def test_pipeweave_load_rejects_wrong_version(pw, tmp_path):
    p = str(tmp_path / "pw.pkl")
    with open(p, "wb") as f:
        pickle.dump({"__pipeweave_version__": PICKLE_VERSION + 1, "models": pw.models}, f)
    with pytest.raises(RuntimeError, match="version"):
        PipeWeave.load(p)


def test_pipeweave_load_rejects_preversioning_pickle(pw, tmp_path):
    p = str(tmp_path / "pw.pkl")
    with open(p, "wb") as f:
        pickle.dump(pw, f)  # the old save() format: the raw object
    with pytest.raises(RuntimeError, match="pre-versioning"):
        PipeWeave.load(p)


# ----------------------------------------------------------------------
# e2e on the new API
# ----------------------------------------------------------------------


def test_lm_head_gemm_covers_prefill_tokens():
    cfg = get_arch("qwen3-0.6b")
    def head_gemm(qlen):
        (_, _, head) = next(g for g in model_calls(cfg, 4, qlen, 128, 1)
                            if g[0] == "head")
        return next(c for c in head if isinstance(c, KernelCall) and c.kind == "gemm")
    assert head_gemm(128).X["M"] == 4 * 128  # prefill: every position
    assert head_gemm(1).X["M"] == 4  # decode: one position per sequence


def test_request_estimate_matches_legacy_lambda_path():
    cfg = get_arch("qwen3-0.6b")
    kt, ct = oracle_times(HW)
    legacy = request_latency(cfg, 2, 64, 8, tp=1, kernel_time=kt, comm_time=ct)
    est = request_estimate(cfg, 2, 64, 8, tp=1, predictor=get_predictor("oracle", HW))
    assert np.isclose(est.total_s, legacy, rtol=1e-9)
    assert est.theoretical_s is not None and 0 < est.theoretical_s <= est.total_s


def test_step_time_rejects_ambiguous_arguments():
    cfg = get_arch("qwen3-0.6b")
    kt, ct = oracle_times(HW)
    with pytest.raises(TypeError):
        step_time(cfg, 2, 8, 8, tp=1)  # neither predictor nor lambdas
    with pytest.raises(TypeError):
        step_time(cfg, 2, 8, 8, tp=1, predictor=get_predictor("oracle", HW),
                  kernel_time=kt, comm_time=ct)


def test_pp_bubble_scales_whole_estimate():
    cfg = get_arch("qwen3-0.6b")
    oracle = get_predictor("oracle", HW)
    e1 = request_estimate(cfg, 2, 64, 8, tp=1, pp=1, predictor=oracle)
    e2 = request_estimate(cfg, 2, 64, 8, tp=1, pp=2, predictor=oracle)
    assert e2.total_s > e1.total_s
    assert e2.comm_s > 0  # stage-boundary p2p traffic
