"""Block-sparse triangular causal attention == dense masked attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import chunked_attention


def _dense(q, k, v, pos, softcap=None, q_block=16):
    return chunked_attention(
        q, k, v, pos, pos, causal=True, softcap=softcap, q_block=q_block,
        causal_sparse=False,
    )


def _sparse(q, k, v, pos, softcap=None, q_block=16):
    return chunked_attention(
        q, k, v, pos, pos, causal=True, softcap=softcap, q_block=q_block,
        causal_sparse=True,
    )


@pytest.mark.parametrize("softcap", [None, 30.0])
@pytest.mark.parametrize("shape", [(2, 64, 4, 2, 16), (1, 96, 2, 1, 8)])
def test_triangular_matches_dense(shape, softcap):
    B, S, Hkv, G, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    np.testing.assert_allclose(
        np.asarray(_sparse(q, k, v, pos, softcap)),
        np.asarray(_dense(q, k, v, pos, softcap)),
        rtol=2e-5, atol=2e-5,
    )


def test_triangular_gradients_match_dense():
    B, S, Hkv, G, D = 1, 48, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    g_s = jax.grad(lambda q: _sparse(q, k, v, pos).sum())(q)
    g_d = jax.grad(lambda q: _dense(q, k, v, pos).sum())(q)
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_d), rtol=1e-4, atol=1e-4)


def test_triangular_halves_hlo_flops():
    """The whole point: compiled dot FLOPs drop to ~(nb+1)/(2*nb) of dense."""
    from repro.roofline.hlo_cost import analyze_hlo

    B, S, Hkv, G, D = 1, 512, 2, 1, 32
    q = jax.ShapeDtypeStruct((B, S, Hkv * G, D), jnp.float32)
    k = jax.ShapeDtypeStruct((B, S, Hkv, D), jnp.float32)
    v = jax.ShapeDtypeStruct((B, S, Hkv, D), jnp.float32)
    pos = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def flops(sparse):
        fn = lambda q, k, v, pos: chunked_attention(
            q, k, v, pos, pos, causal=True, q_block=64, causal_sparse=sparse
        )
        comp = jax.jit(fn).lower(q, k, v, pos).compile()
        return analyze_hlo(comp.as_text()).dot_flops

    dense_f, sparse_f = flops(False), flops(True)
    nb = S // 64
    expected = (nb + 1) / (2 * nb)  # 9/16 for nb=8
    assert sparse_f < dense_f * (expected + 0.1), (sparse_f, dense_f)


@settings(deadline=None, max_examples=8)
@given(
    s_blocks=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_triangular_property_random(s_blocks, seed):
    S = 16 * s_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, 2, 8))
    k = jax.random.normal(ks[1], (1, S, 2, 8))
    v = jax.random.normal(ks[2], (1, S, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    np.testing.assert_allclose(
        np.asarray(_sparse(q, k, v, pos)),
        np.asarray(_dense(q, k, v, pos)),
        rtol=3e-5, atol=3e-5,
    )
