"""Substrate tests: optimizer, data pipeline, checkpointing, trainer
fault-tolerance (kill/restart continuation), gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.collectives import ef_compress_grads
from repro.optim.adamw import AdamW, constant_lr, warmup_cosine
from repro.train.step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=constant_lr(0.1), weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_clips_global_norm():
    opt = AdamW(lr=constant_lr(0.0), clip_norm=1.0)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full((4, 4), 100.0)}, state, params)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_warmup_cosine_schedule_shape():
    s = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(s(100)) < float(s(50)) < float(s(10))


def test_weight_decay_only_on_matrices():
    opt = AdamW(lr=constant_lr(0.1), weight_decay=1.0, clip_norm=None)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.update(zeros, state, params)
    assert float(new["w"][0, 0]) < 1.0  # decayed
    assert float(new["b"][0]) == 1.0  # not decayed


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------


def test_data_deterministic_per_step():
    cfg = get_arch("qwen3-0.6b").smoke()
    src = SyntheticLM(cfg, DataConfig(batch=4, seq_len=32, seed=7))
    a = src.batch_at(12)
    b = src.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_disjoint():
    cfg = get_arch("qwen3-0.6b").smoke()
    s0 = SyntheticLM(cfg, DataConfig(batch=8, seq_len=16, seed=1, process_index=0, process_count=2))
    s1 = SyntheticLM(cfg, DataConfig(batch=8, seq_len=16, seed=1, process_index=1, process_count=2))
    a, b = s0.batch_at(0), s1.batch_at(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_tokens_in_vocab():
    cfg = get_arch("gemma2-2b").smoke()
    src = SyntheticLM(cfg, DataConfig(batch=2, seq_len=64))
    t = src.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < cfg.vocab_size


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32), "b": {"c": jnp.ones(4)}}
    mgr.save(3, state, extra={"loss": 1.5})
    mgr.save(6, state)
    mgr.save(9, state)
    assert mgr.steps() == [6, 9]  # keep=2 retention
    restored = mgr.restore_latest(state)
    assert restored is not None
    step, new_state, _ = restored
    assert step == 9
    np.testing.assert_array_equal(np.asarray(new_state["a"]), np.asarray(state["a"]))


def test_checkpoint_atomicity_no_partial(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "tmp.5.123", exist_ok=True)
    assert mgr.latest_step() is None


# ----------------------------------------------------------------------
# trainer fault tolerance: preemption + restart == uninterrupted run
# ----------------------------------------------------------------------


def _mk_trainer(tmp_path, total_steps):
    cfg = get_arch("qwen3-0.6b").smoke()
    data = DataConfig(batch=4, seq_len=32, seed=0)
    tc = TrainConfig(lr=1e-3, warmup=2, total_steps=total_steps)
    tcfg = TrainerConfig(
        total_steps=total_steps, ckpt_every=4, ckpt_dir=str(tmp_path), keep=2, log_every=100
    )
    return Trainer(cfg, data, tc, tcfg)


def test_preempt_restart_bitwise_continuation(tmp_path):
    # uninterrupted run
    t_full = _mk_trainer(tmp_path / "full", 8)
    _, state_full, losses_full = t_full.run(seed=0)
    # preempted at step 4 then restarted
    t_a = _mk_trainer(tmp_path / "pre", 8)
    step_a, _, losses_a = t_a.run(seed=0, preempt_after=4)
    assert step_a == 4
    t_b = _mk_trainer(tmp_path / "pre", 8)
    step_b, state_resumed, losses_b = t_b.run(seed=0)
    assert step_b == 8
    np.testing.assert_allclose(losses_a + losses_b, losses_full, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_full["params"]), jax.tree.leaves(state_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_training_reduces_loss(tmp_path):
    t = _mk_trainer(tmp_path, 30)
    _, _, losses = t.run(seed=1)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# ----------------------------------------------------------------------
# gradient compression
# ----------------------------------------------------------------------


def test_ef_compression_bias_vanishes():
    """Error feedback: accumulated compressed updates converge to the true
    gradient sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    err = None
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = ef_compress_grads({"g": g_true}, err)
        acc = acc + deq["g"]
    avg = acc / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g_true), atol=2e-2)


def test_ef_compression_quantizes_to_int8_levels():
    g = {"g": jnp.linspace(-1, 1, 256).astype(jnp.float32)}
    deq, err = ef_compress_grads(g, None)
    levels = np.unique(np.round(np.asarray(deq["g"]) / (1.0 / 127.0)).astype(int))
    assert len(levels) <= 255


def test_straggler_watchdog_logs(caplog):
    import logging

    t = _mk_trainer("/tmp/unused_watchdog", 1)
    with caplog.at_level(logging.WARNING, logger="repro.train"):
        for i in range(10):
            t._watchdog(i, 0.1)
        t._watchdog(10, 1.0)  # 10x the median -> straggler
    assert any("straggler" in r.message for r in caplog.records)
