"""Parallelism-aware prediction (ISSUE 5): EP all-to-all byte exactness,
GPipe/1F1B schedule analytics, and the comm wiring through predict/serve.

The executed ``shard_map`` schedules are validated in ``tests/test_dist.py``
(multi-device subprocesses); here the closed forms are pinned against the
pure event-driven ring simulation, and the decomposer's EP payload against
the dry-run's model-derived ledger, across the whole grid."""
import warnings

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

# initialize the backend before importing repro.launch.dryrun: that module
# pins XLA_FLAGS to 512 virtual devices at import time for the real
# dry-run; with the backend already up the flag is inert and the byte
# counters run on the normal single-device test process
jax.devices()

from repro.configs import get_arch, list_archs  # noqa: E402
from repro.core.decomposer import (  # noqa: E402
    COMPUTE_DTYPE_BYTES,
    ep_alltoall_bytes,
    moe_dispatch_geometry,
)
from repro.core.e2e import layer_calls, pp_bubble, request_estimate  # noqa: E402
from repro.core.hardware import get_hw  # noqa: E402
from repro.dist.pipeline import (  # noqa: E402
    bubble_fraction,
    pipeline_bubble_fraction,
    schedule_ticks,
    simulate_schedule,
)
from repro.launch.dryrun import count_ep_alltoall_bytes  # noqa: E402
from repro.predict import CommCall, CommRegressor, SweepPredictor, get_predictor  # noqa: E402
from repro.serve.trace import TraceRecorder  # noqa: E402

HW = get_hw("tpu-v5e")

MOE_ARCHS = [a for a in list_archs() if get_arch(a).n_experts]


# ----------------------------------------------------------------------
# schedule analytics: closed form == event simulation, 1F1B <= GPipe
# ----------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 32), V=st.integers(1, 4))
def test_schedule_ticks_match_ring_simulation(S, M, V):
    """Both analytical tick counts equal the executed ring machine's,
    tick for tick, over the whole (S, M, V) grid."""
    assert simulate_schedule(S, M, "gpipe") == schedule_ticks(S, M, "gpipe") == M + S - 1
    assert simulate_schedule(S, M, "1f1b", V) == schedule_ticks(S, M, "1f1b", V)


@settings(max_examples=80, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 32))
def test_1f1b_bubble_never_worse_than_gpipe(S, M):
    b_1f1b = bubble_fraction(S, M, "1f1b", 2)
    b_gpipe = bubble_fraction(S, M, "gpipe")
    assert b_1f1b <= b_gpipe + 1e-12
    if S > 1 and M % S == 0:
        # the production case (microbatches a multiple of stages): the
        # interleaved schedule is strictly better whenever there is a
        # bubble at all
        assert b_1f1b < b_gpipe


def test_bubble_fraction_edge_cases():
    assert bubble_fraction(1, 8, "gpipe") == 0.0
    assert bubble_fraction(1, 8, "1f1b", 2) == 0.0  # S=1: perfect overlap
    assert pipeline_bubble_fraction(4, 4) == pytest.approx(3 / 7)
    # interleave=1 degenerates to GPipe: same machine, same bubble
    assert bubble_fraction(4, 6, "1f1b", 1) == bubble_fraction(4, 6, "gpipe")
    # S | M: the Megatron closed form (S-1)/(V*M + S - 1)
    assert bubble_fraction(4, 8, "1f1b", 2) == pytest.approx(3 / 19)
    # ZB-H1: three-phase ticks, canonical 3M+S-1 makespan at V=1
    assert schedule_ticks(4, 8, "zb-h1", 1) == 3 * 8 + 4 - 1
    assert bubble_fraction(4, 8, "zb-h1", 2) == pytest.approx(3 / 51)
    with pytest.raises(ValueError, match="schedule"):
        schedule_ticks(4, 4, "zb-h2")


def test_pp_bubble_surcharge():
    # default microbatch count (2*pp) reproduces the pre-ISSUE-5 GPipe
    # heuristic exactly — estimates did not shift under the refactor
    for pp in (2, 3, 4, 8):
        assert pp_bubble(pp) == pytest.approx(1 + 0.5 * (pp - 1) / pp)
        assert pp_bubble(pp, schedule="1f1b") < pp_bubble(pp)
    assert pp_bubble(1) == 1.0
    # surcharge = ticks / ideal work in matching units
    assert pp_bubble(4, 8, "gpipe") == pytest.approx(11 / 8)
    assert pp_bubble(4, 8, "1f1b", 2) == pytest.approx(19 / 16)
    # zb-h1: 3*V*S*ceil(M/S) + (M-1)%S ticks over 3*V*M work units
    assert pp_bubble(4, 8, "zb-h1", 2) == pytest.approx(51 / 48)
    for pp in (2, 3, 4, 8):
        assert pp_bubble(pp, schedule="zb-h1") <= pp_bubble(pp, schedule="1f1b")


def test_request_estimate_1f1b_cheaper_than_gpipe():
    cfg = get_arch("qwen3-0.6b")
    oracle = get_predictor("oracle", HW)
    gp = request_estimate(cfg, 2, 64, 8, tp=1, pp=4, predictor=oracle)
    il = request_estimate(cfg, 2, 64, 8, tp=1, pp=4, pp_schedule="1f1b",
                          predictor=oracle)
    assert il.total_s < gp.total_s
    # the interleaved placement crosses more stage boundaries per token
    assert il.by_comm_op["p2p"] > gp.by_comm_op["p2p"]


# ----------------------------------------------------------------------
# EP all-to-all payloads: decomposer == dry-run model-derived ledger
# ----------------------------------------------------------------------


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_ep_bytes_exact_against_dryrun_count(arch):
    """The decomposer's workload-dict arithmetic must reproduce the
    dry-run's ledger — counted through the executed model layer's own
    ``dispatch_geometry`` — byte for byte, on every MoE arch and across
    prefill/decode/train shapes."""
    cfg = get_arch(arch)
    for B, qlen, train in ((32, 2048, False), (4, 128, False), (128, 1, False),
                           (1, 1, False), (8, 512, True)):
        led = count_ep_alltoall_bytes(cfg, B, qlen, train=train)
        cf = cfg.capacity_factor if train else max(cfg.capacity_factor, 2.0)
        mine = ep_alltoall_bytes({
            "T": B * qlen, "d": cfg.d_model, "E": cfg.n_experts,
            "topk": cfg.top_k, "capacity_factor": cf,
            "moe_group": cfg.moe_group,
            "dtype_bytes": COMPUTE_DTYPE_BYTES[cfg.compute_dtype],
        })
        assert mine == led["dispatch_bytes"] == led["combine_bytes"], (arch, B, qlen)
        assert led["layer_bytes"] == 2 * mine
        assert led["model_bytes"] == 2 * mine * cfg.n_layers


def test_moe_dispatch_geometry_invariants():
    G, Sg, C = moe_dispatch_geometry(T=1024, E=16, topk=4, capacity_factor=2.0,
                                     moe_group=512)
    assert G * Sg == 1024 and Sg <= 512
    assert C == -(-Sg * 4 // 16) * 2  # ceil(Sg*topk/E) * cf
    # tiny decode step: one group, capacity floored at topk
    G1, Sg1, C1 = moe_dispatch_geometry(T=2, E=128, topk=2, capacity_factor=2.0,
                                        moe_group=512)
    assert (G1, Sg1) == (1, 2) and C1 == 2


def test_layer_calls_emit_ep_alltoalls():
    cfg = get_arch("dbrx-132b")
    calls = layer_calls(cfg, 4, 128, 128, tp=4)
    a2a = [c for c in calls if isinstance(c, CommCall) and c.op == "all_to_all"]
    assert len(a2a) == 2  # dispatch + combine
    want = ep_alltoall_bytes({
        "T": 4 * 128, "d": cfg.d_model, "E": cfg.n_experts, "topk": cfg.top_k,
        "capacity_factor": max(cfg.capacity_factor, 2.0),
        "moe_group": cfg.moe_group,
    })
    assert a2a[0].nbytes == a2a[1].nbytes == want
    assert all(c.n_units == 4 for c in a2a)
    # single-unit: no EP traffic; dense archs: never
    assert not [c for c in layer_calls(cfg, 4, 128, 128, tp=1)
                if isinstance(c, CommCall) and c.op == "all_to_all"]
    dense = layer_calls(get_arch("deepseek-67b"), 4, 128, 128, tp=4)
    assert not [c for c in dense if isinstance(c, CommCall) and c.op == "all_to_all"]


def test_moe_request_estimate_prices_ep_traffic():
    cfg = get_arch("dbrx-132b")
    est = request_estimate(cfg, 2, 64, 8, tp=4, predictor=get_predictor("oracle", HW))
    assert est.by_comm_op.get("all_to_all", 0.0) > 0.0
    assert est.comm_s >= est.by_comm_op["all_to_all"]
    # EP traffic is priced per hardware across a sweep
    res = SweepPredictor(["tpu-v5e", "tpu-v6e"], "roofline").predict(
        [("step", 1.0, layer_calls(cfg, 2, 1, 256, tp=4))]
    )
    t5 = res["tpu-v5e"].by_comm_op["all_to_all"]
    t6 = res["tpu-v6e"].by_comm_op["all_to_all"]
    assert t5 > 0 and t6 > 0 and t5 != t6


# ----------------------------------------------------------------------
# comm oracle: per-op contention branches + skew-dependent all-to-all
# ----------------------------------------------------------------------


def test_simulate_comm_per_op_step_factors():
    """Each collective's alpha-beta step count, exercised directly: at a
    fixed payload/fleet the deterministic part of the latency orders as
    the (n-1)/n step factors say."""
    from repro.core import hwsim

    n, b = 4, 1e8
    t = {op: hwsim.simulate_comm(op, b, n, HW)
         for op in ("all_reduce", "all_gather", "reduce_scatter",
                    "all_to_all", "p2p")}
    assert all(v > 0 for v in t.values())
    # all_reduce ships 2(n-1)/n — clearly above the one-pass collectives
    assert t["all_reduce"] > t["all_gather"]
    assert t["all_reduce"] > t["reduce_scatter"]
    # p2p ships the whole payload: above the (n-1)/n single-pass ops
    assert t["p2p"] > t["all_gather"]
    with pytest.raises(KeyError):
        hwsim.simulate_comm("broadcast", b, n, HW)


def test_simulate_comm_zero_cases():
    from repro.core import hwsim

    assert hwsim.simulate_comm("all_reduce", 1e6, 1, HW) == 0.0
    assert hwsim.simulate_comm("all_reduce", 0.0, 8, HW) == 0.0
    assert hwsim.simulate_comm("all_to_all", -5.0, 8, HW) == 0.0


def test_simulate_comm_contention_flags():
    """The fixed contention line: >8 chips adds 12%, all_reduce 5%,
    all_to_all 8% — visible as ratios once noise (deterministic per
    (op, bytes, n, hw)) is divided out."""
    from repro.core import hwsim

    def deterministic(op, n):
        t = hwsim.simulate_comm(op, 1e9, n, HW)
        return t / hwsim._noise(op, {"b": int(1e9), "n": n}, HW, amp=0.05)

    # the >8-chip surcharge: deterministic latency jumps by more than the
    # step-factor drift between n=8 and n=16
    bw_steps = lambda n: 2.0 * (n - 1) / n
    r = (deterministic("all_reduce", 16) / bw_steps(16)) / (
        deterministic("all_reduce", 8) / bw_steps(8)
    )
    assert r == pytest.approx(1.17 / 1.05, rel=1e-3)


def test_a2a_hot_ratio_properties():
    from repro.core.hwsim import a2a_hot_ratio

    # balanced traffic or a single chip: exactly the legacy model
    assert a2a_hot_ratio(0.0, 8) == 1.0
    assert a2a_hot_ratio(-1.0, 8) == 1.0
    assert a2a_hot_ratio(0.9, 1) == 1.0
    # skew stretches the exchange, monotonically, bounded by n_chips
    prev = 1.0
    for skew in (0.1, 0.3, 0.6, 0.9):
        r = a2a_hot_ratio(skew, 8)
        assert prev < r <= 8.0
        prev = r
    # deterministic (lru_cached over a fixed seed range)
    assert a2a_hot_ratio(0.3, 8) == a2a_hot_ratio(0.3, 8)


def test_simulate_comm_skew_monotone_and_legacy_exact():
    from repro.core import hwsim

    t0 = hwsim.simulate_comm("all_to_all", 1e8, 8, HW)
    assert hwsim.simulate_comm("all_to_all", 1e8, 8, HW, 0.0) == t0  # legacy
    prev = t0
    for skew in (0.2, 0.5, 0.8):
        t = hwsim.simulate_comm("all_to_all", 1e8, 8, HW, skew)
        assert t > prev
        prev = t
    # skew only prices all_to_all — other ops ignore it entirely
    assert hwsim.simulate_comm("all_reduce", 1e8, 8, HW, 0.9) == (
        hwsim.simulate_comm("all_reduce", 1e8, 8, HW)
    )


def test_moe_layer_calls_carry_ep_skew():
    """The EP dispatch/combine CommCalls inherit the fused-MoE workload's
    routing skew (0.3), and the oracle prices skewed traffic above the
    balanced legacy estimate."""
    from repro.core import hwsim

    cfg = get_arch("dbrx-132b")
    a2a = [c for c in layer_calls(cfg, 4, 128, 128, tp=4)
           if isinstance(c, CommCall) and c.op == "all_to_all"]
    assert len(a2a) == 2 and all(c.skew == 0.3 for c in a2a)
    skewed = hwsim.simulate_comm("all_to_all", a2a[0].nbytes, 4, HW, 0.3)
    balanced = hwsim.simulate_comm("all_to_all", a2a[0].nbytes, 4, HW)
    assert skewed > balanced


def test_pp_boundary_hops_across_schedules():
    from repro.core.e2e import pp_boundary_hops

    for pp in (1, 2, 4, 8):
        for V in (1, 2, 4):
            gp = pp_boundary_hops(pp, "gpipe", V)
            il = pp_boundary_hops(pp, "1f1b", V)
            zb = pp_boundary_hops(pp, "zb-h1", V)
            if pp == 1:
                assert gp == il == zb == 0
            else:
                assert gp == pp - 1
                assert il == pp * V - 1
                assert zb == 2 * pp * V - 1  # B wave re-crosses every chunk
                assert zb > il >= gp


# ----------------------------------------------------------------------
# comm regressor: all_to_all coverage + actionable errors
# ----------------------------------------------------------------------


def test_comm_regressor_fits_all_to_all():
    reg = CommRegressor().fit(HW)
    assert "all_to_all" in reg.fitted_ops()
    t = reg.predict("all_to_all", 1e7, 4)
    from repro.core import hwsim

    assert t == pytest.approx(hwsim.simulate_comm("all_to_all", 1e7, 4, HW), rel=0.5)


def test_unfitted_errors_name_fitted_ops():
    with pytest.raises(RuntimeError, match=r"fitted ops: none"):
        CommRegressor().predict("all_to_all", 1e6, 4)
    # a regressor fitted before all_to_all joined OPS names what it has
    stale = CommRegressor().fit(HW)
    stale.theta = {k: v for k, v in stale.theta.items() if k[0] != "all_to_all"}
    with pytest.raises(RuntimeError, match=r"'all_to_all' \(fitted ops: \['all_gather'"):
        stale.predict("all_to_all", 1e6, 4)


def test_router_skips_stale_comm_hw_with_actionable_warning():
    """An EP sweep over a fleet where one entry's regressor predates the
    all_to_all bucket skips that entry with a warning naming the fitted
    ops, instead of aborting the whole placement."""
    from repro.serve.placement import FleetRouter

    stale = CommRegressor().fit(get_hw("tpu-v5e"))
    stale.theta = {k: v for k, v in stale.theta.items() if k[0] != "all_to_all"}
    sweep = SweepPredictor(predictors={
        "tpu-v5e": get_predictor("roofline", get_hw("tpu-v5e"), comm=stale),
        "tpu-v6e": get_predictor("roofline", get_hw("tpu-v6e")),
    })
    trace = [("step", 1.0, layer_calls(get_arch("dbrx-132b"), 2, 1, 256, tp=4))]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        pl = FleetRouter(sweep=sweep).route(trace)
    assert pl.best == "tpu-v6e"
    assert "tpu-v5e" in pl.skipped and "all_to_all" in pl.skipped["tpu-v5e"]
    assert any("fitted ops" in str(w.message) for w in caught)


# ----------------------------------------------------------------------
# trace capture at declared parallel degrees
# ----------------------------------------------------------------------


def test_trace_recorder_carries_collectives():
    cfg = get_arch("dbrx-132b").smoke()
    rec = TraceRecorder(tp=2, pp=2)
    rec.record_step("prefill", cfg, 2, 16, 16, phase="prefill")
    rec.record_step("decode", cfg, 2, 1, 17, phase="decode")
    assert rec.meta[0].tp == 2 and rec.meta[0].pp == 2
    from repro.predict import flatten_calls

    flat = [c for c, _ in flatten_calls(rec.calls())]
    ops = {c.op for c in flat if isinstance(c, CommCall)}
    assert {"all_to_all", "p2p", "all_reduce"} <= ops
    # the recorded trace prices end to end, collectives included
    est = get_predictor("oracle", HW).predict(rec.calls())
    assert est.by_comm_op["all_to_all"] > 0 and est.by_comm_op["p2p"] > 0
    # tp=1 recorder (the engines' default) stays collective-free
    rec1 = TraceRecorder()
    rec1.record_step("decode", cfg, 2, 1, 17)
    flat1 = [c for c, _ in flatten_calls(rec1.calls())]
    assert not [c for c in flat1 if isinstance(c, CommCall)]
    assert rec1.meta[0].tp == 1 and rec1.meta[0].pp == 1
