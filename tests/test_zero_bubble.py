"""Zero-bubble schedules + overlap-aware estimates (ISSUE 10).

Three proof surfaces, all analytical (the executed ``shard_map`` ZB-H1
forward and its tick-minimality run in ``tests/test_dist.py`` on forced
multi-device subprocesses):

  * ZB-H1 closed form == event simulation over the whole (S, M, V) grid,
    and the bubble ordering theorem ``zb-h1 <= 1f1b <= gpipe`` with
    strictness exactly where the theory says (``(M-1) mod S != 0``);
  * ``Estimate.overlapped()`` is bounded between pure compute and the
    additive estimate for every window, and the exposed-compute window
    model behaves (0 with no launches, kernel/2 for one, monotone,
    always < kernel);
  * overlap-priced ``request_estimate`` stays inside
    ``[compute-only, additive]`` end to end through the predict stack.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.core.e2e import pp_boundary_hops, pp_bubble, request_estimate
from repro.core.features import overlap_window_s
from repro.core.hardware import get_hw
from repro.dist.pipeline import (
    SCHEDULES,
    bubble_fraction,
    schedule_ticks,
    simulate_schedule,
)
from repro.predict import get_predictor
from repro.predict.api import Estimate

HW = get_hw("tpu-v5e")


# ----------------------------------------------------------------------
# ZB-H1 analytics: closed form == event machine, ordering theorem
# ----------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 32), V=st.integers(1, 4))
def test_zb_h1_closed_form_matches_ring_simulation(S, M, V):
    """The three-phase closed form equals the event-driven ring machine,
    tick for tick, over the whole (S, M, V) grid — the same machine that
    validates 1F1B, with a 3x slot lifecycle."""
    assert simulate_schedule(S, M, "zb-h1", V) == schedule_ticks(S, M, "zb-h1", V)


def test_all_schedules_closed_form_exhaustive_grid():
    """Exhaustive (not sampled) sweep: every schedule's closed form equals
    the simulator on a dense grid, so the property tests cannot have
    missed a resonance between S, M and V."""
    for S in range(1, 7):
        for M in range(1, 19):
            assert simulate_schedule(S, M, "gpipe") == schedule_ticks(S, M, "gpipe")
            for V in (1, 2, 3):
                for sched in ("1f1b", "zb-h1"):
                    assert simulate_schedule(S, M, sched, V) == schedule_ticks(
                        S, M, sched, V
                    ), (S, M, V, sched)


@settings(max_examples=80, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 32), V=st.integers(1, 4))
def test_bubble_ordering_zb_leq_1f1b_leq_gpipe(S, M, V):
    """The ordering theorem: at the same interleave, the ZB-H1 bubble is
    <= 1F1B's, which (at V >= 2... or V=1 where it equals GPipe) is <=
    GPipe's. Strictness for zb-vs-1f1b holds exactly when
    ``(M - 1) mod S != 0`` — the lone-straggler tie region."""
    b_gp = bubble_fraction(S, M, "gpipe")
    b_il = bubble_fraction(S, M, "1f1b", V)
    b_zb = bubble_fraction(S, M, "zb-h1", V)
    assert b_zb <= b_il + 1e-12
    assert b_il <= b_gp + 1e-12
    r = (M - 1) % S
    if r != 0:
        assert b_zb < b_il
    else:
        assert b_zb == pytest.approx(b_il)


def test_zb_h1_canonical_pins():
    # canonical ZB-H1 makespan at V=1, S | M: 3M + S - 1 ticks
    assert schedule_ticks(4, 8, "zb-h1", 1) == 27
    assert schedule_ticks(8, 16, "zb-h1", 1) == 55
    # the bench gate point (S=4, M=8, V=2): 3*2*4*2 + 3 = 51 ticks over
    # 3*2*8 = 48 work units
    assert schedule_ticks(4, 8, "zb-h1", 2) == 51
    assert bubble_fraction(4, 8, "zb-h1", 2) == pytest.approx(3 / 51)
    assert bubble_fraction(4, 8, "1f1b", 2) == pytest.approx(3 / 19)
    # S=1 is bubble-free for every ring schedule
    for V in (1, 2, 4):
        assert bubble_fraction(1, 8, "zb-h1", V) == 0.0
    # degenerate single microbatch: pure fill/drain
    assert schedule_ticks(4, 1, "zb-h1", 2) == 3 * 2 * 4
    # unknown schedules still raise (zb-h1 itself no longer does)
    with pytest.raises(ValueError, match="schedule"):
        schedule_ticks(4, 4, "zb-h2")
    assert "zb-h1" in SCHEDULES


def test_pp_layer_surcharge_and_hops_cover_zb_h1():
    # surcharge: 51 ticks / 48 work units at the gate point
    assert pp_bubble(4, 8, "zb-h1", 2) == pytest.approx(51 / 48)
    # the split backward re-crosses every chunk boundary: 2*pp*V - 1 hops
    assert pp_boundary_hops(4, "zb-h1", 2) == 15
    assert pp_boundary_hops(4, "1f1b", 2) == 7
    assert pp_boundary_hops(4, "gpipe", 2) == 3
    assert pp_boundary_hops(1, "zb-h1", 2) == 0
    # zb-h1's bubble surcharge never exceeds 1f1b's on a production sweep
    for pp in (2, 3, 4, 8):
        for M in (pp, 2 * pp, 3 * pp + 1):
            for V in (1, 2, 4):
                assert (
                    pp_bubble(pp, M, "zb-h1", V)
                    <= pp_bubble(pp, M, "1f1b", V) + 1e-12
                )


# ----------------------------------------------------------------------
# overlap window model + Estimate.overlapped() bounds
# ----------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    kernel_ms=st.floats(0.0, 100.0),
    n=st.integers(0, 10_000),
)
def test_overlap_window_model_properties(kernel_ms, n):
    k = kernel_ms * 1e-3
    w = overlap_window_s(k, n)
    assert 0.0 <= w < max(k, 1e-300) or (k == 0.0 and w == 0.0)
    if n == 0 or k == 0.0:
        assert w == 0.0
    if n == 1:
        assert w == pytest.approx(k / 2)
    # monotone in launch count: denser launches hide more
    assert overlap_window_s(k, n + 1) >= w


@settings(max_examples=40, deadline=None)
@given(
    kernel_ms=st.floats(0.0, 50.0),
    comm_ms=st.floats(0.0, 50.0),
    window_ms=st.floats(0.0, 200.0),
)
def test_overlapped_estimate_bounded(kernel_ms, comm_ms, window_ms):
    """kernel_s <= overlapped total <= additive total, for *any* window —
    oversized windows clamp to kernel_s, so comm exposure never goes
    negative and compute is never hidden under itself."""
    k, c, w = kernel_ms * 1e-3, comm_ms * 1e-3, window_ms * 1e-3
    est = Estimate(
        total_s=k + c, kernel_s=k, comm_s=c, theoretical_s=None,
        by_family={"gemm": k}, by_comm_op={"all_reduce": c},
        n_kernel_calls=1, n_comm_calls=1, fallbacks={},
        overlap_window_s=overlap_window_s(k, 3),
    )
    for ov in (est.overlapped(), est.overlapped(window_s=w)):
        assert est.kernel_s - 1e-15 <= ov.total_s <= est.total_s + 1e-15
        assert ov.kernel_s == est.kernel_s
        assert ov.comm_s >= 0.0
        assert sum(ov.by_comm_op.values()) == pytest.approx(
            ov.comm_s, rel=1e-9, abs=1e-15
        )
        assert ov.overlap_window_s <= est.kernel_s + 1e-15
    # window=0 is the additive estimate exactly
    assert est.overlapped(window_s=0.0).total_s == pytest.approx(est.total_s)


def test_overlapped_none_window_falls_back_to_additive():
    est = Estimate(
        total_s=3.0, kernel_s=1.0, comm_s=2.0, theoretical_s=None,
        by_family={}, by_comm_op={"p2p": 2.0},
        n_kernel_calls=0, n_comm_calls=2, fallbacks={},
        overlap_window_s=None,
    )
    ov = est.overlapped()
    assert ov.total_s == est.total_s and ov.comm_s == est.comm_s


def test_scaled_carries_overlap_window():
    est = Estimate(
        total_s=3.0, kernel_s=1.0, comm_s=2.0, theoretical_s=None,
        by_family={}, by_comm_op={}, n_kernel_calls=0, n_comm_calls=0,
        fallbacks={}, overlap_window_s=0.5,
    )
    assert est.scaled(2.0).overlap_window_s == pytest.approx(1.0)


# ----------------------------------------------------------------------
# overlap-priced request_estimate: regression bounds through the stack
# ----------------------------------------------------------------------


@pytest.mark.parametrize("arch,tp,pp", [
    ("qwen3-0.6b", 2, 1),
    ("dbrx-132b", 4, 1),
    ("qwen3-0.6b", 2, 4),
])
def test_request_estimate_overlap_bounded(arch, tp, pp):
    """comm_overlap=True lands in [compute-only, additive] on every
    request shape, including MoE EP traffic and pipelined requests where
    the bubble surcharge scales both bounds identically."""
    cfg = get_arch(arch).smoke()
    oracle = get_predictor("oracle", HW)
    kw = dict(tp=tp, pp=pp, pp_schedule="zb-h1" if pp > 1 else "gpipe",
              predictor=oracle)
    add = request_estimate(cfg, 2, 64, 8, **kw)
    ovl = request_estimate(cfg, 2, 64, 8, comm_overlap=True, **kw)
    assert add.kernel_s - 1e-15 <= ovl.total_s <= add.total_s + 1e-15
    assert ovl.kernel_s == pytest.approx(add.kernel_s)
    assert ovl.comm_s <= add.comm_s + 1e-15


def test_request_estimate_zb_h1_cheapest_schedule():
    cfg = get_arch("qwen3-0.6b").smoke()
    oracle = get_predictor("oracle", HW)
    totals = {
        sched: request_estimate(cfg, 2, 64, 8, tp=1, pp=4,
                                pp_schedule=sched, predictor=oracle).total_s
        for sched in SCHEDULES
    }
    # zb-h1 pays more boundary p2p traffic but the bubble shrink dominates
    assert totals["zb-h1"] < totals["gpipe"]
    assert pp_bubble(4, None, "zb-h1") < pp_bubble(4, None, "1f1b")
