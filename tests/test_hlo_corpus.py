"""Regression corpus for the optimized-HLO cost walker: hand-written HLO
text in the shapes XLA actually emits (layout-brace operands, batch dims,
mixed dtypes, known-trip-count whiles, collectives), with the parsed
M/N/K, FLOPs and bytes asserted against hand computation."""

from repro.roofline.hlo_cost import (
    _TUPLE_SPLIT,
    _shape_dims,
    analyze_hlo,
    computation_traffic,
    parse_module,
)

SIMPLE_DOT = """\
HloModule simple_dot

ENTRY %main (p0: f32[256,512], p1: f32[512,128]) -> f32[256,128] {
  %p0 = f32[256,512]{1,0} parameter(0)
  %p1 = f32[512,128]{1,0} parameter(1)
  ROOT %dot.1 = f32[256,128]{1,0} dot(f32[256,512]{1,0} %p0, f32[512,128]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

BATCH_DOT_LAYOUT = """\
HloModule batch_dot

ENTRY %main (p0: bf16[2,512,64], p1: bf16[2,64,128]) -> bf16[2,512,128] {
  %p0 = bf16[2,512,64]{2,1,0} parameter(0)
  %p1 = bf16[2,64,128]{2,1,0} parameter(1)
  ROOT %dot.2 = bf16[2,512,128]{2,1,0} dot(bf16[2,512,64]{2,1,0} %p0, bf16[2,64,128]{2,1,0} %p1), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}
}
"""

INT8_DOT = """\
HloModule int8_dot

ENTRY %main (p0: s8[256,512], p1: s8[512,128]) -> s32[256,128] {
  %p0 = s8[256,512]{1,0} parameter(0)
  %p1 = s8[512,128]{1,0} parameter(1)
  ROOT %dot.q = s32[256,128]{1,0} dot(s8[256,512]{1,0} %p0, s8[512,128]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

SCANNED_LAYERS = """\
HloModule scanned

%cond (cparam: (s32[], f32[128,128])) -> pred[] {
  %gte.c = s32[] get-tuple-element((s32[], f32[128,128]) %cparam), index=0
  %cn = s32[] constant(24)
  ROOT %lt = pred[] compare(s32[] %gte.c, s32[] %cn), direction=LT
}

%body (wparam: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %gte.0 = s32[] get-tuple-element((s32[], f32[128,128]) %wparam), index=0
  %c1 = s32[] constant(1)
  %add.0 = s32[] add(s32[] %gte.0, s32[] %c1)
  %gte.1 = f32[128,128]{1,0} get-tuple-element((s32[], f32[128,128]) %wparam), index=1
  %dot.b = f32[128,128]{1,0} dot(f32[128,128]{1,0} %gte.1, f32[128,128]{1,0} %gte.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple.b = (s32[], f32[128,128]) tuple(s32[] %add.0, f32[128,128]{1,0} %dot.b)
}

ENTRY %main (p0: f32[128,128]) -> (s32[], f32[128,128]) {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tuple.0 = (s32[], f32[128,128]) tuple(s32[] %c0, f32[128,128]{1,0} %p0)
  ROOT %while.1 = (s32[], f32[128,128]) while((s32[], f32[128,128]) %tuple.0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"24"}}
}
"""

COLLECTIVES = """\
HloModule collectives

ENTRY %main (p0: f32[1,128], p1: bf16[4096]) -> f32[4,128] {
  %p0 = f32[1,128]{1,0} parameter(0)
  %p1 = bf16[4096]{0} parameter(1)
  %ar = bf16[4096]{0} all-reduce(bf16[4096]{0} %p1), replica_groups={{0,1,2,3}}, to_apply=%add_comp
  ROOT %ag = f32[4,128]{1,0} all-gather(f32[1,128]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


def test_simple_dot_mnk_and_flops():
    comps = parse_module(SIMPLE_DOT)
    entry = comps["__entry__"]
    dot = [i for i in entry.instrs if i.op == "dot"][0]
    assert len(dot.operands) == 2
    assert _shape_dims(dot.type) == [256, 128]  # M, N
    s = analyze_hlo(SIMPLE_DOT)
    assert s.dot_flops == 2 * 256 * 128 * 512
    # boundary traffic: both operands read + output written, all f32
    assert s.hbm_bytes == (256 * 512 + 512 * 128 + 256 * 128) * 4


def test_batch_dot_layout_braces():
    """Layout braces `{2,1,0}` carry commas that must not split operands,
    and the batch dim must stay out of K."""
    comps = parse_module(BATCH_DOT_LAYOUT)
    dot = [i for i in comps["__entry__"].instrs if i.op == "dot"][0]
    assert len(dot.operands) == 2  # _TUPLE_SPLIT kept `{2,1,0}` intact
    s = analyze_hlo(BATCH_DOT_LAYOUT)
    # out numel = 2*512*128, contracting dim (lhs dim 2) = 64; batch dim
    # multiplies through out numel, not K
    assert s.dot_flops == 2 * (2 * 512 * 128) * 64
    assert s.hbm_bytes == (2 * 512 * 64 + 2 * 64 * 128 + 2 * 512 * 128) * 2


def test_mixed_dtype_dot_bytes():
    s = analyze_hlo(INT8_DOT)
    assert s.dot_flops == 2 * 256 * 128 * 512
    # s8 operands, s32 out
    assert s.hbm_bytes == 256 * 512 * 1 + 512 * 128 * 1 + 256 * 128 * 4


def test_while_known_trip_count_scales_body():
    s = analyze_hlo(SCANNED_LAYERS)
    assert s.n_while == 1
    # the body dot executes 24 times — the exact undercount the walker
    # exists to fix (cost_analysis() would count it once)
    assert s.dot_flops == 24 * 2 * 128 * 128 * 128


def test_collective_bytes_per_kind():
    s = analyze_hlo(COLLECTIVES)
    assert s.collectives["all-gather"]["bytes"] == 1 * 128 * 4
    assert s.collectives["all-gather"]["count"] == 1
    assert s.collectives["all-reduce"]["bytes"] == 4096 * 2
    assert s.collective_bytes == 128 * 4 + 4096 * 2


def test_tuple_split_respects_brackets():
    parts = _TUPLE_SPLIT.split(
        "bf16[2,512,64]{2,1,0} %p0, bf16[2,64,128]{2,1,0} %p1, s32[] %i"
    )
    assert len(parts) == 3
    assert parts[0].endswith("%p0") and parts[2] == "s32[] %i"


def test_computation_traffic_fusion_grouping():
    """A single-consumer elementwise producer fuses into its dot consumer:
    the intermediate value never hits HBM."""
    text = """\
HloModule fused

ENTRY %main (p0: f32[256,512], p1: f32[512,128]) -> f32[256,128] {
  %p0 = f32[256,512]{1,0} parameter(0)
  %p1 = f32[512,128]{1,0} parameter(1)
  %neg = f32[256,512]{1,0} negate(f32[256,512]{1,0} %p0)
  ROOT %dot.f = f32[256,128]{1,0} dot(f32[256,512]{1,0} %neg, f32[512,128]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps = parse_module(text)
    traffic = computation_traffic(comps["__entry__"], comps)
    # %neg merges into the dot group: p0 + p1 read, dot out written; the
    # negated intermediate is on-chip
    assert traffic == (256 * 512 + 512 * 128 + 256 * 128) * 4


def test_unknown_dtype_shapes_are_skipped():
    s = analyze_hlo(
        """\
HloModule opaque

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %cc = f32[8]{0} custom-call(f32[8]{0} %p0), custom_call_target="foo"
}
"""
    )
    assert s.dot_flops == 0
