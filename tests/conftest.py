"""Test-environment compatibility shims.

1. `hypothesis` fallback: this container does not ship hypothesis and
   installing packages is out of scope, so when the real package is missing
   we register tests/_hypothesis_stub.py under its name before any test
   module imports it. With hypothesis installed the stub never loads.

2. `AbstractMesh` signature: the suite constructs abstract meshes with the
   jax >= 0.5 two-argument form ``AbstractMesh(axis_sizes, axis_names)``;
   jax 0.4.x expects a single tuple of (name, size) pairs. Wrap the class in
   jax.sharding's namespace so both spellings work.
"""
import importlib.util
import os
import sys


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


def _patch_abstract_mesh():
    import jax.sharding as jsh

    orig = jsh.AbstractMesh
    try:
        orig((1,), ("x",))
        return  # modern signature already supported
    except TypeError:
        pass

    def compat_abstract_mesh(axis_sizes, axis_names=None, **kw):
        if axis_names is None:
            return orig(axis_sizes, **kw)
        return orig(tuple(zip(axis_names, axis_sizes)), **kw)

    jsh.AbstractMesh = compat_abstract_mesh


def _patch_cost_analysis():
    import jax

    compiled_cls = jax.stages.Compiled
    orig = compiled_cls.cost_analysis

    def probe_is_list():
        # jax 0.4.x returns a one-element list of dicts; >= 0.5 returns the
        # dict itself. Normalize to the dict the suite expects.
        import jax.numpy as jnp

        out = jax.jit(lambda x: x + 1).lower(jnp.zeros(())).compile().cost_analysis()
        return isinstance(out, list)

    if not probe_is_list():
        return

    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list):
            return out[0] if out else {}
        return out

    compiled_cls.cost_analysis = cost_analysis


_install_hypothesis_stub()
_patch_abstract_mesh()
_patch_cost_analysis()
