"""Mesh-native serving tests (ISSUE 6): engines that actually execute
sharded, recorder mesh inheritance, and the engine-level satellite fixes
(admission priced at the engine's tp, per-batch PRNG keys, deque queues,
real per-request residency).

Multi-device numerics run in subprocesses (device count locks at first jax
init in the host test process); in-process variants are additionally
gated on ``jax.device_count() >= 8`` so the CI multi-device leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) exercises the
sharded path without a subprocess hop.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve.engine import ContinuousBatchingEngine, Request, ServeEngine
from repro.serve.trace import TraceRecorder


def _run_sub(script: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def _f32_smoke(name="qwen3-0.6b"):
    # float32 compute so sharded-vs-unsharded argmax comparisons are not
    # at the mercy of bf16 reaccumulation ties
    return dataclasses.replace(get_arch(name).smoke(), compute_dtype="float32")


# ----------------------------------------------------------------------
# mesh-native numerics: same tokens sharded vs single-device
# ----------------------------------------------------------------------

_SHARDED_SERVE = """
    import dataclasses
    import numpy as np, jax
    from repro.configs import get_arch
    from repro.serve.engine import ServeEngine, ContinuousBatchingEngine, Request
    from repro.serve.trace import TraceRecorder

    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").smoke(), compute_dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    prompts = [np.arange(1, 9 + i, dtype=np.int32) for i in range(4)]

    eng1 = ServeEngine(cfg, seed=0, max_batch=4)
    for i, p in enumerate(prompts):
        eng1.submit(Request(i, p, max_new=8))
    ref = {r.rid: r.tokens for r in eng1.step_batch()}

    rec = TraceRecorder()
    eng2 = ServeEngine(cfg, params=eng1.params, seed=0, max_batch=4,
                       mesh=mesh, recorder=rec)
    for i, p in enumerate(prompts):
        eng2.submit(Request(i, p, max_new=8))
    got = {r.rid: r.tokens for r in eng2.step_batch()}
    assert got == ref, (got, ref)
    # the engine reports the mesh's degrees and the recorder inherits them
    # without the caller declaring tp=/pp=
    assert eng2.tp == 4 and eng2.pp == 1
    assert rec.meta and all(m.tp == 4 and m.pp == 1 for m in rec.meta)
    # params are genuinely placed sharded, not replicated wholesale
    shardings = {str(l.sharding.spec) for l in jax.tree.leaves(eng2.params)
                 if hasattr(l.sharding, "spec")}
    assert any("model" in s for s in shardings), shardings

    c1 = ContinuousBatchingEngine(cfg, slots=2, max_len=48, seed=0)
    for i, p in enumerate(prompts):
        c1.submit(Request(10 + i, p, max_new=6))
    ref2 = {r.rid: r.tokens for r in c1.run_to_completion()}

    rec2 = TraceRecorder()
    c2 = ContinuousBatchingEngine(cfg, slots=2, max_len=48, params=c1.params,
                                  seed=0, mesh=mesh, recorder=rec2)
    for i, p in enumerate(prompts):
        c2.submit(Request(10 + i, p, max_new=6))
    got2 = {r.rid: r.tokens for r in c2.run_to_completion()}
    assert got2 == ref2, (got2, ref2)
    assert all(m.tp == 4 for m in rec2.meta)
    print("OK")
"""


def test_sharded_engines_match_single_process_subprocess():
    """Both engines produce identical tokens on an 8-device (2 data x 4
    model) mesh vs unsharded, and an attached recorder inherits the
    mesh's degrees — the ISSUE 6 acceptance numerics."""
    assert "OK" in _run_sub(_SHARDED_SERVE)


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices (CI multi-device leg)")
def test_sharded_serve_engine_matches_in_process():
    cfg = _f32_smoke()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    prompts = [np.arange(1, 7 + i, dtype=np.int32) for i in range(3)]
    eng1 = ServeEngine(cfg, seed=0, max_batch=4)
    for i, p in enumerate(prompts):
        eng1.submit(Request(i, p, max_new=6))
    ref = {r.rid: r.tokens for r in eng1.step_batch()}

    rec = TraceRecorder()
    eng2 = ServeEngine(cfg, params=eng1.params, seed=0, max_batch=4,
                       mesh=mesh, recorder=rec)
    for i, p in enumerate(prompts):
        eng2.submit(Request(i, p, max_new=6))
    assert {r.rid: r.tokens for r in eng2.step_batch()} == ref
    assert eng2.tp == 4 and all(m.tp == 4 for m in rec.meta)


# ----------------------------------------------------------------------
# recorder mesh inheritance (unit — no devices needed)
# ----------------------------------------------------------------------


def test_recorder_inherits_bound_mesh_degrees():
    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder()
    rec.bind_mesh(4, 2)
    assert rec.resolved_tp == 4 and rec.resolved_pp == 2
    rec.record_step("tick", cfg, 2, 1, 16, phase="decode")
    assert rec.meta[0].tp == 4 and rec.meta[0].pp == 2
    # bound pp > 1 carries the stage-boundary traffic like declared pp did
    assert rec.steps[0][2][-1][0] == "pp_boundary"


def test_recorder_declared_mode_still_works():
    """The pre-ISSUE-6 declared path (deprecation shim): no engine mesh
    bound, declared degrees price the trace, no warning."""
    import warnings as w

    cfg = get_arch("qwen3-0.6b").smoke()
    with w.catch_warnings():
        w.simplefilter("error")
        rec = TraceRecorder(tp=2, pp=2)
        rec.record_step("tick", cfg, 2, 1, 16, phase="decode")
    assert rec.meta[0].tp == 2 and rec.meta[0].pp == 2


def test_recorder_mesh_wins_over_declared_with_deprecation():
    cfg = get_arch("qwen3-0.6b").smoke()
    rec = TraceRecorder(tp=2)
    with pytest.warns(DeprecationWarning, match="mesh wins"):
        rec.bind_mesh(4, 1)
    rec.record_step("tick", cfg, 2, 1, 16, phase="decode")
    assert rec.meta[0].tp == 4


def test_meshless_engine_leaves_declared_degrees_alone():
    """A recorder with declared degrees attached to a meshless engine
    keeps pricing at the declared mesh (the PR 5 hypothetical-mesh use),
    with no warning."""
    import warnings as w

    cfg = _f32_smoke()
    with w.catch_warnings():
        w.simplefilter("error")
        rec = TraceRecorder(tp=2)
        eng = ServeEngine(cfg, seed=0, max_batch=2, recorder=rec)
        eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), max_new=2))
        eng.step_batch()
    assert all(m.tp == 2 for m in rec.meta)


# ----------------------------------------------------------------------
# satellite: admission prices the engine's actual tp
# ----------------------------------------------------------------------


def test_predicted_admission_prices_engine_tp():
    """_predicted_tick_s must price at the engine's tp, not a hard-coded
    tp=1: with a tp-sensitive predictor, the logged predicted_s matches
    the tp=engine.tp lowering and differs from the tp=1 one."""
    from repro.core.e2e import model_calls
    from repro.core.hardware import get_hw
    from repro.predict import get_predictor

    cfg = _f32_smoke()
    pred = get_predictor("oracle", get_hw("tpu-v5e"))
    eng = ContinuousBatchingEngine(
        cfg, slots=2, max_len=64, seed=0,
        admission="predicted", predictor=pred, decode_slo_s=10.0,
    )
    # simulate a mesh-native engine without needing devices: the runner's
    # degrees are plain attributes resolved from the mesh at construction
    eng._runner.tp = 2
    eng.submit(Request(0, np.arange(1, 9, dtype=np.int32), max_new=4))
    eng.step()
    assert eng.admission_log, "admission decision was not logged"
    entry = eng.admission_log[0]
    at_tp2 = pred.predict(model_calls(cfg, 2, 1, entry["kv"], tp=2)).total_s
    at_tp1 = pred.predict(model_calls(cfg, 2, 1, entry["kv"], tp=1)).total_s
    assert entry["predicted_s"] == pytest.approx(at_tp2, rel=1e-12)
    assert entry["predicted_s"] != pytest.approx(at_tp1, rel=1e-6)


# ----------------------------------------------------------------------
# satellite: per-batch PRNG keys
# ----------------------------------------------------------------------


def test_batches_sample_independently_but_reproducibly():
    cfg = _f32_smoke()
    prompt = np.arange(1, 9, dtype=np.int32)

    def two_batches(seed):
        eng = ServeEngine(cfg, seed=seed, max_batch=1)
        out = []
        for rid in range(2):
            eng.submit(Request(rid, prompt, max_new=8, temperature=1.0))
        out.append(eng.step_batch()[0].tokens)
        out.append(eng.step_batch()[0].tokens)
        return out

    a = two_batches(seed=0)
    # identical request in consecutive batches must not sample identically
    # (the old fixed PRNGKey(17) made every batch an exact replay)
    assert a[0] != a[1]
    # but the engine stays reproducible under its seed
    assert two_batches(seed=0) == a
    assert two_batches(seed=1) != a


# ----------------------------------------------------------------------
# satellites: deque queues + real residency metrics
# ----------------------------------------------------------------------


def test_queues_are_deques_and_fifo():
    cfg = _f32_smoke()
    eng = ServeEngine(cfg, seed=0, max_batch=2)
    cont = ContinuousBatchingEngine(cfg, slots=2, max_len=48, seed=0)
    assert isinstance(eng.queue, deque) and isinstance(cont.queue, deque)
    for rid in range(3):
        eng.submit(Request(rid, np.arange(1, 5, dtype=np.int32), max_new=2))
    first = eng.step_batch()
    assert [r.rid for r in first] == [0, 1] and [r.rid for r in eng.queue] == [2]


def test_continuous_results_carry_residency():
    cfg = _f32_smoke()
    cont = ContinuousBatchingEngine(cfg, slots=2, max_len=48, seed=0)
    for rid in range(3):
        cont.submit(Request(rid, np.arange(1, 6, dtype=np.int32), max_new=4))
    results = cont.run_to_completion()
    assert len(results) == 3
    for r in results:
        # one admission prefill + one tick per decode token
        assert r.ticks == len(r.tokens)
        assert r.prefill_s > 0.0
        assert r.decode_s >= 0.0
        assert r.latency_s >= r.prefill_s + r.decode_s - 1e-9


def test_serve_engine_results_carry_residency():
    cfg = _f32_smoke()
    eng = ServeEngine(cfg, seed=0, max_batch=2)
    eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), max_new=4))
    eng.submit(Request(1, np.arange(1, 4, dtype=np.int32), max_new=2))
    results = eng.step_batch()
    by_rid = {r.rid: r for r in results}
    assert by_rid[0].ticks == 4 and by_rid[1].ticks == 2
    for r in results:
        assert r.latency_s == pytest.approx(r.prefill_s + r.decode_s)
