"""Direct unit tests pinning each kernel's static ``grid_shape`` /
``vmem_footprint`` helpers to the ``pallas_call`` BlockSpecs they mirror
(satellite of the static-auditor PR): footprints are recomputed here from
the BlockSpec block shapes by hand, so a kernel BlockSpec edit that
forgets the helper fails loudly."""
import pytest

from repro.kernels import largest_divisor_block
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.fused_moe import ops as moe_ops
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.scaled_mm import ops as mm_ops
from repro.kernels.silu_mul import ops as silu_ops


def test_largest_divisor_block():
    assert largest_divisor_block(1024, 256) == 256
    assert largest_divisor_block(100, 256) == 100  # clamp to total
    assert largest_divisor_block(100, 64) == 50  # largest divisor <= 64
    assert largest_divisor_block(7, 4) == 1  # prime: falls to 1


# ---------------------------------------------------------------------------
# flash_attention: BlockSpecs (1,bq,D) q/out, (1,bk,D) k/v;
# scratch (bq,1) f32 x2 + (bq,D) f32


@pytest.mark.parametrize("S,Skv,bq,bk", [(512, 512, 128, 128), (64, 512, 128, 128), (1, 384, 128, 128)])
def test_flash_static_helpers(S, Skv, bq, bk):
    B, Hq, Hkv, D = 2, 8, 2, 64
    ebq, ebk = min(bq, S), min(bk, Skv)
    grid = flash_ops.grid_shape(B, S, Skv, Hq, Hkv, D, block_q=bq, block_k=bk)
    assert grid == (B * Hkv * (Hq // Hkv), S // ebq, Skv // ebk)
    fp = flash_ops.vmem_footprint(B, S, Skv, Hq, Hkv, D, block_q=bq, block_k=bk, dtype_bytes=2)
    blocks = (ebq * D + ebk * D + ebk * D + ebq * D) * 2  # q + k + v + out
    scratch = (ebq * 1 + ebq * 1 + ebq * D) * 4  # m, l, acc (f32)
    assert fp == 2 * blocks + scratch


def test_flash_grid_raises_where_kernel_asserts():
    with pytest.raises(ValueError):
        flash_ops.grid_shape(1, 192, 192, 4, 4, 64)  # 192 % min(128,192) != 0
    # the clamp path: S < block never raises on its own
    assert flash_ops.grid_shape(1, 64, 64, 4, 4, 64)[1:] == (1, 1)


# ---------------------------------------------------------------------------
# fused_moe: BlockSpecs x (1,bm,D), w_gate/w_up (1,D,bf), w_down (1,bf,D),
# out (1,bm,D); scratch (bm,D) f32


@pytest.mark.parametrize("C,F,bm,bf", [(256, 1024, 128, 256), (64, 128, 128, 256)])
def test_moe_static_helpers(C, F, bm, bf):
    E, D = 8, 512
    ebm, ebf = min(bm, C), min(bf, F)
    assert moe_ops.grid_shape(E, C, D, F, block_m=bm, block_f=bf) == (E, C // ebm, F // ebf)
    fp = moe_ops.vmem_footprint(E, C, D, F, block_m=bm, block_f=bf, dtype_bytes=2)
    blocks = (ebm * D + D * ebf + D * ebf + ebf * D + ebm * D) * 2
    assert fp == 2 * blocks + ebm * D * 4


def test_moe_grid_raises_on_ragged_capacity():
    with pytest.raises(ValueError):
        moe_ops.grid_shape(8, 192, 512, 1024)  # C=192 % 128 != 0


# ---------------------------------------------------------------------------
# scaled_mm: int8 x (bm,bk) / w (bk,bn), f32 scales (bm,1)/(1,bn),
# out (bm,bn); scratch (bm,bn) int32 — largest-divisor clamp, never raises


@pytest.mark.parametrize("M,K,N", [(1024, 512, 2048), (100, 96, 60)])
def test_scaled_mm_static_helpers(M, K, N):
    bm = largest_divisor_block(M, 128)
    bn = largest_divisor_block(N, 128)
    bk = largest_divisor_block(K, 256)
    assert mm_ops.grid_shape(M, K, N) == (M // bm, N // bn, K // bk)
    fp = mm_ops.vmem_footprint(M, K, N, out_dtype_bytes=2)
    blocks = bm * bk + bk * bn + (bm * 1 + 1 * bn) * 4 + bm * bn * 2
    assert fp == 2 * blocks + bm * bn * 4


# ---------------------------------------------------------------------------
# rmsnorm / silu_mul: full-width row blocks


def test_rmsnorm_static_helpers():
    R, d = 1024, 2048
    rows = largest_divisor_block(R, 256)
    assert rms_ops.grid_shape(R, d) == (R // rows,)
    assert rms_ops.vmem_footprint(R, d, dtype_bytes=2) == 2 * (rows * d + d + rows * d) * 2


def test_silu_mul_static_helpers():
    R, d = 1024, 2048
    rows = largest_divisor_block(R, 128)  # default block_rows is 128
    assert silu_ops.grid_shape(R, d) == (R // rows,)
    assert silu_ops.vmem_footprint(R, d, dtype_bytes=2) == 2 * (3 * rows * d) * 2


def test_silu_mul_default_fits_smallest_vmem_for_largest_dff():
    """The auditor-motivated default: deepseek's d_ff=22016 must fit the
    64 MiB registry devices (the original 256-row default was 64.5 MiB)."""
    from repro.core.hardware import REGISTRY

    min_vmem = min(hw.vmem_mb for hw in REGISTRY.values()) * 2**20
    assert silu_ops.vmem_footprint(1024, 22016, dtype_bytes=2) <= min_vmem


# ---------------------------------------------------------------------------
# helpers agree with a real launch (grid arithmetic exercised end-to-end)


def test_helpers_match_executed_kernel_shapes():
    import jax
    import numpy as np

    B, S, Hq, Hkv, D = 1, 128, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hq, D), "bfloat16")
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), "bfloat16")
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), "bfloat16")
    out = flash_ops.attention(q, k, v)
    assert out.shape == (B, S, Hq, D)
    grid = flash_ops.grid_shape(B, S, S, Hq, Hkv, D)
    assert grid == (B * Hkv * (Hq // Hkv), 1, 1)
    assert np.isfinite(np.asarray(out, dtype=np.float32)).all()
