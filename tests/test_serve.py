"""Serving engine integration tests (reduced configs on CPU)."""
import numpy as np

from repro.configs import get_arch
from repro.serve.engine import Request, ServeEngine


def _engine(arch="qwen3-0.6b", **kw):
    return ServeEngine(get_arch(arch).smoke(), **kw)


def test_serves_batched_requests_to_completion():
    eng = _engine(max_batch=3)
    rng = np.random.default_rng(0)
    for i in range(5):
        L = int(rng.integers(8, 24))
        eng.submit(Request(rid=i, prompt=rng.integers(1, 200, L).astype(np.int32), max_new=4))
    results = []
    while eng.queue:
        results += eng.step_batch()
    assert sorted(r.rid for r in results) == [0, 1, 2, 3, 4]
    for r in results:
        assert len(r.tokens) == 4
        assert all(0 <= t < eng.cfg.vocab_size for t in r.tokens)


def test_greedy_is_deterministic():
    outs = []
    for _ in range(2):
        eng = _engine(max_batch=2)
        eng.submit(Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32), max_new=5))
        outs.append(eng.step_batch()[0].tokens)
    assert outs[0] == outs[1]


def test_temperature_sampling_runs():
    eng = _engine(max_batch=1)
    eng.submit(Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32), max_new=5,
                       temperature=1.0))
    r = eng.step_batch()[0]
    assert len(r.tokens) == 5


def test_ssm_arch_serves():
    eng = _engine("mamba2-370m", max_batch=2)
    eng.submit(Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32), max_new=3))
    r = eng.step_batch()[0]
    assert len(r.tokens) == 3
