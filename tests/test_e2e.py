"""E2E workload generator + predictor tests."""
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.core import hwsim
from repro.core.e2e import (
    CommCall,
    CommRegressor,
    KernelCall,
    layer_calls,
    model_calls,
    oracle_times,
    request_latency,
    step_time,
)
from repro.core.hardware import get_hw

HW = get_hw("tpu-v5e")


@pytest.mark.parametrize("arch", list_archs())
def test_layer_calls_cover_every_arch(arch):
    cfg = get_arch(arch)
    calls = layer_calls(cfg, B=4, qlen=128, kvlen=128, tp=2)
    assert calls, arch
    kinds = {c.kind for c in calls if isinstance(c, KernelCall)}
    if cfg.family == "moe":
        assert "fused_moe" in kinds
    if cfg.family in ("dense", "moe", "hybrid", "audio", "vlm"):
        assert "attention" in kinds
    if cfg.family in ("ssm", "hybrid"):
        assert "gemm" in kinds
    # TP>1 must introduce communication
    assert any(isinstance(c, CommCall) for c in calls)


def test_tp_reduces_per_unit_kernel_work():
    cfg = get_arch("deepseek-67b")
    kt, ct = oracle_times(HW)
    t1 = step_time(cfg, 4, 512, 512, tp=1, kernel_time=kt, comm_time=lambda *a: 0.0)
    t4 = step_time(cfg, 4, 512, 512, tp=4, kernel_time=kt, comm_time=lambda *a: 0.0)
    assert t4 < t1


def test_decode_step_cheaper_than_prefill():
    cfg = get_arch("qwen3-0.6b")
    kt, ct = oracle_times(HW)
    pre = step_time(cfg, 8, 1024, 1024, tp=1, kernel_time=kt, comm_time=ct)
    dec = step_time(cfg, 8, 1, 1024, tp=1, kernel_time=kt, comm_time=ct)
    # small model: decode is launch-overhead bound, so the gap is modest
    assert dec < pre / 3


def test_comm_regressor_fits_oracle():
    reg = CommRegressor().fit(HW)
    errs = []
    rng = np.random.default_rng(3)
    for _ in range(30):
        nbytes = float(np.exp(rng.uniform(np.log(1e4), np.log(5e8))))
        n = int(rng.choice([2, 4, 8]))
        t_true = hwsim.simulate_comm("all_reduce", nbytes, n, HW)
        t_pred = reg.predict("all_reduce", nbytes, n)
        errs.append(abs(t_pred - t_true) / t_true)
    assert np.mean(errs) < 0.25, np.mean(errs)


def test_request_latency_monotone_in_output_len():
    cfg = get_arch("qwen3-0.6b")
    kt, ct = oracle_times(HW)
    t_short = request_latency(cfg, 4, 512, 16, tp=1, kernel_time=kt, comm_time=ct)
    t_long = request_latency(cfg, 4, 512, 128, tp=1, kernel_time=kt, comm_time=ct)
    assert t_long > t_short


def test_pp_adds_bubble():
    cfg = get_arch("deepseek-67b")
    kt, ct = oracle_times(HW)
    t1 = request_latency(cfg, 4, 256, 16, tp=4, pp=1, kernel_time=kt, comm_time=ct)
    t2 = request_latency(cfg, 4, 256, 16, tp=4, pp=2, kernel_time=kt, comm_time=ct)
    assert t2 > t1
