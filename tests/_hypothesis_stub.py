"""Minimal deterministic stand-in for `hypothesis`.

Loaded by tests/conftest.py ONLY when the real package is absent (this
container bakes the jax toolchain but not hypothesis, and installing
dependencies is out of scope). It implements just the surface the test
suite uses — ``@settings(deadline=..., max_examples=N)``, ``@given(**kw)``
and the ``integers`` / ``sampled_from`` / ``floats`` / ``booleans``
strategies — drawing a fixed per-test number of examples from a seeded RNG,
with boundary values tried first. No shrinking, no example database; when
real hypothesis is installed it takes precedence automatically.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, Sequence

__version__ = "0.0-stub"

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy draws example i of a run: boundary cases first, then a
    seeded-random sweep (deterministic across runs)."""

    def __init__(self, edges: Sequence[Any], draw: Callable[[random.Random], Any]):
        self._edges = list(edges)
        self._draw = draw

    def example(self, i: int, rng: random.Random):
        if i < len(self._edges):
            return self._edges[i]
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        [min_value, max_value], lambda rng: rng.randint(min_value, max_value)
    )


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(
        [min_value, max_value], lambda rng: rng.uniform(min_value, max_value)
    )


def booleans() -> _Strategy:
    return _Strategy([False, True], lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(elements, lambda rng: elements[rng.randrange(len(elements))])


def just(value) -> _Strategy:
    return _Strategy([value], lambda rng: value)


def given(*_args, **strats):
    if _args:
        raise NotImplementedError("stub @given supports keyword strategies only")

    def decorate(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{f.__module__}.{f.__qualname__}")
            for i in range(n):
                drawn = {k: s.example(i, rng) for k, s in strats.items()}
                try:
                    f(*args, **kwargs, **drawn)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {drawn!r}"
                    ) from e

        wrapper.hypothesis = types.SimpleNamespace(inner_test=f)
        # hide the drawn parameters from pytest's fixture resolution (real
        # hypothesis does the same): drawn args are supplied here, not by
        # fixtures, and no suite test mixes @given with fixtures/parametrize
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


def settings(deadline=None, max_examples=None, **_kw):
    def decorate(f):
        if max_examples is not None:
            f._stub_max_examples = max_examples
        return f

    return decorate


def assume(condition) -> bool:
    # Real hypothesis retries on a failed assumption; the stub simply skips
    # the example by raising nothing and letting callers guard themselves.
    return bool(condition)


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    booleans=booleans,
    sampled_from=sampled_from,
    just=just,
)
