"""Fast single-device unit tests for the distribution substrate — the cheap
complement to test_dist.py's multi-device subprocess integration suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.collectives import (
    DEFAULT_BUCKET_BYTES,
    bucket_leaves,
    ef_compress_grads,
    ef_compress_grads_bucketed,
    int8_dequantize,
    int8_quantize,
)
from repro.dist.pipeline import pipeline_bubble_fraction
from repro.dist.sharding import (
    active_mesh,
    batch_pspecs,
    cache_pspecs,
    constrain,
    param_pspecs,
    resolve_pspec,
    to_named,
    use_mesh,
)


# ----------------------------------------------------------------------
# resolve_pspec edge cases
# ----------------------------------------------------------------------


def _mesh(sizes, names):
    # two-arg AbstractMesh; conftest normalizes the signature on jax 0.4.x
    from jax.sharding import AbstractMesh

    return AbstractMesh(sizes, names)


def test_resolve_pspec_odd_head_counts_replicate():
    mesh = _mesh((16, 16), ("data", "model"))
    # hymba-style odd head counts on a 16-way model axis
    for heads in (25, 7, 17, 31):
        assert resolve_pspec((heads, 64), ("tp", None), mesh) == P(None, None)
    # even-but-non-divisible also replicates
    assert resolve_pspec((24, 64), ("tp", None), mesh) == P(None, None)
    # divisible shards
    assert resolve_pspec((32, 64), ("tp", None), mesh) == P("model", None)


def test_resolve_pspec_multipod_greedy_batch_factoring():
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    # divisible by pod*data -> joint sharding
    assert resolve_pspec((256, 8), ("batch", None), mesh) == P(("pod", "data"), None)
    # divisible by pod only -> greedy keeps the prefix
    assert resolve_pspec((2, 8), ("batch", None), mesh) in (P("pod", None), P(("pod",), None))
    assert resolve_pspec((6, 8), ("batch", None), mesh) in (P("pod", None), P(("pod",), None))
    # not even divisible by pod -> replicate
    assert resolve_pspec((3, 8), ("batch", None), mesh) == P(None, None)
    # odd batch of 1 (long-context decode) -> replicate
    assert resolve_pspec((1, 8), ("batch", None), mesh) == P(None, None)


def test_resolve_pspec_no_axis_reuse():
    mesh = _mesh((2, 2), ("data", "model"))
    # experts claims the model axis first; a later tp dim must not reuse it
    spec = resolve_pspec((4, 64, 96), ("experts", "fsdp", "tp"), mesh)
    assert spec == P("model", "data", None)


def test_resolve_pspec_missing_axes_replicate():
    mesh = _mesh((4,), ("pipe",))
    assert resolve_pspec((8, 8), ("batch", "tp"), mesh) == P(None, None)


def test_resolve_pspec_rank_mismatch_raises():
    mesh = _mesh((2, 2), ("data", "model"))
    with pytest.raises(ValueError):
        resolve_pspec((4, 4), ("batch",), mesh)


# ----------------------------------------------------------------------
# tree mappers + mesh context
# ----------------------------------------------------------------------


def test_param_pspecs_moe_expert_dim_on_model_axis():
    from repro.configs import get_arch
    from repro.models.registry import build_model

    cfg = get_arch("dbrx-132b").smoke()
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    mesh = _mesh((2, 2), ("data", "model"))
    specs = param_pspecs(shapes, mesh)
    moe_spec = specs["segments"][0]["moe"]["w_gate"]
    # stacked (L, E, d, f): expert dim sharded on the model axis
    assert moe_spec[1] == "model"


def test_batch_pspecs_structure_and_batch_dim():
    mesh = _mesh((2, 2), ("data", "model"))
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "frames": jax.ShapeDtypeStruct((4, 24, 64), jnp.float32),
        "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32),
    }
    specs = batch_pspecs(batch, mesh)
    assert specs["tokens"] == P("data", None)
    assert specs["frames"] == P("data", None, None)
    assert specs["odd"] == P(None, None)  # 3 doesn't divide the data axis


def test_cache_pspecs_kv_heads_on_model_axis():
    mesh = _mesh((2, 2), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 32, 2, 16), jnp.float32)}
    assert cache_pspecs(cache, mesh)["k"] == P(None, "data", None, "model", None)


def test_use_mesh_nesting_and_constrain_noop():
    assert active_mesh() is None
    x = jnp.ones((4, 8))
    assert constrain(x, ("batch", None)) is x  # no mesh -> identity
    m1 = jax.make_mesh((1,), ("data",))
    with use_mesh(m1) as m:
        assert active_mesh() is m1 and m is m1
        with use_mesh(m1):
            assert active_mesh() is m1
        assert active_mesh() is m1
    assert active_mesh() is None


def test_to_named_wraps_specs_and_passes_none_through():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": P("data", None), "b": None, "c": {"d": P()}}
    out = to_named(tree, mesh)
    assert isinstance(out["a"], NamedSharding) and out["a"].spec == P("data", None)
    assert out["b"] is None
    assert isinstance(out["c"]["d"], NamedSharding)
    assert isinstance(to_named(P(), mesh), NamedSharding)  # bare spec


# ----------------------------------------------------------------------
# int8 error-feedback compression
# ----------------------------------------------------------------------


def test_ef_compress_deterministic():
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)}
    d1, e1 = ef_compress_grads(g, None)
    d2, e2 = ef_compress_grads(g, None)
    np.testing.assert_array_equal(np.asarray(d1["w"]), np.asarray(d2["w"]))
    np.testing.assert_array_equal(np.asarray(e1["w"]), np.asarray(e2["w"]))


def test_ef_compress_int8_levels_and_scale():
    g = jnp.asarray(np.linspace(-2.0, 2.0, 1000), jnp.float32)
    q, scale = int8_quantize(g)
    assert q.dtype == jnp.int8
    assert float(scale) == pytest.approx(2.0 / 127.0)
    levels = np.unique(np.asarray(q))
    assert levels.min() >= -127 and levels.max() <= 127
    # dequantization error bounded by half a quantization step
    err = np.abs(np.asarray(int8_dequantize(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_ef_compress_zero_grads_exact():
    g = {"w": jnp.zeros((8, 8), jnp.float32)}
    deq, err = ef_compress_grads(g, None)
    np.testing.assert_array_equal(np.asarray(deq["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(err["w"]), 0.0)


def test_ef_compress_residual_carries_between_steps():
    g = {"w": jnp.full((4,), 0.501 * (1.0 / 127.0), jnp.float32)}
    deq1, err1 = ef_compress_grads(g, None)
    # residual is what quantization dropped
    np.testing.assert_allclose(
        np.asarray(err1["w"]),
        np.asarray(g["w"]) - np.asarray(deq1["w"]),
        rtol=1e-6,
    )
    # feeding the residual back changes the next quantization target
    deq2, _ = ef_compress_grads(g, err1)
    total = np.asarray(deq1["w"]) + np.asarray(deq2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]), atol=float(1 / 127.0))


def test_ef_compress_jit_compatible():
    g = {"w": jnp.ones((8,), jnp.float32)}
    e = {"w": jnp.zeros((8,), jnp.float32)}
    deq, err = jax.jit(ef_compress_grads)(g, e)
    np.testing.assert_allclose(np.asarray(deq["w"]), 1.0, rtol=1e-6)


# ----------------------------------------------------------------------
# bucketed, overlapped error-feedback (ISSUE 10)
# ----------------------------------------------------------------------


def _grad_tree(seed: int = 0) -> dict:
    """A small nested tree with uneven leaf sizes, so mid-range bucket caps
    produce a genuinely mixed ledger (multi-leaf and singleton buckets)."""
    rng = np.random.default_rng(seed)
    arr = lambda *shape: jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return {
        "emb": arr(64, 16),
        "blocks": [{"w": arr(16, 16), "b": arr(16)} for _ in range(3)],
        "head": arr(16, 7),
    }


def test_bucket_leaves_partition_invariants():
    leaves = jax.tree.leaves(_grad_tree())
    for bucket_bytes in (1, 64, 300, 1 << 20):
        ledger = bucket_leaves(leaves, bucket_bytes)
        covered = [i for b in ledger for i in b.leaf_indices]
        # exact partition, walked in reverse tree order (the order backward
        # makes gradients available, hence the order buckets can launch)
        assert covered == list(reversed(range(len(leaves))))
        for b in ledger:
            assert b.nbytes == sum(int(leaves[i].size) + 4 for i in b.leaf_indices)
            # a bucket only exceeds the cap when a single leaf does
            assert b.nbytes <= bucket_bytes or len(b.leaf_indices) == 1
    # a cap larger than the whole tree yields one launch
    assert len(bucket_leaves(leaves, 1 << 30)) == 1
    # every-leaf-alone at the minimum cap
    assert all(len(b.leaf_indices) == 1 for b in bucket_leaves(leaves, 1))
    with pytest.raises(ValueError):
        bucket_leaves(leaves, 0)


def test_bucketed_ef_bit_identical_to_sync_across_bucket_sizes():
    """Partitioning the leaves into launch buckets changes the launch
    schedule, not one arithmetic op: dequantized grads AND carried
    residuals match the synchronous path bit for bit, for any cap."""
    grads = _grad_tree(1)
    err = jax.tree.map(lambda g: 1e-3 * g, _grad_tree(2))
    deq_s, err_s = ef_compress_grads(grads, err)
    for bucket_bytes in (1, 64, 300, 1500, DEFAULT_BUCKET_BYTES):
        deq_b, err_b, ledger = ef_compress_grads_bucketed(
            grads, err, bucket_bytes=bucket_bytes
        )
        assert jax.tree_util.tree_structure(deq_b) == jax.tree_util.tree_structure(grads)
        for a, b in zip(jax.tree.leaves(deq_b), jax.tree.leaves(deq_s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(err_b), jax.tree.leaves(err_s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ledger == bucket_leaves(jax.tree.leaves(grads), bucket_bytes)
    # first-step (err=None) path agrees too
    d0_s, e0_s = ef_compress_grads(grads, None)
    d0_b, e0_b, _ = ef_compress_grads_bucketed(grads, None, bucket_bytes=300)
    for a, b in zip(jax.tree.leaves(d0_b), jax.tree.leaves(d0_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(e0_b), jax.tree.leaves(e0_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucketed_ef_invariants_hold_per_bucket():
    """The EF invariants survive bucketing: per-leaf conservation
    (deq + new_err == grads + err), residual bounded by half a
    quantization step, float32 structure stability."""
    grads = _grad_tree(3)
    err = jax.tree.map(lambda g: 1e-2 * g, _grad_tree(4))
    deq, new_err, ledger = ef_compress_grads_bucketed(grads, err, bucket_bytes=300)
    assert len(ledger) > 1  # the cap actually split the tree
    g_l, e_l = jax.tree.leaves(grads), jax.tree.leaves(err)
    d_l, n_l = jax.tree.leaves(deq), jax.tree.leaves(new_err)
    for g, e, d, n in zip(g_l, e_l, d_l, n_l):
        assert d.dtype == jnp.float32 and n.dtype == jnp.float32
        target = np.asarray(g, np.float32) + np.asarray(e, np.float32)
        np.testing.assert_allclose(
            np.asarray(d) + np.asarray(n), target, rtol=1e-6, atol=1e-7
        )
        scale = np.abs(target).max() / 127.0
        assert np.abs(np.asarray(n)).max() <= scale / 2 + 1e-7


def test_bucketed_ef_per_bucket_transport_applies():
    """The optional per-bucket ``all_reduce`` callable sees each bucket's
    dequantized leaves and its result lands in the output tree — a 2x
    stand-in transport checks wiring without needing devices."""
    grads = _grad_tree(5)
    calls = []

    def fake_reduce(bucket):
        calls.append(len(bucket))
        return [2.0 * x for x in bucket]

    deq, _, ledger = ef_compress_grads_bucketed(
        grads, None, bucket_bytes=300, all_reduce=fake_reduce
    )
    assert calls == [len(b.leaf_indices) for b in ledger]
    ref, _ = ef_compress_grads(grads, None)
    for a, b in zip(jax.tree.leaves(deq), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), 2.0 * np.asarray(b))


def test_train_step_overlap_grads_bit_identical_to_sync():
    """TrainConfig(overlap_grads=True) reproduces the synchronous
    compressed step exactly — losses and updated params bit for bit over
    several steps, with a cap small enough to force many buckets."""
    from repro.configs import get_arch
    from repro.models.registry import build_model, materialize_batch
    from repro.train.step import (
        TrainConfig,
        init_train_state,
        make_optimizer,
        make_train_step,
    )

    cfg = get_arch("qwen3-0.6b").smoke()
    api = build_model(cfg)
    batch = materialize_batch(cfg, 4, 32)
    runs = {}
    for overlap in (False, True):
        tc = TrainConfig(
            compress_grads=True,
            overlap_grads=overlap,
            bucket_bytes=32 << 10,
            total_steps=8,
            warmup=1,
        )
        opt = make_optimizer(tc)
        state = init_train_state(api, opt, jax.random.PRNGKey(0), compress_grads=True)
        step = jax.jit(make_train_step(api, opt, tc))
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        runs[overlap] = (losses, state)
    assert runs[True][0] == runs[False][0]
    for key in ("params", "err"):
        for a, b in zip(
            jax.tree.leaves(runs[True][1][key]), jax.tree.leaves(runs[False][1][key])
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# pipeline accounting
# ----------------------------------------------------------------------


def test_pipeline_bubble_fraction():
    assert pipeline_bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert pipeline_bubble_fraction(1, 8) == 0.0
