"""Fast single-device unit tests for the distribution substrate — the cheap
complement to test_dist.py's multi-device subprocess integration suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.collectives import ef_compress_grads, int8_dequantize, int8_quantize
from repro.dist.pipeline import pipeline_bubble_fraction
from repro.dist.sharding import (
    active_mesh,
    batch_pspecs,
    cache_pspecs,
    constrain,
    param_pspecs,
    resolve_pspec,
    to_named,
    use_mesh,
)


# ----------------------------------------------------------------------
# resolve_pspec edge cases
# ----------------------------------------------------------------------


def _mesh(sizes, names):
    # two-arg AbstractMesh; conftest normalizes the signature on jax 0.4.x
    from jax.sharding import AbstractMesh

    return AbstractMesh(sizes, names)


def test_resolve_pspec_odd_head_counts_replicate():
    mesh = _mesh((16, 16), ("data", "model"))
    # hymba-style odd head counts on a 16-way model axis
    for heads in (25, 7, 17, 31):
        assert resolve_pspec((heads, 64), ("tp", None), mesh) == P(None, None)
    # even-but-non-divisible also replicates
    assert resolve_pspec((24, 64), ("tp", None), mesh) == P(None, None)
    # divisible shards
    assert resolve_pspec((32, 64), ("tp", None), mesh) == P("model", None)


def test_resolve_pspec_multipod_greedy_batch_factoring():
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    # divisible by pod*data -> joint sharding
    assert resolve_pspec((256, 8), ("batch", None), mesh) == P(("pod", "data"), None)
    # divisible by pod only -> greedy keeps the prefix
    assert resolve_pspec((2, 8), ("batch", None), mesh) in (P("pod", None), P(("pod",), None))
    assert resolve_pspec((6, 8), ("batch", None), mesh) in (P("pod", None), P(("pod",), None))
    # not even divisible by pod -> replicate
    assert resolve_pspec((3, 8), ("batch", None), mesh) == P(None, None)
    # odd batch of 1 (long-context decode) -> replicate
    assert resolve_pspec((1, 8), ("batch", None), mesh) == P(None, None)


def test_resolve_pspec_no_axis_reuse():
    mesh = _mesh((2, 2), ("data", "model"))
    # experts claims the model axis first; a later tp dim must not reuse it
    spec = resolve_pspec((4, 64, 96), ("experts", "fsdp", "tp"), mesh)
    assert spec == P("model", "data", None)


def test_resolve_pspec_missing_axes_replicate():
    mesh = _mesh((4,), ("pipe",))
    assert resolve_pspec((8, 8), ("batch", "tp"), mesh) == P(None, None)


def test_resolve_pspec_rank_mismatch_raises():
    mesh = _mesh((2, 2), ("data", "model"))
    with pytest.raises(ValueError):
        resolve_pspec((4, 4), ("batch",), mesh)


# ----------------------------------------------------------------------
# tree mappers + mesh context
# ----------------------------------------------------------------------


def test_param_pspecs_moe_expert_dim_on_model_axis():
    from repro.configs import get_arch
    from repro.models.registry import build_model

    cfg = get_arch("dbrx-132b").smoke()
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    mesh = _mesh((2, 2), ("data", "model"))
    specs = param_pspecs(shapes, mesh)
    moe_spec = specs["segments"][0]["moe"]["w_gate"]
    # stacked (L, E, d, f): expert dim sharded on the model axis
    assert moe_spec[1] == "model"


def test_batch_pspecs_structure_and_batch_dim():
    mesh = _mesh((2, 2), ("data", "model"))
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "frames": jax.ShapeDtypeStruct((4, 24, 64), jnp.float32),
        "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32),
    }
    specs = batch_pspecs(batch, mesh)
    assert specs["tokens"] == P("data", None)
    assert specs["frames"] == P("data", None, None)
    assert specs["odd"] == P(None, None)  # 3 doesn't divide the data axis


def test_cache_pspecs_kv_heads_on_model_axis():
    mesh = _mesh((2, 2), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 32, 2, 16), jnp.float32)}
    assert cache_pspecs(cache, mesh)["k"] == P(None, "data", None, "model", None)


def test_use_mesh_nesting_and_constrain_noop():
    assert active_mesh() is None
    x = jnp.ones((4, 8))
    assert constrain(x, ("batch", None)) is x  # no mesh -> identity
    m1 = jax.make_mesh((1,), ("data",))
    with use_mesh(m1) as m:
        assert active_mesh() is m1 and m is m1
        with use_mesh(m1):
            assert active_mesh() is m1
        assert active_mesh() is m1
    assert active_mesh() is None


def test_to_named_wraps_specs_and_passes_none_through():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": P("data", None), "b": None, "c": {"d": P()}}
    out = to_named(tree, mesh)
    assert isinstance(out["a"], NamedSharding) and out["a"].spec == P("data", None)
    assert out["b"] is None
    assert isinstance(out["c"]["d"], NamedSharding)
    assert isinstance(to_named(P(), mesh), NamedSharding)  # bare spec


# ----------------------------------------------------------------------
# int8 error-feedback compression
# ----------------------------------------------------------------------


def test_ef_compress_deterministic():
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)}
    d1, e1 = ef_compress_grads(g, None)
    d2, e2 = ef_compress_grads(g, None)
    np.testing.assert_array_equal(np.asarray(d1["w"]), np.asarray(d2["w"]))
    np.testing.assert_array_equal(np.asarray(e1["w"]), np.asarray(e2["w"]))


def test_ef_compress_int8_levels_and_scale():
    g = jnp.asarray(np.linspace(-2.0, 2.0, 1000), jnp.float32)
    q, scale = int8_quantize(g)
    assert q.dtype == jnp.int8
    assert float(scale) == pytest.approx(2.0 / 127.0)
    levels = np.unique(np.asarray(q))
    assert levels.min() >= -127 and levels.max() <= 127
    # dequantization error bounded by half a quantization step
    err = np.abs(np.asarray(int8_dequantize(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_ef_compress_zero_grads_exact():
    g = {"w": jnp.zeros((8, 8), jnp.float32)}
    deq, err = ef_compress_grads(g, None)
    np.testing.assert_array_equal(np.asarray(deq["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(err["w"]), 0.0)


def test_ef_compress_residual_carries_between_steps():
    g = {"w": jnp.full((4,), 0.501 * (1.0 / 127.0), jnp.float32)}
    deq1, err1 = ef_compress_grads(g, None)
    # residual is what quantization dropped
    np.testing.assert_allclose(
        np.asarray(err1["w"]),
        np.asarray(g["w"]) - np.asarray(deq1["w"]),
        rtol=1e-6,
    )
    # feeding the residual back changes the next quantization target
    deq2, _ = ef_compress_grads(g, err1)
    total = np.asarray(deq1["w"]) + np.asarray(deq2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]), atol=float(1 / 127.0))


def test_ef_compress_jit_compatible():
    g = {"w": jnp.ones((8,), jnp.float32)}
    e = {"w": jnp.zeros((8,), jnp.float32)}
    deq, err = jax.jit(ef_compress_grads)(g, e)
    np.testing.assert_allclose(np.asarray(deq["w"]), 1.0, rtol=1e-6)


# ----------------------------------------------------------------------
# pipeline accounting
# ----------------------------------------------------------------------


def test_pipeline_bubble_fraction():
    assert pipeline_bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert pipeline_bubble_fraction(1, 8) == 0.0
