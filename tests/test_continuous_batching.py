"""Continuous-batching engine: in-flight admission, lock-step decode,
equivalence with isolated serving."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve.engine import ContinuousBatchingEngine, Request, ServeEngine


def _reqs(cfg, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        L = int(rng.integers(8, 20))
        out.append(
            Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
                    max_new=max_new)
        )
    return out


def test_serves_more_requests_than_slots():
    cfg = get_arch("qwen3-0.6b").smoke()
    eng = ContinuousBatchingEngine(cfg, slots=2, max_len=48)
    for r in _reqs(cfg, 5):
        eng.submit(r)
    results = eng.run_to_completion()
    assert sorted(r.rid for r in results) == [0, 1, 2, 3, 4]
    for r in results:
        assert len(r.tokens) == 4


def test_inflight_admission_mid_decode():
    """A request submitted while others are decoding gets admitted at a step
    boundary without disturbing running slots."""
    cfg = get_arch("qwen3-0.6b").smoke()
    eng = ContinuousBatchingEngine(cfg, slots=2, max_len=48)
    first = _reqs(cfg, 2, seed=1, max_new=6)
    for r in first:
        eng.submit(r)
    eng.step()  # admit + 1 decode step
    late = _reqs(cfg, 1, seed=2, max_new=3)[0]
    late.rid = 99
    eng.submit(late)
    results = eng.run_to_completion()
    assert {r.rid for r in results} == {0, 1, 99}


def test_matches_isolated_greedy_decode():
    """Greedy outputs from the continuous engine match the simple batch
    engine serving the same request alone (same params/seed)."""
    cfg = get_arch("qwen3-0.6b").smoke()
    req = _reqs(cfg, 1, seed=3, max_new=5)[0]

    cont = ContinuousBatchingEngine(cfg, slots=2, max_len=48, seed=0)
    cont.submit(Request(rid=0, prompt=req.prompt, max_new=5))
    out_cont = cont.run_to_completion()[0].tokens

    iso = ServeEngine(cfg, params=cont.params, max_batch=1)
    iso.submit(Request(rid=0, prompt=req.prompt, max_new=5))
    out_iso = iso.step_batch()[0].tokens
    assert out_cont == out_iso


def test_rejects_unsupported_family():
    cfg = get_arch("mamba2-370m").smoke()
    with pytest.raises(AssertionError):
        ContinuousBatchingEngine(cfg)
