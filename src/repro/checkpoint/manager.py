"""Fault-tolerant checkpointing.

Design (single-process here, multi-host-shaped API):
  * every leaf of the state pytree is saved as raw numpy inside one .npz per
    save, plus a JSON manifest recording the tree structure, dtypes and step;
  * saves are atomic (write to ``<dir>/tmp.<step>`` then ``os.replace``), so
    a preemption mid-save never corrupts the latest checkpoint;
  * ``restore_latest`` finds the newest complete checkpoint; resuming on a
    different device count / mesh works because checkpoints store full
    (unsharded) arrays and the caller re-shards on load (elastic scaling);
  * retention: keep the last K checkpoints;
  * optional async save on a background thread (overlaps I/O with compute).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf_{i:05d}"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        if self.async_save:
            host_state = jax.tree.map(np.asarray, state)  # pull off device now
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_state, extra), daemon=True
            )
            self._thread.start()
        else:
            self._save_sync(step, state, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, state: Any, extra: Optional[dict]):
        leaves, treedef = _flatten(state)
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {_key(i): np.asarray(l) for i, l in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "time": time.time(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``. When ``shardings`` is given
        every leaf is device_put with its sharding — this is how a checkpoint
        taken on one mesh is resumed on another (elastic restart)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "checkpoint/state mismatch"
        new_leaves = []
        flat_sh = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
        for i, (ref, sh) in enumerate(zip(leaves, flat_sh)):
            arr = data[_key(i)]
            assert arr.shape == tuple(ref.shape), f"leaf {i}: {arr.shape} vs {ref.shape}"
            arr = arr.astype(ref.dtype)
            new_leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
        state = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return state, manifest["extra"]

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, like, shardings)
        return step, state, extra

    # ------------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)
