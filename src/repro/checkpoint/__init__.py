"""Fault-tolerant checkpointing: atomic saves, elastic restore."""
