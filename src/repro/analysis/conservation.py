"""Conservation checks (SP1xx): the analytical call stream must account
for *exactly* the work the lowered computation performs.

Three statically provable layers, per registry arch x request shape:

* every decomposed :class:`~repro.core.decomposer.TaskArray` must conserve
  its family's closed-form demand — GEMM tile MXU sums telescope to
  ``2*M*N*K``, fused-MoE routing counts sum to ``M*topk`` so MXU is
  ``2*M*topk*3*H*N``, causal attention tiling stays inside its provable
  over-count bounds, elementwise families stream exactly their operands;
* the LM-head group of ``core.e2e.model_calls`` must price every position
  (``B*qlen`` prefill tokens — the PR 2 undercount, pinned forever) and
  its all-gather payload must match the head GEMM's output;
* the MoE EP dispatch/combine ``CommCall("all_to_all")`` payloads must
  equal ``launch.dryrun.count_ep_alltoall_bytes`` — the byte ledger
  derived from the executed model layer — bit-for-bit.

Every check takes an optional ``calls=`` stream so seeded-bug tests can
re-introduce a historical bug and prove the diagnostic fires.
"""
from __future__ import annotations

import glob
import json
import math
import os
from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.diagnostics import Diagnostic
from repro.configs.base import ArchConfig
from repro.core.decomposer import COMPUTE_DTYPE_BYTES, decompose
from repro.core.hardware import REGISTRY, TPUSpec
from repro.predict.api import CommCall, KernelCall, flatten_calls

#: decomposition tile choices depend on the device, but the conservation
#: sums are tile-invariant — one representative device is enough
DEFAULT_HW_NAME = "tpu-v5e"

#: relative tolerance for "exact" float comparisons
_RTOL = 1e-9


def _rel_err(actual: float, expected: float) -> float:
    return abs(actual - expected) / max(abs(expected), 1.0)


def _attention_mxu_bounds(X: Dict[str, Any]) -> tuple:
    """(lower, upper) MXU bound of one attention call: the exact causal
    per-row sum, and the sum plus the tile-granularity over-count (each
    row of a ``bq``-row query tile may see at most ``bq - 1`` extra KV
    positions — the tile's ``kv_eff`` is evaluated at its last row)."""
    B, H, G = X["bs"], X["nkv"], X["group"]
    qlen, kvlen, hd = X["qlen"], X["kvlen"], X["hd"]
    causal = X.get("causal", 1)
    if causal:
        offset = kvlen - qlen
        rows_kv = np.clip(offset + np.arange(qlen) + 1, 0, kvlen)
    else:
        rows_kv = np.full(qlen, float(kvlen))
    exact = 4.0 * hd * G * float(rows_kv.sum()) * B * H
    bq = min(256, qlen) if qlen > 1 else 1
    slack = 4.0 * hd * G * qlen * (bq - 1) * B * H if causal else 0.0
    return exact, exact + slack


def check_task_conservation(
    cfg: ArchConfig,
    *,
    B: int,
    lin: int,
    lout: int,
    tp: int,
    hw: Optional[TPUSpec] = None,
    calls: Optional[list] = None,
) -> List[Diagnostic]:
    """SP102: decompose every unique kernel call of the request stream and
    check the family's conservation law on the task sums."""
    from repro.core.e2e import request_calls

    hw = hw if hw is not None else REGISTRY[DEFAULT_HW_NAME]
    if calls is None:
        calls = request_calls(cfg, B, lin, lout, tp=tp)
    diags: List[Diagnostic] = []
    seen: set = set()
    for call, _w in flatten_calls(calls):
        if not isinstance(call, KernelCall):
            continue
        key = (call.kind, tuple(sorted(call.X.items())))
        if key in seen:
            continue
        seen.add(key)
        t = decompose(call.kind, call.X, hw)
        mxu = float(t.mxu.sum())
        X = call.X

        def fail(expected: str, actual: float, want: float) -> None:
            diags.append(
                Diagnostic(
                    code="SP102",
                    severity="error",
                    check="conservation",
                    message=(
                        f"{call.kind} task demands break conservation: "
                        f"expected {expected}, got {actual:.6g} (want {want:.6g})"
                    ),
                    arch=cfg.name,
                    where=f"core/decomposer:{call.kind} X={X}",
                    data={"kind": call.kind, "X": X, "actual": actual, "expected": want},
                )
            )

        if call.kind in ("gemm", "scaled_mm"):
            want = 2.0 * X["M"] * X["N"] * X["K"]
            if _rel_err(mxu, want) > _RTOL:
                fail("sum(mxu) == 2*M*N*K", mxu, want)
        elif call.kind == "fused_moe":
            want = 2.0 * X["M"] * X["topk"] * 3.0 * X["H"] * X["N"]
            if _rel_err(mxu, want) > _RTOL:
                fail("sum(mxu) == 2*M*topk*3*H*N", mxu, want)
        elif call.kind == "attention":
            lo, hi = _attention_mxu_bounds(X)
            if not (lo * (1 - _RTOL) <= mxu <= hi * (1 + _RTOL)):
                fail(f"causal MXU within [{lo:.6g}, {hi:.6g}]", mxu, lo)
        elif call.kind in ("rmsnorm", "silu_mul"):
            if mxu != 0.0:
                fail("sum(mxu) == 0 for elementwise families", mxu, 0.0)
            streams = 2.0 if call.kind == "rmsnorm" else 3.0
            b = X.get("dtype_bytes", 2)
            want = streams * X["seq"] * X["dim"] * b
            hbm = float(t.hbm.sum())
            if _rel_err(hbm, want) > _RTOL:
                fail("sum(hbm) == streams*seq*dim*bytes", hbm, want)
    return diags


def check_head_accounting(
    cfg: ArchConfig,
    *,
    B: int,
    qlen: int,
    tp: int,
    calls: Optional[list] = None,
) -> List[Diagnostic]:
    """SP103/SP104: the LM-head group must price every position.

    Prefill runs the head GEMM over ``B*qlen`` tokens (a decode step over
    ``B``); its TP all-gather moves exactly the f32 logit shard
    ``tokens * padded_vocab/tp * 4`` bytes. This is the statically pinned
    form of the PR 2 LM-head undercount bug."""
    from repro.core.e2e import model_calls

    if calls is None:
        calls = model_calls(cfg, B, qlen, qlen, tp)
    diags: List[Diagnostic] = []
    head_seq = None
    for item in calls:
        if not isinstance(item, (KernelCall, CommCall)) and item[0] == "head":
            head_seq = list(item[2])
    if head_seq is None:
        return [
            Diagnostic(
                code="SP103",
                severity="error",
                check="conservation",
                message="model_calls emits no ('head', ...) group — the LM head is unpriced",
                arch=cfg.name,
                where="core/e2e:model_calls",
            )
        ]
    want_tokens = B * qlen if qlen > 1 else B
    want_n = cfg.padded_vocab // tp
    gemms = [c for c in head_seq if isinstance(c, KernelCall) and c.kind == "gemm"]
    gathers = [c for c in head_seq if isinstance(c, CommCall) and c.op == "all_gather"]
    if not gemms:
        diags.append(
            Diagnostic(
                code="SP103",
                severity="error",
                check="conservation",
                message="head group has no GEMM call",
                arch=cfg.name,
                where="core/e2e:model_calls head",
            )
        )
        return diags
    g = gemms[0]
    if g.X["M"] != want_tokens or g.X["N"] != want_n or g.X["K"] != cfg.d_model:
        diags.append(
            Diagnostic(
                code="SP103",
                severity="error",
                check="conservation",
                message=(
                    f"LM-head GEMM prices (M={g.X['M']}, N={g.X['N']}, K={g.X['K']}) "
                    f"but the model computes logits for (M={want_tokens}, "
                    f"N={want_n}, K={cfg.d_model}) at B={B}, qlen={qlen}, tp={tp} "
                    f"— token undercount (the PR 2 bug class)"
                ),
                arch=cfg.name,
                where="core/e2e:model_calls head",
                data={"actual": dict(g.X), "expected": {"M": want_tokens, "N": want_n, "K": cfg.d_model}},
            )
        )
    if tp > 1:
        want_bytes = want_tokens * want_n * 4.0
        if not gathers:
            diags.append(
                Diagnostic(
                    code="SP104",
                    severity="error",
                    check="conservation",
                    message=f"head group emits no all_gather at tp={tp} — logit shards never rejoin",
                    arch=cfg.name,
                    where="core/e2e:model_calls head",
                )
            )
        elif _rel_err(gathers[0].nbytes, want_bytes) > _RTOL:
            diags.append(
                Diagnostic(
                    code="SP104",
                    severity="error",
                    check="conservation",
                    message=(
                        f"head all_gather moves {gathers[0].nbytes:.6g} bytes but the "
                        f"f32 logit shard is {want_bytes:.6g} (tokens*padded_vocab/tp*4)"
                    ),
                    arch=cfg.name,
                    where="core/e2e:model_calls head",
                    data={"actual": gathers[0].nbytes, "expected": want_bytes},
                )
            )
    return diags


def check_ep_alltoall(
    cfg: ArchConfig,
    *,
    B: int,
    qlen: int,
    tp: int,
    calls: Optional[list] = None,
) -> List[Diagnostic]:
    """SP101: the workload generator's EP dispatch/combine all-to-all
    payloads must equal ``launch.dryrun.count_ep_alltoall_bytes`` — the
    byte ledger counted through the executed model layer's own dispatch
    geometry — exactly. Non-MoE archs (or tp==1) audit vacuously."""
    from repro.core.e2e import layer_calls
    from repro.launch.dryrun import count_ep_alltoall_bytes

    if not cfg.n_experts or tp <= 1:
        return []
    if calls is None:
        calls = layer_calls(cfg, B, qlen, qlen, tp)
    ledger = count_ep_alltoall_bytes(cfg, B, qlen)
    a2a = [
        c for c, _w in flatten_calls(calls)
        if isinstance(c, CommCall) and c.op == "all_to_all"
    ]
    diags: List[Diagnostic] = []
    if len(a2a) != 2:
        diags.append(
            Diagnostic(
                code="SP101",
                severity="error",
                check="conservation",
                message=(
                    f"MoE layer at tp={tp} emits {len(a2a)} all_to_all call(s); "
                    f"EP dispatch+combine require exactly 2"
                ),
                arch=cfg.name,
                where="core/e2e:layer_calls moe",
            )
        )
    for label, call in zip(("dispatch", "combine"), a2a):
        want = ledger[f"{label}_bytes"]
        if call.nbytes != want:
            diags.append(
                Diagnostic(
                    code="SP101",
                    severity="error",
                    check="conservation",
                    message=(
                        f"EP {label} all_to_all prices {call.nbytes:.6g} bytes; the "
                        f"dry-run ledger counts {want:.6g} from the executed model "
                        f"layer (B={B}, qlen={qlen}, tp={tp}) — byte drift"
                    ),
                    arch=cfg.name,
                    where="core/e2e:layer_calls moe",
                    data={"actual": call.nbytes, "expected": want, "hop": label},
                )
            )
    return diags


def check_dryrun_artifacts(
    cfg: ArchConfig, *, root: str = os.path.join("results", "dryrun")
) -> List[Diagnostic]:
    """SP105/SP101: cross-check cached dry-run HLO cost ledgers (written by
    ``launch.dryrun.analyze``) against the analytical EP byte counts. When
    no artifacts are cached — the normal CI state, since full lowering is
    tier-2 — the check reports an *info* skip instead of lowering anything
    (the auditor never compiles)."""
    paths = sorted(glob.glob(os.path.join(root, f"*{cfg.name}*.json")))
    if not paths:
        return [
            Diagnostic(
                code="SP105",
                severity="info",
                check="conservation",
                message=(
                    f"no cached dry-run ledger under {root!r} — HLO cross-check "
                    f"skipped (run launch.dryrun to materialize one)"
                ),
                arch=cfg.name,
                where=root,
            )
        ]
    diags: List[Diagnostic] = []
    for path in paths:
        with open(path, encoding="utf-8") as f:
            ledger = json.load(f)
        ep = ledger.get("ep_alltoall")
        if not ep or not cfg.n_experts:
            continue
        T = int(ep.get("T", 0))
        if not T:
            continue
        from repro.core.decomposer import ep_alltoall_bytes

        want = ep_alltoall_bytes(
            {
                "T": T,
                "d": cfg.d_model,
                "E": cfg.n_experts,
                "topk": cfg.top_k,
                "capacity_factor": max(cfg.capacity_factor, 2.0),
                "moe_group": cfg.moe_group,
                "dtype_bytes": COMPUTE_DTYPE_BYTES[cfg.compute_dtype],
            }
        )
        got = float(ep.get("dispatch_bytes", math.nan))
        if got != want:
            diags.append(
                Diagnostic(
                    code="SP101",
                    severity="error",
                    check="conservation",
                    message=(
                        f"cached dry-run ledger {os.path.basename(path)} counts "
                        f"{got:.6g} EP dispatch bytes; the decomposer prices {want:.6g}"
                    ),
                    arch=cfg.name,
                    where=path,
                    data={"actual": got, "expected": want},
                )
            )
    return diags


def check_conservation(
    cfg: ArchConfig,
    *,
    B: int = 2,
    lin: int = 512,
    lout: int = 64,
    tp: int = 16,
    hw: Optional[TPUSpec] = None,
) -> List[Diagnostic]:
    """All conservation checks for one arch at one request shape: task
    sums over the full request stream, head accounting at prefill and
    decode, EP byte exactness at both phases, and the (artifact-gated)
    dry-run cross-check."""
    diags = check_task_conservation(cfg, B=B, lin=lin, lout=lout, tp=tp, hw=hw)
    for qlen in (lin, 1):
        diags += check_head_accounting(cfg, B=B, qlen=qlen, tp=tp)
        diags += check_ep_alltoall(cfg, B=B, qlen=qlen, tp=tp)
    diags += check_dryrun_artifacts(cfg)
    return diags
