"""Diagnostic model of the static auditor: stable codes, severities,
anchors, JSON rendering.

Every check in ``repro.analysis`` returns a list of :class:`Diagnostic`.
Codes are *stable identifiers* — tests, CI gates and suppression lists key
on them, so a code is never renumbered or reused once shipped:

====== ========== ==============================================================
code   severity   meaning
====== ========== ==============================================================
SP101  error      EP all-to-all payload drifts from the dry-run byte ledger
SP102  error      decomposer task demands break the family's conservation law
SP103  error      LM-head GEMM token accounting is wrong (the PR 2 bug class)
SP104  error      LM-head all_gather payload disagrees with the head GEMM
SP105  info       dry-run artifact cross-check skipped (no cached ledgers)
SP201  error      kernel block choice overflows a registry device's VMEM
SP202  error      non-divisible tiling (the kernel would fail its assert)
SP203  error      degenerate Pallas grid (a zero/negative grid dimension)
SP204  error      compute/param dtype outside the priced dtype vocabulary
SP301  error      param/cache leaf name has no audited sharding rule
SP302  error      a resolved PartitionSpec consumes one mesh axis twice
SP303  error      a sharded dim is not divisible by its mesh axes
SP304  warning    large parameter left fully replicated on the mesh
SP401  error      workload emits a comm op the comm regressor cannot price
SP402  error      workload emits a kernel family no backend can price
====== ========== ==============================================================
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")

#: rank for sorting / exit-code policy (lower = more severe)
_SEV_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of the static auditor.

    ``code`` is the stable identifier (table above); ``check`` names the
    check family (``conservation`` / ``kernel-resource`` / ``sharding`` /
    ``coverage``); ``where`` anchors the finding (a ``module:function`` or
    a call/leaf description); ``arch`` is the registry architecture under
    audit (None for arch-independent findings); ``data`` carries the
    machine-readable expected/actual values."""

    code: str
    severity: str
    check: str
    message: str
    arch: Optional[str] = None
    where: Optional[str] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v not in (None, {})}

    def render(self) -> str:
        loc = " @ ".join(x for x in (self.arch, self.where) if x)
        head = f"{self.code} [{self.severity}] {self.check}"
        return f"{head}: {self.message}" + (f"  ({loc})" if loc else "")


def sort_diagnostics(diags: List[Diagnostic]) -> List[Diagnostic]:
    """Severity-major ordering (errors first), then stable by code/arch."""
    return sorted(
        diags, key=lambda d: (_SEV_RANK[d.severity], d.code, d.arch or "", d.where or "")
    )


def worst_severity(diags: List[Diagnostic]) -> Optional[str]:
    ranks = [_SEV_RANK[d.severity] for d in diags]
    return SEVERITIES[min(ranks)] if ranks else None


def render_report(diags: List[Diagnostic]) -> str:
    """Human-readable report: one line per finding plus a severity tally."""
    ordered = sort_diagnostics(diags)
    lines = [d.render() for d in ordered]
    tally = {s: sum(1 for d in diags if d.severity == s) for s in SEVERITIES}
    lines.append(
        f"-- {len(diags)} finding(s): "
        + ", ".join(f"{n} {s}" for s, n in tally.items())
    )
    return "\n".join(lines)


def json_report(diags: List[Diagnostic]) -> str:
    return json.dumps([d.to_json() for d in sort_diagnostics(diags)], indent=2)


class AuditError(RuntimeError):
    """Raised by pre-flight ``audit=`` hooks (``FleetRouter``,
    ``ContinuousBatchingEngine``) when the auditor finds error-severity
    diagnostics at construction time — the diagnostic list rides on
    ``.diagnostics`` so callers can render or log it."""

    def __init__(self, diagnostics: List[Diagnostic]) -> None:
        self.diagnostics = sort_diagnostics(diagnostics)
        errors = [d for d in self.diagnostics if d.severity == "error"]
        super().__init__(
            f"pre-flight audit failed with {len(errors)} error(s):\n"
            + "\n".join(d.render() for d in self.diagnostics)
        )
