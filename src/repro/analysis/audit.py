"""Repo-wide audit orchestration: run every check family over registry
architectures and aggregate the diagnostics.

This is what ``python -m repro.analysis`` and the CI auditor job drive.
Everything here is static — the auditor never compiles, never allocates a
parameter, never touches a device (parameter trees come from
``jax.eval_shape``; meshes are shape-only stand-ins)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.analysis.conservation import check_conservation
from repro.analysis.coverage import check_coverage
from repro.analysis.diagnostics import Diagnostic
from repro.analysis.kernels import check_kernel_resources
from repro.analysis.sharding import check_sharding
from repro.configs import get_arch, list_archs

#: check-family name -> callable(cfg, **shape_kw); the CLI's --check filter
CHECK_FAMILIES = ("conservation", "kernel-resource", "sharding", "coverage")


@dataclasses.dataclass(frozen=True)
class AuditShape:
    """The request shape the auditor lowers each arch at. Defaults pick a
    production-like point whose dims divide the default kernel blocks, so
    a clean repo audits clean."""

    B: int = 2
    lin: int = 512
    lout: int = 64
    tp: int = 16
    pp: int = 2


def audit_arch(
    arch: str,
    *,
    shape: Optional[AuditShape] = None,
    checks: Optional[Sequence[str]] = None,
    mesh_sizes: Optional[Dict[str, int]] = None,
) -> List[Diagnostic]:
    """Every selected check family for one registry arch."""
    shape = shape or AuditShape()
    selected = set(checks if checks is not None else CHECK_FAMILIES)
    unknown = selected - set(CHECK_FAMILIES)
    if unknown:
        raise ValueError(f"unknown check family(ies) {sorted(unknown)}; known: {CHECK_FAMILIES}")
    cfg = get_arch(arch)
    diags: List[Diagnostic] = []
    if "conservation" in selected:
        diags += check_conservation(
            cfg, B=shape.B, lin=shape.lin, lout=shape.lout, tp=shape.tp
        )
    if "kernel-resource" in selected:
        diags += check_kernel_resources(cfg, B=shape.B, lin=shape.lin)
    if "sharding" in selected:
        diags += check_sharding(cfg, mesh_sizes)
    if "coverage" in selected:
        diags += check_coverage(
            cfg, B=shape.B, lin=shape.lin, lout=shape.lout, tp=shape.tp, pp=shape.pp
        )
    return diags


def run_audit(
    archs: Optional[Sequence[str]] = None,
    *,
    shape: Optional[AuditShape] = None,
    checks: Optional[Sequence[str]] = None,
    mesh_sizes: Optional[Dict[str, int]] = None,
) -> List[Diagnostic]:
    """The repo-wide audit: every check family x every requested arch
    (default: the whole registry)."""
    out: List[Diagnostic] = []
    for arch in archs if archs is not None else list_archs():
        out += audit_arch(arch, shape=shape, checks=checks, mesh_sizes=mesh_sizes)
    return out
