"""Predictor-coverage lint (SP4xx): every call kind the workload generator
can emit must be priceable *before* a sweep or a serving run starts.

Two modes share the diagnostics:

* **static** (:func:`check_coverage`) — the kernel families and comm ops a
  request stream emits must be inside the decomposer vocabulary
  (``DECOMPOSERS``) and the comm-regressor vocabulary
  (``CommRegressor.OPS``). Registry-wide, device-free, runs in CI.
* **instance** (:func:`audit_predictor`) — a *configured* backend must
  cover the vocabulary: a ``CommRegressor`` fitted before an op joined
  ``OPS`` (the stale-regressor class ``FleetRouter`` used to discover
  mid-sweep, one warning per hardware) and kernel families missing from a
  trained estimator under ``fallback="error"`` become pre-flight errors.
  The ``audit=`` hooks on ``FleetRouter`` and ``ContinuousBatchingEngine``
  call this at construction and raise :class:`~repro.analysis.AuditError`.
"""
from __future__ import annotations

from typing import Any, Iterable, List, Optional, Set

from repro.analysis.diagnostics import Diagnostic
from repro.configs.base import ArchConfig
from repro.core.decomposer import DECOMPOSERS
from repro.predict.api import CommCall, KernelCall, flatten_calls
from repro.predict.comm import CommRegressor


#: kernel families the e2e workload generator emits (``scaled_mm`` only
#: appears in explicitly quantized traces, so predictor-instance audits
#: default to this set; pass ``required_families=DECOMPOSERS`` to demand
#: the full vocabulary)
E2E_FAMILIES = ("gemm", "attention", "rmsnorm", "silu_mul", "fused_moe")


def emitted_vocab(calls: Iterable) -> tuple:
    """``(kernel kinds, comm ops)`` a (possibly nested) call stream emits."""
    kinds: Set[str] = set()
    ops: Set[str] = set()
    for call, _w in flatten_calls(calls):
        if isinstance(call, KernelCall):
            kinds.add(call.kind)
        elif isinstance(call, CommCall):
            ops.add(call.op)
    return kinds, ops


def check_coverage(
    cfg: ArchConfig,
    *,
    B: int = 2,
    lin: int = 512,
    lout: int = 64,
    tp: int = 16,
    pp: int = 2,
    calls: Optional[list] = None,
) -> List[Diagnostic]:
    """SP401/SP402 statically: the request stream of one arch (with TP and
    PP engaged so collective emission paths are exercised) against the
    decomposer and comm vocabularies."""
    from repro.core.e2e import request_calls

    if calls is None:
        calls = request_calls(cfg, B, lin, lout, tp=tp, pp=pp)
    kinds, ops = emitted_vocab(calls)
    diags: List[Diagnostic] = []
    for kind in sorted(kinds - set(DECOMPOSERS)):
        diags.append(
            Diagnostic(
                code="SP402",
                severity="error",
                check="coverage",
                message=(
                    f"workload emits kernel family {kind!r} with no decomposer "
                    f"(known: {sorted(DECOMPOSERS)}) — no backend can price it"
                ),
                arch=cfg.name,
                where="core/e2e:request_calls",
                data={"kind": kind},
            )
        )
    for op in sorted(ops - set(CommRegressor.OPS)):
        diags.append(
            Diagnostic(
                code="SP401",
                severity="error",
                check="coverage",
                message=(
                    f"workload emits comm op {op!r} outside CommRegressor.OPS "
                    f"{list(CommRegressor.OPS)} — no fitted regressor can price it"
                ),
                arch=cfg.name,
                where="core/e2e:request_calls",
                data={"op": op},
            )
        )
    return diags


def audit_comm_regressor(
    comm: Optional[CommRegressor],
    *,
    required_ops: Optional[Iterable[str]] = None,
    hw_name: str = "",
) -> List[Diagnostic]:
    """SP401 against a comm-regressor *instance*: a regressor fitted before
    an op joined ``CommRegressor.OPS`` (or never fitted at all) cannot
    price that op — the stale-regressor class. ``comm=None`` passes
    vacuously (the backend auto-fits the full vocabulary on first use)."""
    if comm is None:
        return []
    required = set(required_ops if required_ops is not None else CommRegressor.OPS)
    missing = sorted(required - set(comm.fitted_ops()))
    if not missing:
        return []
    suffix = f" for {hw_name}" if hw_name else ""
    return [
        Diagnostic(
            code="SP401",
            severity="error",
            check="coverage",
            message=(
                f"CommRegressor{suffix} has no coefficients for comm op(s) "
                f"{missing} (fitted: {comm.fitted_ops() or 'none'}) — refit "
                f"with fit(hw) before routing/admission"
            ),
            where="predict/comm:CommRegressor",
            data={"missing_ops": missing, "fitted_ops": comm.fitted_ops(), "hw": hw_name},
        )
    ]


def audit_predictor(
    predictor: Any,
    *,
    required_families: Optional[Iterable[str]] = None,
    required_ops: Optional[Iterable[str]] = None,
    hw_name: str = "",
) -> List[Diagnostic]:
    """SP401/SP402 against a configured backend instance: missing comm-op
    coefficients and untrained kernel families surface *now*, not as a
    skip warning in the middle of a fleet sweep or as an admission
    fallback mid-replay."""
    name = hw_name or getattr(getattr(predictor, "hw", None), "name", "")
    diags = audit_comm_regressor(
        getattr(predictor, "_comm", None), required_ops=required_ops, hw_name=name
    )
    families = predictor.families() if hasattr(predictor, "families") else None
    if families is not None:
        required = set(
            required_families if required_families is not None else E2E_FAMILIES
        )
        missing = sorted(required - set(families))
        if missing:
            fallback = getattr(predictor, "fallback", "error")
            severity = "error" if fallback == "error" else "warning"
            suffix = f" for {name}" if name else ""
            diags.append(
                Diagnostic(
                    code="SP402",
                    severity=severity,
                    check="coverage",
                    message=(
                        f"predictor {getattr(predictor, 'name', type(predictor).__name__)!r}"
                        f"{suffix} has no model for kernel family(ies) {missing} "
                        + (
                            "and fallback='error' — prediction would raise"
                            if fallback == "error"
                            else f"(explicit fallback={fallback!r} substitutes)"
                        )
                    ),
                    where="predict/backends",
                    data={"missing_families": missing, "fallback": fallback, "hw": name},
                )
            )
    return diags
