"""Kernel-resource lint (SP2xx): walk each Pallas kernel's static
grid/BlockSpec geometry against every :class:`~repro.core.hardware.TPUSpec`
before any compile.

The kernels' ``ops.py`` modules expose ``grid_shape``/``vmem_footprint``
static helpers that mirror the ``pallas_call`` BlockSpecs exactly (pinned
by direct unit tests); this module derives each registry arch's default
kernel workloads, evaluates the helpers, and reports:

* SP201 — the double-buffered working set exceeds a device's VMEM;
* SP202 — a block choice the kernel would reject with an assert
  (non-divisible tiling after the ``min(block, dim)`` clamp);
* SP203 — a degenerate grid (zero/negative dimension: nothing launches);
* SP204 — a compute/param dtype outside the priced vocabulary (the
  decomposer and the ref/kernel pair would disagree on byte widths).
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import Diagnostic
from repro.configs.base import ArchConfig
from repro.core.decomposer import COMPUTE_DTYPE_BYTES, moe_dispatch_geometry
from repro.core.hardware import REGISTRY, TPUSpec
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.fused_moe import ops as moe_ops
from repro.kernels.rmsnorm import ops as rmsnorm_ops
from repro.kernels.scaled_mm import ops as scaled_mm_ops
from repro.kernels.silu_mul import ops as silu_mul_ops

_PARAM_DTYPES = ("float32", "bfloat16", "float16")

#: kernel name -> (grid_shape, vmem_footprint) static helper pair
KERNEL_HELPERS = {
    "flash_attention": (flash_ops.grid_shape, flash_ops.vmem_footprint),
    "fused_moe": (moe_ops.grid_shape, moe_ops.vmem_footprint),
    "scaled_mm": (scaled_mm_ops.grid_shape, scaled_mm_ops.vmem_footprint),
    "rmsnorm": (rmsnorm_ops.grid_shape, rmsnorm_ops.vmem_footprint),
    "silu_mul": (silu_mul_ops.grid_shape, silu_mul_ops.vmem_footprint),
}


def kernel_workloads(
    cfg: ArchConfig, *, B: int = 2, lin: int = 512
) -> Iterator[Tuple[str, Dict[str, Any]]]:
    """The default Pallas kernel launches one prefill step of ``cfg``
    implies: ``(kernel name, helper kwargs)`` pairs with the kernels'
    default block choices. Families the arch does not use are omitted
    (pure-SSM archs launch no attention; non-MoE archs no fused_moe)."""
    T = B * lin
    if cfg.n_heads:
        yield (
            "flash_attention",
            {
                "B": B,
                "S": lin,
                "Skv": lin,
                "Hq": cfg.n_heads,
                "Hkv": cfg.n_kv_heads,
                "D": cfg.resolved_head_dim,
            },
        )
    if cfg.n_experts:
        _, _, C = moe_dispatch_geometry(
            T, cfg.n_experts, cfg.top_k, max(cfg.capacity_factor, 2.0), cfg.moe_group
        )
        yield (
            "fused_moe",
            {"E": cfg.n_experts, "C": C, "D": cfg.d_model, "F": cfg.moe_hidden},
        )
    if cfg.d_ff:  # pure-SSM archs (mamba2) have no FFN projection
        yield ("scaled_mm", {"M": T, "K": cfg.d_model, "N": cfg.d_ff})
        yield ("silu_mul", {"R": T, "d": cfg.d_ff})
    yield ("rmsnorm", {"R": T, "d": cfg.d_model})


def check_kernel_resources(
    cfg: ArchConfig,
    *,
    B: int = 2,
    lin: int = 512,
    hws: Optional[Sequence[TPUSpec]] = None,
    workloads: Optional[Sequence[Tuple[str, Dict[str, Any]]]] = None,
    block_overrides: Optional[Dict[str, Dict[str, int]]] = None,
) -> List[Diagnostic]:
    """SP201-SP204 for one arch across the hardware registry.

    ``workloads`` overrides the derived kernel set (seeded-bug tests);
    ``block_overrides`` maps kernel name -> block kwargs, so autotuning
    candidates can be linted before being launched."""
    hws = list(hws) if hws is not None else list(REGISTRY.values())
    if workloads is None:
        workloads = list(kernel_workloads(cfg, B=B, lin=lin))
    diags: List[Diagnostic] = []

    if cfg.compute_dtype not in COMPUTE_DTYPE_BYTES:
        diags.append(
            Diagnostic(
                code="SP204",
                severity="error",
                check="kernel-resource",
                message=(
                    f"compute_dtype {cfg.compute_dtype!r} is outside the priced "
                    f"vocabulary {sorted(COMPUTE_DTYPE_BYTES)} — the decomposer "
                    f"cannot size its byte streams"
                ),
                arch=cfg.name,
                where="configs:compute_dtype",
            )
        )
    if cfg.param_dtype not in _PARAM_DTYPES:
        diags.append(
            Diagnostic(
                code="SP204",
                severity="error",
                check="kernel-resource",
                message=(
                    f"param_dtype {cfg.param_dtype!r} is outside the supported "
                    f"vocabulary {_PARAM_DTYPES} — ref and kernel dtypes would diverge"
                ),
                arch=cfg.name,
                where="configs:param_dtype",
            )
        )

    dtype_bytes = COMPUTE_DTYPE_BYTES.get(cfg.compute_dtype, 2)
    for name, kwargs in workloads:
        blocks = dict((block_overrides or {}).get(name, {}))
        diags += check_blocks(
            name, kwargs, blocks, hws=hws, dtype_bytes=dtype_bytes, arch=cfg.name
        )
    return diags


def check_blocks(
    name: str,
    kwargs: Dict[str, Any],
    blocks: Optional[Dict[str, int]] = None,
    *,
    hws: Optional[Sequence[TPUSpec]] = None,
    dtype_bytes: int = 2,
    arch: str = "tuner",
) -> List[Diagnostic]:
    """SP201-SP203 geometry lint for ONE (kernel, workload, block-config)
    triple across ``hws`` — no :class:`ArchConfig` needed. This is the exact
    check the ``repro.tune`` autotuner runs over every candidate before it
    is allowed to launch, so nothing the auditor would reject ever runs."""
    hws = list(hws) if hws is not None else list(REGISTRY.values())
    blocks = dict(blocks or {})
    grid_fn, vmem_fn = KERNEL_HELPERS[name]
    diags: List[Diagnostic] = []
    try:
        grid = grid_fn(**kwargs, **blocks)
    except ValueError as e:
        diags.append(
            Diagnostic(
                code="SP202",
                severity="error",
                check="kernel-resource",
                message=str(e),
                arch=arch,
                where=f"kernels/{name}:grid_shape {kwargs}",
                data={"kernel": name, "workload": kwargs, "blocks": blocks},
            )
        )
        return diags
    if any(g <= 0 for g in grid):
        diags.append(
            Diagnostic(
                code="SP203",
                severity="error",
                check="kernel-resource",
                message=f"{name} launches a degenerate grid {grid} — nothing executes",
                arch=arch,
                where=f"kernels/{name}:grid_shape {kwargs}",
                data={"kernel": name, "grid": list(grid), "workload": kwargs},
            )
        )
        return diags
    vm_kw = dict(blocks)
    if name != "scaled_mm":  # int8 kernel: operand widths are fixed
        vm_kw["dtype_bytes"] = dtype_bytes
    footprint = vmem_fn(**kwargs, **vm_kw)
    for hw in hws:
        budget = hw.vmem_mb * 2**20
        if footprint > budget:
            diags.append(
                Diagnostic(
                    code="SP201",
                    severity="error",
                    check="kernel-resource",
                    message=(
                        f"{name} working set {footprint / 2**20:.1f} MiB overflows "
                        f"{hw.name} VMEM ({hw.vmem_mb:g} MiB) with blocks "
                        f"{blocks or 'default'} — the compile would spill or abort"
                    ),
                    arch=arch,
                    where=f"kernels/{name}:vmem_footprint {kwargs} on {hw.name}",
                    data={
                        "kernel": name,
                        "hw": hw.name,
                        "footprint_bytes": footprint,
                        "vmem_bytes": int(budget),
                        "blocks": blocks,
                    },
                )
            )
    return diags
