"""Static auditor: device-free checks that catch model/prediction drift
before anything compiles or serves.

Four check families (see :mod:`repro.analysis.diagnostics` for the code
table):

* ``conservation`` (SP1xx) — analytical FLOP/byte ledgers vs the
  decomposer's per-call output;
* ``kernel-resource`` (SP2xx) — Pallas grid/BlockSpec geometry vs each
  ``TPUSpec``'s VMEM;
* ``sharding`` (SP3xx) — PartitionSpec trees vs a mesh shape;
* ``coverage`` (SP4xx) — emitted call vocabulary vs what backends price.

Run the full audit with ``python -m repro.analysis --all --strict``.
"""
from repro.analysis.audit import CHECK_FAMILIES, AuditShape, audit_arch, run_audit
from repro.analysis.conservation import (
    check_conservation,
    check_dryrun_artifacts,
    check_ep_alltoall,
    check_head_accounting,
    check_task_conservation,
)
from repro.analysis.coverage import (
    E2E_FAMILIES,
    audit_comm_regressor,
    audit_predictor,
    check_coverage,
)
from repro.analysis.diagnostics import (
    SEVERITIES,
    AuditError,
    Diagnostic,
    json_report,
    render_report,
    sort_diagnostics,
    worst_severity,
)
from repro.analysis.kernels import KERNEL_HELPERS, check_kernel_resources, kernel_workloads
from repro.analysis.sharding import PRODUCTION_MESH_SIZES, MeshShape, check_sharding

__all__ = [
    "AuditError",
    "AuditShape",
    "CHECK_FAMILIES",
    "Diagnostic",
    "E2E_FAMILIES",
    "KERNEL_HELPERS",
    "MeshShape",
    "PRODUCTION_MESH_SIZES",
    "SEVERITIES",
    "audit_arch",
    "audit_comm_regressor",
    "audit_predictor",
    "check_conservation",
    "check_coverage",
    "check_dryrun_artifacts",
    "check_ep_alltoall",
    "check_head_accounting",
    "check_kernel_resources",
    "check_sharding",
    "check_task_conservation",
    "json_report",
    "kernel_workloads",
    "render_report",
    "run_audit",
    "sort_diagnostics",
    "worst_severity",
]
