"""Device-free sharding checker (SP3xx): validate parameter and cache
PartitionSpec trees against a mesh *shape* — no devices, no mesh object,
no placement.

``dist.sharding.resolve_pspec`` only ever consumes ``dict(mesh.shape)``,
so a :class:`MeshShape` stand-in (an axis-name -> size mapping exposed as
``.shape``) lets the auditor resolve every arch's full-size parameter tree
against the 16x16 production geometry in milliseconds, via
``jax.eval_shape`` (no parameter is ever materialized). Checks:

* SP301 — a param/cache leaf name outside the audited rule set (the
  frozen ``AUDITED_PARAM_LEAVES`` contract: new model families must add a
  deliberate rule, not ride the generic matrix fallback);
* SP302 — a resolved spec consuming one mesh axis twice (would shard a
  tensor onto more shards than devices);
* SP303 — a sharded dim its mesh axes do not divide (ragged shards);
* SP304 — a large parameter left fully replicated (warning: every device
  holds a full copy; legitimate for norm scales, suspicious above
  ``replicated_warn_mb``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.analysis.diagnostics import Diagnostic
from repro.configs.base import ArchConfig
from repro.dist.sharding import (
    AUDITED_PARAM_LEAVES,
    _CACHE_RULES,
    _path_names,
    cache_pspecs,
    param_pspecs,
)

#: the production mesh geometry (launch.mesh.make_production_mesh) as a
#: device-free shape — the default audit target
PRODUCTION_MESH_SIZES = {"data": 16, "model": 16}

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1, "int32": 4}


class MeshShape:
    """Shape-only mesh stand-in: ``resolve_pspec`` reads nothing but
    ``dict(mesh.shape)``, so this audits sharding with zero devices."""

    def __init__(self, sizes: Dict[str, int]) -> None:
        self._sizes = dict(sizes)

    @property
    def shape(self) -> Dict[str, int]:
        return dict(self._sizes)

    def __repr__(self) -> str:
        return f"MeshShape({self._sizes})"


def _leaf_bytes(leaf: Any) -> int:
    n = 1
    for d in leaf.shape:
        n *= int(d)
    return n * _DTYPE_BYTES.get(str(getattr(leaf, "dtype", "float32")), 4)


def _spec_axes(entry: Any) -> List[str]:
    if entry is None:
        return []
    if isinstance(entry, tuple):
        return [str(a) for a in entry]
    return [str(entry)]


def _validate_tree(
    shapes: Any,
    specs: Any,
    sizes: Dict[str, int],
    *,
    cfg_name: str,
    kind: str,
    audited: frozenset,
    replicated_warn_mb: float,
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    leaves_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    leaves_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(leaves_shapes, leaves_specs):
        names = _path_names(path)
        name = names[-1] if names else ""
        where = f"{kind}:{'/'.join(names) or '<root>'}"
        if name not in audited:
            diags.append(
                Diagnostic(
                    code="SP301",
                    severity="error",
                    check="sharding",
                    message=(
                        f"{kind} leaf {name!r} has no audited sharding rule — add "
                        f"a deliberate rule to dist.sharding instead of riding "
                        f"the generic fallback"
                    ),
                    arch=cfg_name,
                    where=where,
                    data={"leaf": name, "shape": [int(d) for d in leaf.shape]},
                )
            )
        used: Dict[str, int] = {}
        entries = list(spec)
        for dim_i, entry in enumerate(entries):
            axes = _spec_axes(entry)
            for ax in axes:
                used[ax] = used.get(ax, 0) + 1
            prod = 1
            for ax in axes:
                prod *= sizes.get(ax, 1)
            if axes and int(leaf.shape[dim_i]) % prod != 0:
                diags.append(
                    Diagnostic(
                        code="SP303",
                        severity="error",
                        check="sharding",
                        message=(
                            f"{kind} leaf {name!r} dim {dim_i} (={leaf.shape[dim_i]}) "
                            f"is not divisible by mesh axes {axes} (x{prod}) — "
                            f"ragged shards"
                        ),
                        arch=cfg_name,
                        where=where,
                        data={"leaf": name, "dim": dim_i, "axes": axes, "prod": prod},
                    )
                )
        reused = sorted(ax for ax, n in used.items() if n > 1)
        if reused:
            diags.append(
                Diagnostic(
                    code="SP302",
                    severity="error",
                    check="sharding",
                    message=(
                        f"{kind} leaf {name!r} spec {spec} consumes mesh axis(es) "
                        f"{reused} more than once"
                    ),
                    arch=cfg_name,
                    where=where,
                    data={"leaf": name, "spec": str(spec), "reused": reused},
                )
            )
        if not any(_spec_axes(e) for e in entries):
            nbytes = _leaf_bytes(leaf)
            if nbytes > replicated_warn_mb * 2**20:
                diags.append(
                    Diagnostic(
                        code="SP304",
                        severity="warning",
                        check="sharding",
                        message=(
                            f"{kind} leaf {name!r} ({nbytes / 2**20:.1f} MiB) is fully "
                            f"replicated — every device holds a full copy"
                        ),
                        arch=cfg_name,
                        where=where,
                        data={"leaf": name, "bytes": nbytes},
                    )
                )
    return diags


def check_sharding(
    cfg: ArchConfig,
    mesh_sizes: Optional[Dict[str, int]] = None,
    *,
    param_shapes: Optional[Any] = None,
    replicated_warn_mb: float = 64.0,
    cache_batch: int = 4,
    cache_len: int = 128,
) -> List[Diagnostic]:
    """SP301-SP304 for one arch's parameter and cache trees, resolved
    against ``mesh_sizes`` (default: the 16x16 production geometry)
    entirely device-free. ``param_shapes`` overrides the
    ``jax.eval_shape``-derived tree (seeded-bug tests inject a leaf)."""
    from repro.models.registry import build_model

    sizes = dict(mesh_sizes if mesh_sizes is not None else PRODUCTION_MESH_SIZES)
    mesh = MeshShape(sizes)
    api = build_model(cfg)
    if param_shapes is None:
        param_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = param_pspecs(param_shapes, mesh)
    diags = _validate_tree(
        param_shapes,
        specs,
        sizes,
        cfg_name=cfg.name,
        kind="param",
        audited=AUDITED_PARAM_LEAVES,
        replicated_warn_mb=replicated_warn_mb,
    )
    try:
        cache_shapes = jax.eval_shape(lambda: api.init_cache(cache_batch, cache_len))
    except Exception:  # encoder-decoder/exotic families without a plain cache
        cache_shapes = None
    if cache_shapes is not None:
        cache_specs = cache_pspecs(cache_shapes, mesh)
        diags += _validate_tree(
            cache_shapes,
            cache_specs,
            sizes,
            cfg_name=cfg.name,
            kind="cache",
            audited=frozenset(_CACHE_RULES),
            replicated_warn_mb=float("inf"),  # caches: replication is size-checked via params
        )
    return diags
