"""``python -m repro.analysis`` — the static auditor CLI.

Exit codes: 0 clean (or warnings/info only), 1 when any error-severity
diagnostic fires (``--strict`` also fails on warnings), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.audit import CHECK_FAMILIES, AuditShape, run_audit
from repro.analysis.diagnostics import json_report, render_report, sort_diagnostics
from repro.configs import list_archs


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static auditor: conservation, kernel-resource, sharding "
        "and predictor-coverage checks over registry architectures.",
    )
    target = p.add_mutually_exclusive_group(required=True)
    target.add_argument(
        "--arch",
        action="append",
        choices=list_archs(),
        help="audit one arch (repeatable)",
    )
    target.add_argument(
        "--all", action="store_true", help="audit every registry arch"
    )
    p.add_argument(
        "--check",
        action="append",
        choices=CHECK_FAMILIES,
        help="run only this check family (repeatable; default: all four)",
    )
    p.add_argument("--json", action="store_true", help="emit machine-readable JSON")
    p.add_argument(
        "--strict",
        action="store_true",
        help="treat warning-severity diagnostics as failures too",
    )
    p.add_argument("--batch", type=int, default=AuditShape.B, help="audit batch size")
    p.add_argument("--lin", type=int, default=AuditShape.lin, help="audit prefill length")
    p.add_argument("--lout", type=int, default=AuditShape.lout, help="audit decode length")
    p.add_argument("--tp", type=int, default=AuditShape.tp, help="audit tensor-parallel degree")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    shape = AuditShape(B=args.batch, lin=args.lin, lout=args.lout, tp=args.tp)
    diags = sort_diagnostics(
        run_audit(args.arch, shape=shape, checks=args.check)
    )
    if args.json:
        print(json_report(diags))
    else:
        print(render_report(diags))
    failing = {"error", "warning"} if args.strict else {"error"}
    return 1 if any(d.severity in failing for d in diags) else 0


if __name__ == "__main__":
    sys.exit(main())
