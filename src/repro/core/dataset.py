"""Dataset construction (paper §V-B): sample kernel workloads from the
serving-framework ranges, run the analytical pipeline (decompose -> schedule
-> features) and record the hwsim ground truth per (workload, hardware).

Workload ranges follow the paper's Section V-B (scaled for single-core-CPU
tractability; the structure — log-uniform dims, variable-length attention
batches, MoE routing skew — is preserved).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hwsim
from repro.core.decomposer import SCHED_POLICY, decompose
from repro.core.features import analyze
from repro.core.hardware import REGISTRY, TPUSpec, seen_hw, unseen_hw
from repro.core.scheduler import schedule

KERNELS = ("gemm", "attention", "rmsnorm", "silu_mul", "scaled_mm", "fused_moe")


def _logu(rng, lo, hi):
    return int(np.exp(rng.uniform(np.log(lo), np.log(hi))))


def sample_workload(kind: str, rng: np.random.Generator) -> dict:
    if kind == "gemm":
        return {
            "M": _logu(rng, 2, 65536),
            "N": max(128, _logu(rng, 384, 65536) // 128 * 128),
            "K": max(128, _logu(rng, 256, 16384) // 128 * 128),
        }
    if kind == "scaled_mm":
        return {
            "M": _logu(rng, 2, 65536),
            "N": max(128, _logu(rng, 384, 8192) // 128 * 128),
            "K": max(128, _logu(rng, 256, 8192) // 128 * 128),
        }
    if kind == "attention":
        decode = rng.random() < 0.3
        qlen = 1 if decode else _logu(rng, 16, 16384)
        kvlen = qlen + (_logu(rng, 4, 20481) if decode or rng.random() < 0.5 else 0)
        nkv = int(rng.integers(1, 9))
        return {
            "bs": int(rng.integers(1, 17)),
            "nkv": nkv,
            "group": int(rng.integers(1, 9)),
            "hd": int(rng.choice([64, 128])),
            "qlen": qlen,
            "kvlen": kvlen,
            "causal": 1 if rng.random() < 0.8 else 0,
        }
    if kind == "rmsnorm":
        return {"seq": _logu(rng, 2, 65536), "dim": _logu(rng, 128, 16384)}
    if kind == "silu_mul":
        return {"seq": _logu(rng, 2, 65536), "dim": _logu(rng, 768, 32768)}
    if kind == "fused_moe":
        return {
            "M": _logu(rng, 2, 8192),
            "E": int(rng.choice([8, 16, 32, 64, 128])),
            "topk": int(rng.integers(2, 9)),
            "H": max(128, _logu(rng, 1024, 4096) // 128 * 128),
            "N": max(128, _logu(rng, 512, 3072) // 128 * 128),
            "skew": float(rng.uniform(0.0, 0.7)),
            "seed": int(rng.integers(0, 2**31 - 1)),
        }
    raise ValueError(kind)


@dataclasses.dataclass
class KernelDataset:
    kind: str
    X: np.ndarray  # (n, FEATURE_DIM) analytical feature vectors
    y_eff: np.ndarray  # (n,) efficiency targets in (0, 1]
    theoretical_s: np.ndarray
    actual_s: np.ndarray
    hw_names: list
    workloads: list  # dicts

    def mask_hw(self, names: set) -> "KernelDataset":
        m = np.array([h in names for h in self.hw_names])
        return KernelDataset(
            self.kind,
            self.X[m],
            self.y_eff[m],
            self.theoretical_s[m],
            self.actual_s[m],
            [h for h, keep in zip(self.hw_names, m) if keep],
            [w for w, keep in zip(self.workloads, m) if keep],
        )


def featurize(kind: str, X: dict, hw: TPUSpec):
    tasks = decompose(kind, X, hw)
    chip_of = schedule(SCHED_POLICY[kind], tasks, hw)
    return analyze(tasks, chip_of, hw)


def build_dataset(
    kind: str,
    n_workloads: int = 300,
    seed: int = 0,
    hw_list: list | None = None,
) -> KernelDataset:
    rng = np.random.default_rng(seed)
    hws = hw_list or list(REGISTRY.values())
    feats, ys, theos, actuals, hw_names, workloads = [], [], [], [], [], []
    for _ in range(n_workloads):
        w = sample_workload(kind, rng)
        for hw in hws:
            fs = featurize(kind, w, hw)
            actual = hwsim.simulate(kind, w, hw)
            eff = min(fs.theoretical_s / actual, 1.0)
            feats.append(fs.vector(hw))
            ys.append(eff)
            theos.append(fs.theoretical_s)
            actuals.append(actual)
            hw_names.append(hw.name)
            workloads.append(w)
    return KernelDataset(
        kind=kind,
        X=np.stack(feats),
        y_eff=np.asarray(ys, np.float32),
        theoretical_s=np.asarray(theos),
        actual_s=np.asarray(actuals),
        hw_names=hw_names,
        workloads=workloads,
    )


SEEN = {h.name for h in seen_hw()}
UNSEEN = {h.name for h in unseen_hw()}


def mape(pred, actual) -> float:
    pred = np.asarray(pred, np.float64)
    actual = np.asarray(actual, np.float64)
    return float(np.mean(np.abs(pred - actual) / np.maximum(actual, 1e-12)) * 100.0)
