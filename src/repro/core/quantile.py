"""Beyond simulation (paper §VII-A/B): P80 quantile-regression ceiling model
and Performance-Gap diagnosis for the fused MoE kernel."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dataset import KernelDataset, SEEN
from repro.core.nn import TrainedMLP, fit_mlp


@dataclasses.dataclass
class CeilingModel:
    model: TrainedMLP
    quantile: float

    def predict_ceiling(self, X: np.ndarray) -> np.ndarray:
        return np.clip(self.model.predict(X), 1e-3, 1.0)


def train_ceiling(
    ds: KernelDataset, *, quantile: float = 0.8, seed: int = 0, max_epochs: int = 150
) -> CeilingModel:
    """Same features and efficiency target as §V-C, pinball loss at P80:
    fits the top-20% envelope — a statistically robust Potential Performance
    Ceiling (less outlier-sensitive than P90+)."""
    tr = ds.mask_hw(SEEN)  # trained on seen hw; diagnosis runs on all hw
    model = fit_mlp(
        tr.X, tr.y_eff, seed=seed, loss_kind="pinball", quantile=quantile,
        max_epochs=max_epochs,
    )
    return CeilingModel(model=model, quantile=quantile)


@dataclasses.dataclass
class GapReport:
    gaps: np.ndarray  # ceiling - actual efficiency per row
    underperforming: np.ndarray  # bool mask (gap > threshold)
    per_hw_counts: dict  # hw -> count of underperforming points
    per_hw_frac: dict
    threshold: float

    def cdf(self, grid=None):
        grid = grid if grid is not None else np.linspace(-0.2, 0.8, 101)
        return grid, np.array([(self.gaps <= g).mean() for g in grid])


def perf_gap(ceiling: CeilingModel, ds: KernelDataset, threshold: float = 0.1) -> GapReport:
    """perf_gap = y_hat_p80 - y_actual  (paper §VII-B)."""
    yhat = ceiling.predict_ceiling(ds.X)
    gaps = yhat - ds.y_eff
    under = gaps > threshold
    per_hw_counts, per_hw_frac = {}, {}
    hw_arr = np.asarray(ds.hw_names)
    for hw in sorted(set(ds.hw_names)):
        m = hw_arr == hw
        per_hw_counts[hw] = int(under[m].sum())
        per_hw_frac[hw] = float(under[m].mean())
    return GapReport(
        gaps=gaps,
        underperforming=under,
        per_hw_counts=per_hw_counts,
        per_hw_frac=per_hw_frac,
        threshold=threshold,
    )
