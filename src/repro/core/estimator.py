"""Performance Estimator (paper §IV-D): one lightweight MLP per kernel
family consuming the analytical feature vector; latency is recovered as
theoretical_time / predicted_efficiency."""
from __future__ import annotations

import dataclasses
import os
import pickle

import numpy as np

from repro.core.dataset import KernelDataset, build_dataset, featurize, SEEN
from repro.core.hardware import REGISTRY, TPUSpec
from repro.core.nn import TrainedMLP, fit_mlp


@dataclasses.dataclass
class PipeWeave:
    models: dict  # kind -> TrainedMLP

    def predict_eff(self, kind: str, feats: np.ndarray) -> np.ndarray:
        return np.clip(self.models[kind].predict(feats), 1e-3, 1.0)

    def predict_latency(self, kind: str, X: dict, hw: TPUSpec) -> float:
        fs = featurize(kind, X, hw)
        eff = self.predict_eff(kind, fs.vector(hw)[None])[0]
        return float(fs.theoretical_s / eff)

    def predict_dataset(self, ds: KernelDataset) -> np.ndarray:
        eff = self.predict_eff(ds.kind, ds.X)
        return ds.theoretical_s / eff

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "PipeWeave":
        with open(path, "rb") as f:
            return pickle.load(f)


def train_pipeweave(
    datasets: dict[str, KernelDataset],
    *,
    seed: int = 0,
    max_epochs: int = 250,
    verbose: bool = False,
) -> PipeWeave:
    """Train per-kernel MLPs on SEEN hardware rows only (paper's split)."""
    models = {}
    for kind, ds in datasets.items():
        tr = ds.mask_hw(SEEN)
        if verbose:
            print(f"[pipeweave] training {kind}: {len(tr.X)} rows")
        models[kind] = fit_mlp(
            tr.X, tr.y_eff, seed=seed, max_epochs=max_epochs, loss_kind="mape",
            verbose=verbose,
        )
    return PipeWeave(models=models)
