"""Performance Estimator (paper §IV-D): one lightweight MLP per kernel
family consuming the analytical feature vector; latency is recovered as
theoretical_time / predicted_efficiency."""
from __future__ import annotations

import dataclasses
import os
import pickle

import numpy as np

from repro.core.dataset import KernelDataset, featurize, SEEN
from repro.core.hardware import TPUSpec
from repro.core.nn import TrainedMLP, fit_mlp


# bump when the pickle payload layout or the feature contract changes;
# benchmarks cache fitted models on disk (benchmarks/common.py) and a
# stale cache must fail loudly, not mispredict silently
PICKLE_VERSION = 2


@dataclasses.dataclass
class PipeWeave:
    models: dict  # kind -> TrainedMLP

    def predict_eff(self, kind: str, feats: np.ndarray) -> np.ndarray:
        return np.clip(self.models[kind].predict(feats), 1e-3, 1.0)

    def predict_latency(self, kind: str, X: dict, hw: TPUSpec) -> float:
        """Scalar per-call prediction (featurizes from scratch every call);
        for batched, cached estimation use repro.predict.get_predictor."""
        fs = featurize(kind, X, hw)
        eff = self.predict_eff(kind, fs.vector(hw)[None])[0]
        return float(fs.theoretical_s / eff)

    def predict_dataset(self, ds: KernelDataset) -> np.ndarray:
        eff = self.predict_eff(ds.kind, ds.X)
        return ds.theoretical_s / eff

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = {"__pipeweave_version__": PICKLE_VERSION, "models": self.models}
        with open(path, "wb") as f:
            pickle.dump(payload, f)

    @staticmethod
    def load(path: str) -> "PipeWeave":
        with open(path, "rb") as f:
            obj = pickle.load(f)
        if isinstance(obj, PipeWeave):
            raise RuntimeError(
                f"{path} is a pre-versioning PipeWeave pickle; delete the "
                "stale cache entry (e.g. rm -r results/bench_cache) and "
                "retrain (benchmarks.common.get_pipeweave retrains "
                "automatically on a fresh cache)"
            )
        version = obj.get("__pipeweave_version__") if isinstance(obj, dict) else None
        if version != PICKLE_VERSION:
            raise RuntimeError(
                f"{path} has PipeWeave pickle version {version!r}, this code "
                f"expects {PICKLE_VERSION}; delete the stale cache entry and "
                "retrain with the current feature contract"
            )
        return PipeWeave(models=obj["models"])


def train_pipeweave(
    datasets: dict[str, KernelDataset],
    *,
    seed: int = 0,
    max_epochs: int = 250,
    verbose: bool = False,
) -> PipeWeave:
    """Train per-kernel MLPs on SEEN hardware rows only (paper's split)."""
    models = {}
    for kind, ds in datasets.items():
        tr = ds.mask_hw(SEEN)
        if verbose:
            print(f"[pipeweave] training {kind}: {len(tr.X)} rows")
        models[kind] = fit_mlp(
            tr.X, tr.y_eff, seed=seed, max_epochs=max_epochs, loss_kind="mape",
            verbose=verbose,
        )
    return PipeWeave(models=models)
