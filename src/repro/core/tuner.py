"""Model-guided kernel optimization (paper §VII-C): brute-force autotuning of
the fused-MoE kernel's (block_m, block_f, stages) on the configurations the
P80 ceiling model flags as underperforming; validates that diagnosed gap
density predicts realized tuning gains (the paper's Pearson-0.86 result)."""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import hwsim
from repro.core.dataset import KernelDataset
from repro.core.hardware import REGISTRY, TPUSpec

SEARCH_SPACE = {
    "block_m": (32, 64, 128, 256),
    "block_f": (128, 256, 512),
    "stages": (2, 3, 4),
}


@dataclasses.dataclass
class TuneResult:
    workload: dict
    hw: str
    t_default: float
    t_best: float
    best_config: dict

    @property
    def speedup(self) -> float:
        return self.t_default / self.t_best


def tune_one(workload: dict, hw: TPUSpec) -> TuneResult:
    t_default = hwsim.simulate("fused_moe", workload, hw)
    best_t, best_cfg = t_default, {}
    for bm, bf, st in itertools.product(*SEARCH_SPACE.values()):
        cfg = {"block_m": bm, "block_f": bf, "stages": st}
        t = hwsim.simulate("fused_moe", workload, hw, config=cfg)
        if t < best_t:
            best_t, best_cfg = t, cfg
    return TuneResult(workload, hw.name, t_default, best_t, best_cfg)


def tune_underperformers(
    ds: KernelDataset, under_mask: np.ndarray, per_hw_limit: int = 40,
) -> dict[str, list[TuneResult]]:
    """Tune up to N unique underperforming configurations per hardware."""
    out: dict[str, list[TuneResult]] = {}
    hw_arr = np.asarray(ds.hw_names)
    for hw_name in sorted(set(ds.hw_names)):
        idxs = np.where((hw_arr == hw_name) & under_mask)[0][:per_hw_limit]
        results = [tune_one(ds.workloads[i], REGISTRY[hw_name]) for i in idxs]
        out[hw_name] = results
    return out


def geomean_speedup(results: list[TuneResult]) -> float:
    if not results:
        return 1.0
    return float(np.exp(np.mean([np.log(r.speedup) for r in results])))


def pearson(x, y) -> float:
    x, y = np.asarray(x, float), np.asarray(y, float)
    if len(x) < 2 or x.std() == 0 or y.std() == 0:
        return 0.0
    return float(np.corrcoef(x, y)[0, 1])
