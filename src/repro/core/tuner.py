"""Compatibility shim — the autotuner moved to :mod:`repro.tune`.

The original module brute-forced a hard-coded ``(block_m, block_f, stages)``
space through hwsim, including a ``stages`` knob no Pallas kernel accepts.
The real loop (signature-derived spaces, SP2xx prefilter, predictor
ranking, timed top-k) lives in ``repro.tune``; this module keeps the old
entry points importable for existing callers.
"""
from __future__ import annotations

from repro.core.hardware import TPUSpec
from repro.tune.tuner import (
    TuneResult,
    geomean_speedup,
    pearson,
    spearman,
    tune_underperformers,
    tune_workload,
)

__all__ = [
    "TuneResult",
    "geomean_speedup",
    "pearson",
    "spearman",
    "tune_one",
    "tune_underperformers",
    "tune_workload",
]


def tune_one(workload: dict, hw: TPUSpec) -> TuneResult:
    """Old name for single-workload hwsim tuning (oracle-ranked, so the
    result is the exhaustive-search optimum over the measured top-k and
    the speedup is always >= 1)."""
    return tune_workload(workload, hw)
