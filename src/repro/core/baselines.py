"""Baseline predictors (paper §VI-A), adjusted — as the paper does — to share
PipeWeave's analytical components where their design allows:

* Roofline  [Williams et al.]: latency = dominant-pipe theoretical time
  (perfect-efficiency first-order model).
* Linear    [Li et al., MICRO'23]: linear regression on two features from our
  Feature Analyzer — aggregate compute cycles and memory cycles.
* Habitat   [Yu et al., ATC'21]-like: black-box MLP on raw workload dims +
  hardware vector (kernel-level granularity, no pipeline decomposition).
* Neusight  [Lee et al., ASPLOS'25]-like: tile-level grey-box — consumes the
  SAME task definitions from our Kernel Decomposer, but with the paper's
  documented limitations baked in: a *static wave model* (latency =
  waves x uniform tile latency), aggregate mean-tile features, no dynamic
  per-chip scheduling — exactly the three gaps §III identifies.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.dataset import KernelDataset, SEEN
from repro.core.decomposer import decompose
from repro.core.features import PIPES, throughput
from repro.core.hardware import REGISTRY, TPUSpec
from repro.core.nn import fit_mlp


# ----------------------------------------------------------------------
# Roofline
# ----------------------------------------------------------------------


class RooflineBaseline:
    name = "roofline"

    def fit(self, ds: KernelDataset):
        return self

    def predict(self, ds: KernelDataset) -> np.ndarray:
        return ds.theoretical_s.copy()


# ----------------------------------------------------------------------
# Linear (2 aggregate features -> latency)
# ----------------------------------------------------------------------


class LinearBaseline:
    name = "linear"

    def __init__(self):
        self.theta = None

    @staticmethod
    def _feats(ds: KernelDataset) -> np.ndarray:
        # columns of the analytical vector: per-pipe [total, cycles, maxchip,
        # maxcycles, imb]; reconstruct aggregate compute & memory cycles
        comp = np.max(
            [10 ** ds.X[:, 5 * i + 1] for i, p in enumerate(PIPES) if p in ("mxu", "vpu", "xu")],
            axis=0,
        )
        mem = np.max(
            [10 ** ds.X[:, 5 * i + 1] for i, p in enumerate(PIPES) if p in ("hbm", "vmem")],
            axis=0,
        )
        return np.stack([comp, mem, np.ones(len(comp))], axis=1)

    def fit(self, ds: KernelDataset):
        tr = ds.mask_hw(SEEN)
        A = self._feats(tr)
        self.theta, *_ = np.linalg.lstsq(A, tr.actual_s * 1e6, rcond=None)
        return self

    def predict(self, ds: KernelDataset) -> np.ndarray:
        pred = self._feats(ds) @ self.theta / 1e6
        return np.maximum(pred, 1e-7)


# ----------------------------------------------------------------------
# Habitat-like (black-box MLP on raw dims + hw vector)
# ----------------------------------------------------------------------

_RAW_KEYS = ("M", "N", "K", "bs", "nkv", "group", "hd", "qlen", "kvlen",
             "causal", "seq", "dim", "E", "topk", "H", "skew")


def _raw_vector(w: dict, hw: TPUSpec) -> np.ndarray:
    feats = [math.log10(max(float(w.get(k, 0)), 1.0)) for k in _RAW_KEYS]
    return np.asarray(feats + list(hw.as_vector()), np.float32)


class HabitatBaseline:
    name = "habitat"

    def __init__(self):
        self.model = None
        self.scale = None

    @staticmethod
    def _X(ds: KernelDataset) -> np.ndarray:
        return np.stack(
            [_raw_vector(w, REGISTRY[h]) for w, h in zip(ds.workloads, ds.hw_names)]
        )

    def fit(self, ds: KernelDataset):
        tr = ds.mask_hw(SEEN)
        # black-box target: log-latency squashed to (0,1)
        logt = np.log10(tr.actual_s)
        self.scale = (logt.min() - 0.5, logt.max() + 0.5)
        y = (logt - self.scale[0]) / (self.scale[1] - self.scale[0])
        self.model = fit_mlp(self._X(tr), y.astype(np.float32), seed=1, loss_kind="mape")
        return self

    def predict(self, ds: KernelDataset) -> np.ndarray:
        y = self.model.predict(self._X(ds))
        logt = y * (self.scale[1] - self.scale[0]) + self.scale[0]
        return 10.0 ** logt


# ----------------------------------------------------------------------
# Neusight-like (tile-level features + static wave model)
# ----------------------------------------------------------------------


class NeusightBaseline:
    name = "neusight"

    def __init__(self):
        self.model = None

    @staticmethod
    def _tile_feats(w: dict, kind: str, hw: TPUSpec):
        tasks = decompose(kind, w, hw)
        n = max(len(tasks), 1)
        waves = math.ceil(n / hw.num_chips)
        mean = {
            "mxu": float(tasks.mxu.mean()) if n and len(tasks) else 0.0,
            "vpu": float(tasks.vpu.mean()) if len(tasks) else 0.0,
            "xu": float(tasks.xu.mean()) if len(tasks) else 0.0,
            "hbm": float(tasks.hbm.mean()) if len(tasks) else 0.0,
            "vmem": float(tasks.vmem.mean()) if len(tasks) else 0.0,
        }
        tile_cycles = max(
            max(mean[p] / throughput(hw, p) for p in PIPES), 1.0
        )
        lg = lambda x: math.log10(max(x, 1.0))
        feats = [lg(mean[p]) for p in PIPES] + [
            lg(tile_cycles),
            lg(n),
            lg(waves),
            *hw.as_vector(),
        ]
        tile_theo_s = tile_cycles / (hw.clock_ghz * 1e9)
        return np.asarray(feats, np.float32), tile_theo_s, waves

    def _X(self, ds: KernelDataset):
        rows, theo, waves = [], [], []
        for w, h in zip(ds.workloads, ds.hw_names):
            f, t, wv = self._tile_feats(w, ds.kind, REGISTRY[h])
            rows.append(f)
            theo.append(t)
            waves.append(wv)
        return np.stack(rows), np.asarray(theo), np.asarray(waves)

    def fit(self, ds: KernelDataset):
        tr = ds.mask_hw(SEEN)
        X, theo, waves = self._X(tr)
        # static-wave tile efficiency target: actual = waves * tile_theo / eff
        eff = np.clip(waves * theo / tr.actual_s, 1e-3, 1.0)
        self.model = fit_mlp(X, eff.astype(np.float32), seed=2, loss_kind="mape")
        self._cache = None
        return self

    def predict(self, ds: KernelDataset) -> np.ndarray:
        X, theo, waves = self._X(ds)
        eff = np.clip(self.model.predict(X), 1e-3, 1.0)
        return waves * theo / eff


BASELINES = {
    "roofline": RooflineBaseline,
    "linear": LinearBaseline,
    "habitat": HabitatBaseline,
    "neusight": NeusightBaseline,
}
