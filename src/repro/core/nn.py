"""Pure-JAX MLP with BatchNorm + Dropout and a MAPE / pinball-loss trainer
(paper §V-C): 3 hidden layers (256/128/64), ReLU, sigmoid head predicting
execution efficiency in [0, 1]. AdamW (reused from repro.optim), early
stopping on validation loss."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW, constant_lr

HIDDEN = (256, 128, 64)


def init_mlp(key, in_dim: int, hidden=HIDDEN):
    params = {"layers": []}
    dims = [in_dim, *hidden, 1]
    ks = jax.random.split(key, len(dims))
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        layer = {
            "w": (jax.random.normal(ks[i], (a, b)) * jnp.sqrt(2.0 / a)).astype(jnp.float32),
            "b": jnp.zeros((b,), jnp.float32),
        }
        if i < len(dims) - 2:  # BatchNorm on hidden layers
            layer["bn_scale"] = jnp.ones((b,), jnp.float32)
            layer["bn_bias"] = jnp.zeros((b,), jnp.float32)
        params["layers"].append(layer)
    state = {
        "bn_mean": [jnp.zeros((h,), jnp.float32) for h in hidden],
        "bn_var": [jnp.ones((h,), jnp.float32) for h in hidden],
    }
    return params, state


def mlp_forward(params, state, x, *, train: bool, rng=None, dropout: float = 0.1,
                momentum: float = 0.99):
    """Returns (sigmoid output in (0,1), new_state)."""
    new_mean, new_var = [], []
    h = x
    n_hidden = len(params["layers"]) - 1
    for i, layer in enumerate(params["layers"]):
        h = h @ layer["w"] + layer["b"]
        if i < n_hidden:
            if train:
                mu = jnp.mean(h, axis=0)
                var = jnp.var(h, axis=0) + 1e-5
                new_mean.append(momentum * state["bn_mean"][i] + (1 - momentum) * mu)
                new_var.append(momentum * state["bn_var"][i] + (1 - momentum) * var)
            else:
                mu, var = state["bn_mean"][i], state["bn_var"][i] + 1e-5
            h = (h - mu) / jnp.sqrt(var)
            h = h * layer["bn_scale"] + layer["bn_bias"]
            h = jax.nn.relu(h)
            if train and dropout > 0 and rng is not None:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1 - dropout, h.shape)
                h = jnp.where(keep, h / (1 - dropout), 0.0)
    out = jax.nn.sigmoid(h[:, 0])
    new_state = (
        {"bn_mean": new_mean, "bn_var": new_var} if train and new_mean else state
    )
    return out, new_state


def mape_loss(pred_eff, y_eff):
    """MAPE on efficiency (the paper's training objective)."""
    return jnp.mean(jnp.abs(pred_eff - y_eff) / jnp.maximum(y_eff, 1e-3))


def pinball_loss(pred, y, q: float):
    """Quantile (pinball) loss — §VII-A P80 ceiling objective."""
    diff = y - pred
    return jnp.mean(jnp.maximum(q * diff, (q - 1) * diff) / jnp.maximum(y, 1e-3))


@dataclasses.dataclass
class TrainedMLP:
    params: dict
    state: dict
    mu_x: np.ndarray
    sd_x: np.ndarray
    y_floor: float = 1e-3  # sigmoid-collapse guard: no training row was
    # below this efficiency, so predictions aren't allowed to be either
    # (latency = theo/eff amplifies eff underestimates unboundedly)
    # normalized-space training envelope: unseen-hardware rows can land 3x
    # outside the training z-range, saturating BatchNorm+sigmoid and
    # collapsing predictions to the floor — clip inference inputs to the
    # envelope (no-op for in-distribution rows)
    x_lo: Optional[np.ndarray] = None
    x_hi: Optional[np.ndarray] = None

    def _np_model(self):
        """Weights/BN stats as float64 numpy, converted once per instance.
        Inference runs in numpy float64 (not the jitted f32 forward) so
        per-row results are batch-size independent — the batched predictor
        path must reproduce per-call scalar sums to 1e-9 — and so batch
        shape changes never trigger jit recompiles."""
        cached = getattr(self, "_np_cache", None)
        if cached is None:
            layers = [
                {k: np.asarray(v, np.float64) for k, v in layer.items()}
                for layer in self.params["layers"]
            ]
            bn_mean = [np.asarray(m, np.float64) for m in self.state["bn_mean"]]
            bn_var = [np.asarray(v, np.float64) for v in self.state["bn_var"]]
            cached = (layers, bn_mean, bn_var)
            self._np_cache = cached
        return cached

    def __getstate__(self):
        state = self.__dict__.copy()
        state.pop("_np_cache", None)  # derived; keep pickles lean
        return state

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xn = (np.asarray(X, np.float64) - self.mu_x) / self.sd_x
        if self.x_lo is not None:
            Xn = np.clip(Xn, self.x_lo, self.x_hi)
        layers, bn_mean, bn_var = self._np_model()
        h = Xn
        n_hidden = len(layers) - 1
        for i, layer in enumerate(layers):
            h = h @ layer["w"] + layer["b"]
            if i < n_hidden:
                h = (h - bn_mean[i]) / np.sqrt(bn_var[i] + 1e-5)
                h = h * layer["bn_scale"] + layer["bn_bias"]
                h = np.maximum(h, 0.0)
        with np.errstate(over="ignore"):  # saturated sigmoid is fine
            out = 1.0 / (1.0 + np.exp(-h[:, 0]))
        return np.clip(out, self.y_floor, 1.0)


def fit_mlp(
    X: np.ndarray,
    y: np.ndarray,
    *,
    seed: int = 0,
    lr: float = 1e-3,
    weight_decay: float = 1e-4,
    batch: int = 512,
    max_epochs: int = 250,
    patience: int = 30,
    min_epochs: int = 40,
    loss_kind: str = "mape",
    quantile: float = 0.8,
    val_frac: float = 0.1,
    verbose: bool = False,
) -> TrainedMLP:
    rng = np.random.default_rng(seed)
    n = len(X)
    perm = rng.permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    mu_x = X[tr_idx].mean(0)
    sd_x = X[tr_idx].std(0) + 1e-6
    Xn = (X - mu_x) / sd_x
    Xtr, ytr = jnp.asarray(Xn[tr_idx]), jnp.asarray(y[tr_idx])
    Xva, yva = jnp.asarray(Xn[val_idx]), jnp.asarray(y[val_idx])

    params, state = init_mlp(jax.random.PRNGKey(seed), X.shape[1])
    opt = AdamW(lr=constant_lr(lr), weight_decay=weight_decay, clip_norm=1.0)
    opt_state = opt.init(params)

    def loss_fn(params, state, xb, yb, rng):
        pred, new_state = mlp_forward(params, state, xb, train=True, rng=rng)
        if loss_kind == "mape":
            return mape_loss(pred, yb), new_state
        return pinball_loss(pred, yb, quantile), new_state

    @jax.jit
    def step(params, state, opt_state, xb, yb, rng):
        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, state, xb, yb, rng
        )
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, new_state, opt_state, loss

    @jax.jit
    def val_loss(params, state):
        pred, _ = mlp_forward(params, state, Xva, train=False)
        if loss_kind == "mape":
            return mape_loss(pred, yva)
        return pinball_loss(pred, yva, quantile)

    key = jax.random.PRNGKey(seed + 1)
    best = (np.inf, params, state)
    bad = 0
    n_tr = len(tr_idx)
    steps_per_epoch = max(n_tr // batch, 1)
    for epoch in range(max_epochs):
        order = rng.permutation(n_tr)
        for s in range(steps_per_epoch):
            idx = order[s * batch : (s + 1) * batch]
            key, sub = jax.random.split(key)
            params, state, opt_state, _ = step(
                params, state, opt_state, Xtr[idx], ytr[idx], sub
            )
        vl = float(val_loss(params, state))
        if verbose and epoch % 10 == 0:
            print(f"  epoch {epoch:3d} val={vl:.4f}")
        if vl < best[0] - 1e-5:
            best = (vl, jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, state))
            bad = 0
        else:
            bad += 1
            if bad >= patience and epoch >= min_epochs:
                break
    _, params, state = best
    floor = float(max(np.min(y) * 0.5, 1e-3))
    return TrainedMLP(
        params=params, state=state, mu_x=mu_x, sd_x=sd_x, y_floor=floor,
        x_lo=np.asarray(Xn[tr_idx].min(0)), x_hi=np.asarray(Xn[tr_idx].max(0)),
    )
