"""Kernel Decomposer — F(X, S) -> {tasks}   (paper §IV-A).

Deterministically decomposes each kernel family into schedulable *tasks*.
On TPU a task is a Pallas grid tile (the unit a TensorCore streams through
with double-buffered DMA); across the slice, tiles are distributed by either
the static SPMD partition (conventional kernels) or a software work queue
(persistent/grouped kernels) — see scheduler.py.

Tasks are stored as a struct-of-arrays (:class:`TaskArray`) for speed; each
task carries its dimension-derived per-pipeline demands (paper Eq. 3-4):

    mxu  = alpha * prod(tile dims)    (alpha=2 GEMM, 4 flash-attention)
    vpu  = elementwise op count
    xu   = transcendental count (exp / rsqrt / silu / tanh)
    hbm  = operand/result bytes streamed from HBM
    vmem = bytes touched in VMEM (incl. accumulator traffic)
    align= MXU/VPU tile-alignment utilization in (0, 1]
    ws   = VMEM working-set bytes of the task

Each family's decomposer is a few dozen lines (paper §V-A reports 10-50).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.hardware import TPUSpec


@dataclasses.dataclass
class TaskArray:
    mxu: np.ndarray
    vpu: np.ndarray
    xu: np.ndarray
    hbm: np.ndarray
    vmem: np.ndarray
    align: np.ndarray
    ws: np.ndarray

    def __len__(self):
        return len(self.mxu)

    @staticmethod
    def build(n, **kw):
        z = lambda: np.zeros(n, dtype=np.float64)
        f = {k: np.asarray(v, dtype=np.float64) for k, v in kw.items()}
        return TaskArray(
            mxu=f.get("mxu", z()),
            vpu=f.get("vpu", z()),
            xu=f.get("xu", z()),
            hbm=f.get("hbm", z()),
            vmem=f.get("vmem", z()),
            align=f.get("align", np.ones(n)),
            ws=f.get("ws", z()),
        )

    @staticmethod
    def concat(parts: list["TaskArray"]) -> "TaskArray":
        return TaskArray(
            **{
                f.name: np.concatenate([getattr(p, f.name) for p in parts])
                for f in dataclasses.fields(TaskArray)
            }
        )


def _ceil(a, b):
    return -(-a // b)


def _util(sizes, quantum):
    sizes = np.asarray(sizes, dtype=np.float64)
    return sizes / (np.ceil(sizes / quantum) * quantum)


def _tile_sizes(total: int, tile: int) -> np.ndarray:
    n = _ceil(total, tile)
    sizes = np.full(n, tile, dtype=np.float64)
    if total % tile:
        sizes[-1] = total % tile
    return sizes


# ----------------------------------------------------------------------
# GEMM  (cuBLAS analogue: the XLA/Mosaic tile heuristic is the
# "closed-source" selector we reverse-engineer — paper §IV-A)
# ----------------------------------------------------------------------


def gemm_tile_heuristic(M, N, K, hw: TPUSpec, dtype_bytes: int = 2):
    """Biggest MXU-aligned tile whose working set fits VMEM, shrunk when the
    grid would underfill the slice (wave-quantization avoidance)."""
    vmem_budget = hw.vmem_mb * 2**20 * 0.6
    cands = ((512, 512), (512, 256), (256, 256), (256, 128), (128, 128))
    for tm, tn in cands:
        tiles = _ceil(M, tm) * _ceil(N, tn)
        work = (tm + tn) * min(K, 2048) * dtype_bytes + tm * tn * 4
        if work <= vmem_budget and (tiles >= hw.num_chips or (tm >= M and tn >= N)):
            return tm, tn
    return 128, 128


def decompose_gemm(X: dict, hw: TPUSpec) -> TaskArray:
    M, N, K = X["M"], X["N"], X["K"]
    b = X.get("dtype_bytes", 2)
    tm_h, tn_h = gemm_tile_heuristic(M, N, K, hw, b)
    # explicit kernel block choices (the autotuner's candidates) override the
    # XLA/Mosaic heuristic; absent keys reproduce the default decomposition
    tm = int(X.get("block_m", tm_h))
    tn = int(X.get("block_n", tn_h))
    ms = _tile_sizes(M, tm)
    ns = _tile_sizes(N, tn)
    m = np.repeat(ms, len(ns))
    n = np.tile(ns, len(ms))
    if "block_k" in X:
        bk = int(X["block_k"])
        k_panel = float(min(K, bk))
        # K is streamed in ceil(K/bk) panels with the f32 accumulator block
        # re-read/written once per extra panel
        acc = (np.ceil(K / bk) - 1.0) * m * n * 8.0
    else:
        k_panel = float(min(K, 2048))
        acc = 0.0
    t = TaskArray.build(
        len(m),
        mxu=2.0 * m * n * K,
        vpu=m * n,
        hbm=(m + n) * K * b + m * n * b,
        vmem=(m + n) * K * b + m * n * (b + 4) + acc,
        align=_util(m, 8) * _util(n, 128) * _util([K], 128)[0],
        ws=(k_panel * (m + n)) * b + m * n * 4,
    )
    return t


def decompose_scaled_mm(X: dict, hw: TPUSpec) -> TaskArray:
    """W8A8 GEMM: 1-byte operands + dequant epilogue (MXU int8 rate handled
    by hwsim via the int8 flag in X)."""
    t = decompose_gemm({**X, "dtype_bytes": 1}, hw)
    t.vpu = t.vpu * 3.0  # scale multiply + cast epilogue
    return t


# ----------------------------------------------------------------------
# FlashAttention (FA2-style): per (batch, kv-head, q-block) task; causal
# masking makes the effective KV per task variable — the paper's canonical
# non-uniform workload.
# ----------------------------------------------------------------------


def decompose_attention(X: dict, hw: TPUSpec) -> TaskArray:
    B, H, G = X["bs"], X["nkv"], X["group"]
    qlen, kvlen, hd = X["qlen"], X["kvlen"], X["hd"]
    causal = X.get("causal", 1)
    b = X.get("dtype_bytes", 2)
    bq_default = min(256, qlen) if qlen > 1 else 1
    bq = max(1, min(int(X.get("block_q", bq_default)), qlen))
    nq = _ceil(qlen, bq)
    m = _tile_sizes(qlen, bq)  # (nq,)
    starts = np.arange(nq) * bq
    offset = kvlen - qlen
    kv_eff = np.full(nq, float(kvlen))
    if causal:
        kv_eff = np.minimum(kvlen, offset + starts + m)
    rows = G * m
    if "block_k" in X:
        bk = int(X["block_k"])
        kv_panel = np.minimum(kv_eff, float(bk))
        # online-softmax accumulators (o, l, m) are re-updated once per extra
        # KV block the inner loop streams
        acc = (np.ceil(kv_eff / bk) - 1.0) * (rows * hd + 2.0 * rows) * 8.0
    else:
        kv_panel = np.minimum(kv_eff, 512)
        acc = 0.0
    one = TaskArray.build(
        nq,
        mxu=2.0 * rows * kv_eff * hd * 2.0,
        xu=rows * kv_eff,
        vpu=4.0 * rows * kv_eff,
        hbm=(2.0 * rows * hd + 2.0 * kv_eff * hd) * b,
        vmem=(2.0 * rows * hd + 2.0 * kv_eff * hd) * b + rows * kv_eff * b + acc,
        align=_util(rows, 8) * _util([hd], 128)[0],
        ws=(rows * hd * 2 + kv_panel * hd * 2) * b + rows * hd * 4,
    )
    reps = B * H
    return TaskArray(
        **{
            f.name: np.tile(getattr(one, f.name), reps)
            for f in dataclasses.fields(TaskArray)
        }
    )


# ----------------------------------------------------------------------
# RMSNorm / SiLU&Mul: row-block elementwise tasks
# ----------------------------------------------------------------------


def _rowwise(X, b, vpu_per_el, xu_per_el, streams):
    seq, dim = X["seq"], X["dim"]
    rows = _tile_sizes(seq, max(1, int(X.get("block_rows", 512))))
    n = len(rows)
    return TaskArray.build(
        n,
        vpu=vpu_per_el * rows * dim,
        xu=xu_per_el * rows * dim if xu_per_el >= 1 else rows,
        hbm=streams * rows * dim * b,
        vmem=streams * rows * dim * b,
        align=_util(rows, 8) * _util([dim], 128)[0],
        ws=streams * rows * dim * b,
    )


def decompose_rmsnorm(X: dict, hw: TPUSpec) -> TaskArray:
    return _rowwise(X, X.get("dtype_bytes", 2), 4.0, 0.0, 2.0)


def decompose_silu_mul(X: dict, hw: TPUSpec) -> TaskArray:
    return _rowwise(X, X.get("dtype_bytes", 2), 3.0, 1.0, 3.0)


# ----------------------------------------------------------------------
# Fused MoE (grouped GEMM, §VII case study): per-(expert, m-tile) tasks with
# ragged token counts from routing — software work-queue scheduled. block_m /
# block_f / stages are the tunable config (paper's BLOCK_SIZE / num_warps /
# num_stages).
# ----------------------------------------------------------------------


def routing_counts(M: int, E: int, topk: int, skew: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    w = rng.dirichlet(np.full(E, max(0.05, 10.0 * (1.0 - skew))))
    counts = np.floor(w * M * topk).astype(np.int64)
    rem = M * topk - counts.sum()
    counts[np.argsort(-w)[: int(rem)]] += 1
    return counts


def default_moe_config(X: dict, hw: TPUSpec) -> dict:
    """The production kernel's config-selection logic. Tuned for the v5e
    sweet spot — deliberately *not* revisited per generation, which is the
    implementation shortcoming the paper's §VII diagnoses (its Triton kernel
    was ill-suited to the A40)."""
    return {"block_m": 128, "block_f": 512, "stages": 2}


def decompose_fused_moe(X: dict, hw: TPUSpec) -> TaskArray:
    M, E, topk = X["M"], X["E"], X["topk"]
    H, N = X["H"], X["N"]
    b = X.get("dtype_bytes", 2)
    cfgd = default_moe_config(X, hw)
    bm = X.get("block_m", cfgd["block_m"])
    bf = X.get("block_f", cfgd["block_f"])
    bf = min(bf, N)
    counts = routing_counts(M, E, topk, X.get("skew", 0.3), X.get("seed", 0))
    sizes = []
    for c in counts:
        c = int(c)
        if c:
            sizes.append(_tile_sizes(c, bm))
    if not sizes:
        return TaskArray.build(0)
    m = np.concatenate(sizes)
    n = len(m)
    # per m-tile: all three expert matrices streamed once (weight-dominated)
    w_bytes = 3.0 * H * N * b
    # the kernel's inner F loop re-updates the (m, H) f32 accumulator scratch
    # once per extra f-block — the VMEM cost of choosing a small block_f
    n_f = math.ceil(N / bf)
    acc = (n_f - 1) * m * H * 8.0
    return TaskArray.build(
        n,
        mxu=2.0 * m * 3.0 * H * N,
        xu=m * N,
        vpu=2.0 * m * N,
        hbm=w_bytes + (2.0 * m * H + m * N) * b,
        vmem=w_bytes + (2.0 * m * H + m * N) * b + m * H * 4 + acc,
        align=_util(m, 8) * _util([min(bf, N)], 128)[0],
        ws=(bm * H + (H + bm) * bf) * b * X.get("stages", cfgd["stages"]) + bm * H * 4,
    )


# ----------------------------------------------------------------------
# Expert-parallel dispatch/combine all-to-all (collective payload model).
# Not a kernel family: EP traffic is priced by the comm half of every
# backend (CommRegressor / hwsim.simulate_comm), but the *payload* is a
# dimension-derived analytical quantity exactly like the task demands
# above, so it lives with the decomposer.
# ----------------------------------------------------------------------


#: bytes per element of the compute dtypes the model zoo runs in — the
#: dtype the dispatched activations cross the EP axis as (shared by the
#: e2e workload generator and the dry-run ledger so the two can't drift)
COMPUTE_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4}


def moe_dispatch_geometry(
    T: int, E: int, topk: int, capacity_factor: float, moe_group: int
) -> tuple:
    """``(G, Sg, C)`` of the dense GSPMD/GShard MoE dispatch for ``T``
    tokens: ``G`` dispatch groups of ``Sg`` tokens (the largest divisor of
    ``T`` that is <= ``moe_group``), each with per-expert capacity
    ``C = max(ceil(Sg * topk / E * capacity_factor), topk)``.

    This is the decomposer's *independent* statement of the geometry the
    model layer executes (``repro.models.moe.dispatch_geometry``);
    ``tests/test_parallelism.py`` and ``benchmarks/bench_parallelism.py``
    pin the two byte-for-byte against ``launch.dryrun``'s model-derived
    count on every MoE arch, so drift in either breaks CI.
    """
    Sg = next(g for g in range(min(moe_group, T), 0, -1) if T % g == 0)
    C = max(int(math.ceil(Sg * topk / E * capacity_factor)), topk)
    return T // Sg, Sg, C


def ep_alltoall_bytes(X: dict) -> float:
    """Payload bytes of ONE expert-parallel all-to-all hop (dispatch and
    combine are symmetric): the full dispatched-activation tensor
    ``(G, E, C, d)`` in the compute dtype — the tensor the EP mesh axis
    actually re-shards, and the quantity ``launch.dryrun
    .count_ep_alltoall_bytes`` counts from the model implementation.

    ``X`` keys: ``T`` (tokens in the step), ``d`` (model dim), ``E``
    (experts), ``topk``, ``capacity_factor`` (the *serving* factor — e2e
    passes ``max(cfg.capacity_factor, 2.0)`` to match the model's
    inference capacity), ``moe_group``, optional ``dtype_bytes`` (2).
    The returned bytes are the whole-tensor payload; per-chip traffic is
    the comm model's concern (``simulate_comm`` applies the ``(n-1)/n``
    cross-chip fraction for balanced all-to-alls).
    """
    G, _, C = moe_dispatch_geometry(
        int(X["T"]), int(X["E"]), int(X["topk"]),
        float(X["capacity_factor"]), int(X["moe_group"]),
    )
    return float(G * int(X["E"]) * C * int(X["d"]) * X.get("dtype_bytes", 2))


DECOMPOSERS = {
    "gemm": decompose_gemm,
    "scaled_mm": decompose_scaled_mm,
    "attention": decompose_attention,
    "rmsnorm": decompose_rmsnorm,
    "silu_mul": decompose_silu_mul,
    "fused_moe": decompose_fused_moe,
}

# which scheduling paradigm each family uses (paper Table V HW/SW column)
SCHED_POLICY = {
    "gemm": "static",
    "scaled_mm": "static",
    "attention": "static",
    "rmsnorm": "static",
    "silu_mul": "static",
    "fused_moe": "workqueue",
}


def decompose(kind: str, X: dict, hw: TPUSpec) -> TaskArray:
    return DECOMPOSERS[kind](X, hw)
