"""hwsim — pipeline-level TPU timing oracle (the "physical hardware" of this
container; see DESIGN.md §2).

Strictly richer than the Feature Analyzer's first-order view: it models the
microarchitectural frictions the paper's MLP is supposed to learn —

  * MXU tile-alignment losses (ragged tiles vs the 128x128 systolic array),
  * imperfect MXU<->VPU overlap (cross-pipeline coupling, gen-dependent),
  * imperfect DMA/compute overlap (double-buffering quality, gen-dependent;
    improved by the fused-MoE ``stages`` config),
  * VMEM working-set pressure and spill,
  * per-tile pipeline fill/drain overhead amortized over the tile stream,
  * per-chip load imbalance (the scheduler's partition is taken as-is),
  * kernel launch overhead and deterministic measurement noise (+-3%).

The Estimator NEVER sees these internals — only the analytical features.
Baselines are scored against the same oracle. Its absolute scale is
calibrated to TPU-class numbers but is synthetic; the paper's experimental
structure (seen/unseen hardware, per-kernel MLPs, quantile ceilings) is what
is reproduced, not vendor-measured milliseconds.
"""
from __future__ import annotations

import hashlib
from functools import lru_cache

import numpy as np

from repro.core.decomposer import (
    SCHED_POLICY,
    decompose,
    default_moe_config,
    routing_counts,
)
from repro.core.hardware import TPUSpec
from repro.core.scheduler import schedule

# per-generation friction parameters (never exposed to the estimator)
GEN_FRICTION = {
    "v4": dict(gamma_cp=0.35, gamma_mo=0.30, fill=3500.0, spill=2.2, ramp=0.92),
    "v5e": dict(gamma_cp=0.16, gamma_mo=0.12, fill=2000.0, spill=1.6, ramp=0.97),
    "v5p": dict(gamma_cp=0.14, gamma_mo=0.10, fill=1800.0, spill=1.5, ramp=0.97),
    "v6e": dict(gamma_cp=0.22, gamma_mo=0.09, fill=1500.0, spill=1.4, ramp=0.95),
    "v7": dict(gamma_cp=0.08, gamma_mo=0.06, fill=1200.0, spill=1.3, ramp=0.99),
}


def _noise(kind: str, X: dict, hw: TPUSpec, amp: float = 0.03) -> float:
    key = f"{kind}|{sorted(X.items())}|{hw.name}".encode()
    h = int.from_bytes(hashlib.sha256(key).digest()[:8], "little")
    rng = np.random.default_rng(h)
    return float(1.0 + amp * rng.standard_normal())


# tunable config keys the simulator prices per kernel family; passing any
# other key raises instead of being silently ignored (the old tuner searched
# a phantom knob for families whose config dict was dropped entirely)
CONFIG_KEYS = {
    "fused_moe": {"block_m", "block_f", "stages"},
    "gemm": {"block_m", "block_n", "block_k"},
    "scaled_mm": {"block_m", "block_n", "block_k"},
    "attention": {"block_q", "block_k"},
    "rmsnorm": {"block_rows"},
    "silu_mul": {"block_rows"},
}


def simulate(kind: str, X: dict, hw: TPUSpec, config: dict | None = None) -> float:
    """Simulated kernel latency in seconds. ``config`` carries tunable
    kernel block choices (``CONFIG_KEYS``); they reach the decomposer as
    workload keys, so tiling, alignment and working sets all respond."""
    Xs = dict(X)
    if config:
        unknown = set(config) - CONFIG_KEYS.get(kind, set())
        if unknown:
            raise ValueError(
                f"hwsim.simulate({kind!r}): unknown config keys {sorted(unknown)}; "
                f"tunable: {sorted(CONFIG_KEYS.get(kind, set()))}"
            )
        Xs.update(config)
    if kind == "fused_moe":
        cfgd = default_moe_config(X, hw)
        for k, v in cfgd.items():
            Xs.setdefault(k, v)
    tasks = decompose(kind, Xs, hw)
    if len(tasks) == 0:
        return hw.launch_us * 1e-6
    chip_of = schedule(SCHED_POLICY[kind], tasks, hw)
    fr = GEN_FRICTION[hw.generation]

    # ---- per-task pipe cycles -----------------------------------------
    mxu_thr = hw.mxu_flops_per_cycle * fr["ramp"]
    if Xs.get("int8") or kind == "scaled_mm":
        mxu_thr = mxu_thr * 2.0
    mxu_c = tasks.mxu / (mxu_thr * np.maximum(tasks.align, 1e-3))
    vec_c = tasks.vpu / hw.vpu_ops_per_cycle + tasks.xu / hw.xu_ops_per_cycle
    compute = np.maximum(mxu_c, vec_c) + fr["gamma_cp"] * np.minimum(mxu_c, vec_c)

    hbm_c = tasks.hbm / hw.hbm_bytes_per_cycle
    vmem_c = tasks.vmem / hw.vmem_bytes_per_cycle
    pressure = tasks.ws / (hw.vmem_mb * 2**20 * 0.8)
    spill = 1.0 + np.maximum(pressure - 0.6, 0.0) * fr["spill"]
    mem = np.maximum(hbm_c, vmem_c) * spill

    gamma_mo = fr["gamma_mo"]
    if kind == "fused_moe":
        stages = Xs.get("stages", 2)
        gamma_mo = gamma_mo * {1: 2.2, 2: 1.0, 3: 0.62, 4: 0.48}.get(stages, 1.0)
    t_task = np.maximum(compute, mem) + gamma_mo * np.minimum(compute, mem)

    # ---- per-chip timeline ---------------------------------------------
    n = hw.num_chips
    chip_time = np.bincount(chip_of, weights=t_task, minlength=n)
    counts = np.bincount(chip_of, minlength=n)
    # pipeline fill/drain: first tile pays full latency; later tiles hide
    # most of it behind double-buffered DMA
    chip_time = chip_time + fr["fill"] * (counts > 0) + 0.15 * fr["fill"] * np.maximum(counts - 1, 0)

    cycles = float(chip_time.max())
    seconds = cycles / (hw.clock_ghz * 1e9) + hw.launch_us * 1e-6
    return seconds * _noise(kind, Xs, hw)


# ----------------------------------------------------------------------
# communication oracle (E2E distributed prediction, paper §V-D)
# ----------------------------------------------------------------------


@lru_cache(maxsize=None)
def a2a_hot_ratio(skew: float, n_chips: int) -> float:
    """Hot-chip serialization factor of a routing-skewed all-to-all:
    ``max chip load / mean chip load`` under the same dirichlet routing
    model the fused-MoE decomposition and the dry-run EP ledger use
    (``decomposer.routing_counts``) — one expert group per chip, averaged
    over the ledger's seed range so the factor is deterministic.

    Exactly 1.0 at ``skew <= 0`` (balanced traffic — the legacy fixed
    contention model), monotonically growing with skew: the hottest
    chip's excess traffic serializes the exchange because every other
    chip must wait for it to drain. Bounded by ``n_chips`` (one chip
    receiving everything).
    """
    if skew <= 0.0 or n_chips <= 1:
        return 1.0
    ratios = []
    for seed in range(8):  # the dry-run ledger's seed convention
        counts = routing_counts(M=4096, E=n_chips, topk=1,
                                skew=float(skew), seed=seed)
        ratios.append(counts.max() / counts.mean())
    return float(np.mean(ratios))


def simulate_comm(
    op: str, nbytes: float, n_chips: int, hw: TPUSpec, skew: float = 0.0
) -> float:
    """alpha-beta collective time over the slice's ICI with contention
    friction and noise.

    ``skew`` (all_to_all only) is the routing-imbalance of the payload:
    the balanced ``(n-1)/n`` exchange is stretched by the hot-chip ratio
    :func:`a2a_hot_ratio` — at ``skew=0`` this reproduces the legacy
    fixed contention factor exactly.
    """
    if n_chips <= 1 or nbytes <= 0:
        return 0.0
    bw = hw.ici_gbps * 1e9 * hw.ici_links
    # all_to_all: every chip keeps 1/n of the payload and ships the rest —
    # the balanced EP dispatch/combine pattern (nbytes is the whole tensor)
    steps = {"all_reduce": 2.0 * (n_chips - 1) / n_chips,
             "all_gather": (n_chips - 1) / n_chips,
             "reduce_scatter": (n_chips - 1) / n_chips,
             "all_to_all": (n_chips - 1) / n_chips,
             "p2p": 1.0}[op]
    alpha = 4e-6 + 0.5e-6 * np.log2(max(n_chips, 2))
    beta = nbytes * steps / bw
    contention = (1.0 + 0.12 * (n_chips > 8) + 0.05 * (op == "all_reduce")
                  + 0.08 * (op == "all_to_all"))
    if op == "all_to_all" and skew > 0.0:
        beta *= a2a_hot_ratio(skew, n_chips)
    t = alpha + beta * contention
    return float(t * _noise(op, {"b": int(nbytes), "n": n_chips}, hw, amp=0.05))
