"""PipeWeave-TPU: the paper's contribution as a composable library.

decompose -> schedule -> featurize -> estimate, plus the hwsim oracle,
baselines, E2E workload generator, quantile ceilings and the autotuner.
"""
