"""Simulated accelerator registry — the TPU analogue of the paper's Table VI.

11 variants across 4 "generations"; 6 are used for training the estimator and
5 are held out as *unseen hardware* (the paper's generalization split).
Real-generation entries use public TPU numbers; the hypothetical entries fill
the compute-to-memory-ratio spectrum the paper probes with H20 (low compute /
high bandwidth) vs H800 (the opposite).

The paper's per-GPU quantities map as: GPU -> inference slice, SM -> chip
(the parallel scheduling unit), pipelines -> MXU / VPU / XU(transcendental) /
HBM / VMEM / ICI. See DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    generation: str
    num_chips: int  # chips in the modeled slice (the "SM count" analogue)
    clock_ghz: float
    mxu_flops_per_cycle: float  # bf16 flops / cycle / chip (MACs*2)
    vpu_ops_per_cycle: float  # fp32 vector lanes ops / cycle / chip
    xu_ops_per_cycle: float  # transcendental ops / cycle / chip
    hbm_gbps: float  # GB/s per chip
    vmem_mb: float
    vmem_gbps: float  # GB/s per chip (on-chip)
    ici_gbps: float  # GB/s per link
    ici_links: int
    launch_us: float  # per-kernel dispatch overhead
    seen: bool
    #: list price in $/chip-hour (the placement layer's cost axis; a slice
    #: costs ``usd_per_chip_hour * num_chips`` per hour). None = unpriced:
    #: cost objectives skip the entry with a warning (see
    #: ``repro.predict.objective``). Deliberately NOT part of
    #: :meth:`as_vector` — price is a procurement fact, not a performance
    #: feature, so the estimator never sees it.
    usd_per_chip_hour: Optional[float] = None

    @property
    def peak_tflops(self) -> float:
        return self.mxu_flops_per_cycle * self.clock_ghz * 1e9 / 1e12

    @property
    def usd_per_slice_hour(self) -> Optional[float]:
        """Price of the whole modeled slice (all ``num_chips`` chips)."""
        if self.usd_per_chip_hour is None:
            return None
        return self.usd_per_chip_hour * self.num_chips

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_gbps * 1e9 / (self.clock_ghz * 1e9)

    @property
    def vmem_bytes_per_cycle(self) -> float:
        return self.vmem_gbps * 1e9 / (self.clock_ghz * 1e9)

    def as_vector(self):
        """Normalized spec descriptor fed to the estimator (hardware
        generalization input, paper Table II)."""
        import numpy as np

        return np.array(
            [
                self.num_chips / 16.0,
                self.clock_ghz,
                self.mxu_flops_per_cycle / 2**18,
                self.vpu_ops_per_cycle / 2**11,
                self.xu_ops_per_cycle / 2**8,
                self.hbm_gbps / 1000.0,
                self.vmem_mb / 128.0,
                self.vmem_gbps / 10000.0,
                self.ici_gbps / 100.0,
                self.peak_tflops / (self.hbm_gbps / 1000.0) / 500.0,  # ridge point
                self.launch_us / 10.0,
            ],
            dtype=np.float32,
        )


def _mk(name, gen, chips, clock, tflops, hbm, vmem_mb, seen, *, vpu=2048, xu=256,
        vmem_gbps=None, ici=50.0, links=4, launch=6.0, usd=None):
    return TPUSpec(
        name=name,
        generation=gen,
        num_chips=chips,
        clock_ghz=clock,
        mxu_flops_per_cycle=tflops * 1e12 / (clock * 1e9),
        vpu_ops_per_cycle=vpu,
        xu_ops_per_cycle=xu,
        hbm_gbps=hbm,
        vmem_mb=vmem_mb,
        vmem_gbps=vmem_gbps or hbm * 12.0,
        ici_gbps=ici,
        ici_links=links,
        launch_us=launch,
        seen=seen,
        usd_per_chip_hour=usd,
    )


# name, generation, chips, GHz, bf16 TFLOP/s/chip, HBM GB/s, VMEM MB.
# usd = $/chip-hour: real generations use public on-demand list prices,
# hypothetical entries interpolate within their generation by peak FLOPs.
REGISTRY: dict[str, TPUSpec] = {
    s.name: s
    for s in [
        # ----- seen (training hardware) --------------------------------
        _mk("tpu-v4", "v4", 8, 1.05, 275, 1228, 128, True, launch=8.0, usd=3.22),
        _mk("tpu-v5e", "v5e", 8, 0.94, 197, 819, 128, True, launch=6.0, usd=1.20),
        _mk("tpu-v5p", "v5p", 8, 1.75, 459, 2765, 128, True, links=6, launch=7.0, usd=4.20),
        _mk("tpu-v5e-lite", "v5e", 4, 0.94, 99, 819, 64, True, launch=6.0, usd=0.75),   # H20-like: compute-starved
        _mk("tpu-v6e-half", "v6e", 8, 1.45, 459, 1640, 160, True, launch=5.0, usd=1.70),
        _mk("tpu-v4i", "v4", 4, 1.05, 138, 614, 64, True, launch=8.0, usd=1.80),
        # ----- unseen (held-out hardware) -------------------------------
        _mk("tpu-v6e", "v6e", 8, 1.45, 918, 1640, 160, False, launch=5.0, usd=2.70),    # H800-like: bw-starved
        _mk("tpu-v5e-16", "v5e", 16, 0.94, 197, 819, 128, False, launch=6.0, usd=1.20),
        _mk("tpu-v4-turbo", "v4", 8, 1.30, 340, 1228, 128, False, launch=7.5, usd=3.80),
        _mk("tpu-v6e-lite", "v6e", 4, 1.45, 459, 820, 96, False, launch=5.5, usd=1.55),
        _mk("tpu-v7p", "v7", 8, 1.90, 1250, 3280, 256, False, links=6, launch=4.5, usd=6.80),  # extrapolation
    ]
}


def seen_hw() -> list[TPUSpec]:
    return [s for s in REGISTRY.values() if s.seen]


def unseen_hw() -> list[TPUSpec]:
    return [s for s in REGISTRY.values() if not s.seen]


def get_hw(name: str) -> TPUSpec:
    return REGISTRY[name]
