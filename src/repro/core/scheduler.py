"""Scheduling Simulator — M(tasks, S) -> per-chip partition  (paper §IV-B).

Two paradigms, mapping the paper's HW-vs-SW scheduler split onto TPU:

* ``static``   — XLA SPMD-style contiguous partition of the tile grid across
                 the slice's chips (conventional kernels). Like the paper's
                 round-robin GigaThread model it captures wave quantization
                 (ceil/floor task-count imbalance) plus the *content*
                 imbalance of non-uniform tasks (causal attention).
* ``workqueue``— greedy earliest-finish-first assignment (persistent-kernel /
                 grouped-GEMM work queues, e.g. fused MoE), mirroring the
                 MinHeap tile scheduler the paper replicates for FA3 (§V-A).

Returns ``chip_of``: an int array assigning each task to a chip.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.decomposer import TaskArray
from repro.core.hardware import TPUSpec


def task_weights(tasks: TaskArray, hw: TPUSpec) -> np.ndarray:
    """Dominant-pipe theoretical cycles — the scheduler's cost estimate."""
    return np.maximum.reduce(
        [
            tasks.mxu / hw.mxu_flops_per_cycle,
            tasks.vpu / hw.vpu_ops_per_cycle,
            tasks.xu / hw.xu_ops_per_cycle,
            tasks.hbm / hw.hbm_bytes_per_cycle,
        ]
    )


def schedule_static(tasks: TaskArray, hw: TPUSpec) -> np.ndarray:
    """Contiguous grid partition (how SPMD shards a Pallas grid)."""
    n, total = hw.num_chips, len(tasks)
    base, rem = divmod(total, n)
    counts = np.full(n, base)
    counts[:rem] += 1
    return np.repeat(np.arange(n), counts)


def schedule_workqueue(tasks: TaskArray, hw: TPUSpec) -> np.ndarray:
    """Greedy earliest-finish-first over the global work list (queue order =
    expert-major problem order, like a software tile scheduler)."""
    n = hw.num_chips
    w = task_weights(tasks, hw)
    heap = [(0.0, c) for c in range(n)]
    heapq.heapify(heap)
    chip_of = np.zeros(len(tasks), dtype=np.int64)
    for i in range(len(tasks)):
        load, c = heapq.heappop(heap)
        chip_of[i] = c
        heapq.heappush(heap, (load + w[i], c))
    return chip_of


def schedule(policy: str, tasks: TaskArray, hw: TPUSpec) -> np.ndarray:
    if policy == "workqueue":
        return schedule_workqueue(tasks, hw)
    return schedule_static(tasks, hw)
