"""End-to-end inference prediction (paper §V-D).

The Workload Generator lowers an ArchConfig + request shape + parallelism
into the kernel-invocation sequence a serving engine would issue (sequential
kernel execution, no overlap — the paper's stated assumption), plus the
collective calls of TP/EP/PP. Kernel latencies come from a pluggable
predictor (PipeWeave / baselines); communication from a data-driven
regressor fitted on profiled collectives. The oracle E2E time sums hwsim
kernel times + simulated comm — the "measured serving latency" analogue.

Modeling conventions (documented deviations):
  * one REGISTRY slice = one accelerator unit (the paper's "GPU"); TP/PP
    span units, the slice's chips are the intra-unit parallelism;
  * MoE EP over TP units: each unit runs ~M*topk/tp token-expert pairs on
    E/tp local experts with 2 all-to-all hops;
  * SSM (mamba2/hymba) lowers to the SSD chunked einsum structure expressed
    as gemm + elementwise calls (its MXU/VPU demands), an approximation
    noted in DESIGN.md;
  * decode-phase cost integrates over growing KV via Simpson's rule on
    3 sampled cache lengths (same approximation for oracle and predictors).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import hwsim
from repro.core.dataset import featurize
from repro.core.hardware import TPUSpec


@dataclasses.dataclass
class KernelCall:
    kind: str
    X: dict
    count: int = 1


@dataclasses.dataclass
class CommCall:
    op: str
    nbytes: float
    n_units: int
    count: int = 1


def _gemm(M, N, K, count=1):
    return KernelCall("gemm", {"M": int(M), "N": int(max(N, 1)), "K": int(max(K, 1))}, count)


def layer_calls(cfg: ArchConfig, B: int, qlen: int, kvlen: int, tp: int) -> list:
    """One decoder layer's kernel + comm sequence."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    T = B * qlen
    calls: list = []

    def attn_block():
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, (Hq + 2 * Hkv) * hd // tp, d),
            KernelCall(
                "attention",
                {
                    "bs": B,
                    "nkv": max(Hkv // tp, 1),
                    "group": max(Hq // Hkv, 1),
                    "hd": hd,
                    "qlen": qlen,
                    "kvlen": kvlen,
                    "causal": 1,
                },
            ),
            _gemm(T, d, Hq * hd // tp),
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    def ffn_block(dff):
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, dff // tp, d, count=2),  # gate + up
            KernelCall("silu_mul", {"seq": T, "dim": max(dff // tp, 1)}),
            _gemm(T, d, dff // tp),
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    def ssm_block():
        di, N, Q = cfg.d_inner, cfg.ssm_state, cfg.ssd_chunk
        proj = 2 * di + 2 * cfg.ssm_groups * N + cfg.ssm_heads
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, proj // tp, d),  # in_proj
            # SSD chunked einsums (intra-chunk quadratic + state path)
            _gemm(T, min(Q, max(qlen, 1)), N),  # C B^T scores
            _gemm(T, cfg.ssm_headdim, min(Q, max(qlen, 1))),  # scores @ x
            _gemm(T, cfg.ssm_headdim * N // max(tp, 1), 2),  # state update/out
            KernelCall("silu_mul", {"seq": T, "dim": max(di // tp, 1)}),
            _gemm(T, d, di // tp),  # out_proj
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    fam = cfg.family
    if fam in ("dense", "audio", "vlm"):
        calls += attn_block()
        calls += ffn_block(cfg.d_ff)
        if fam == "vlm" and cfg.cross_every:
            # amortized gated cross-attn layer every (cross_every+1) layers
            frac = 1.0 / cfg.cross_every
            calls.append(
                KernelCall(
                    "attention",
                    {
                        "bs": B,
                        "nkv": max(Hkv // tp, 1),
                        "group": max(Hq // Hkv, 1),
                        "hd": hd,
                        "qlen": qlen,
                        "kvlen": cfg.n_img_tokens,
                        "causal": 0,
                    },
                    count=0 if qlen == 0 else 1,
                )
            )
    elif fam == "moe":
        calls += attn_block()
        calls.append(KernelCall("rmsnorm", {"seq": T, "dim": d}))
        E_unit = max(cfg.n_experts // tp, 1)
        pairs = T * cfg.top_k
        M_unit = max(int(math.ceil(pairs / tp)), 1)
        calls.append(_gemm(T, cfg.n_experts, d))  # router
        if tp > 1:
            calls.append(CommCall("p2p", T * d * 2.0 * cfg.top_k / tp, tp, count=2))
        calls.append(
            KernelCall(
                "fused_moe",
                {
                    "M": M_unit,
                    "E": E_unit,
                    "topk": 1,
                    "H": d,
                    "N": cfg.moe_hidden,
                    "skew": 0.3,
                    "seed": 7,
                },
            )
        )
        if cfg.dense_residual:
            calls += ffn_block(cfg.d_ff)
        if tp > 1:
            calls.append(CommCall("all_reduce", T * d * 2.0, tp))
    elif fam == "ssm":
        calls += ssm_block()
    elif fam == "hybrid":
        calls += attn_block()
        calls += ssm_block()
        calls += ffn_block(cfg.d_ff)
    return calls


def model_calls(cfg: ArchConfig, B: int, qlen: int, kvlen: int, tp: int) -> list:
    calls = []
    per_layer = layer_calls(cfg, B, qlen, kvlen, tp)
    calls.append(("layers", cfg.n_layers, per_layer))
    head = [
        KernelCall("rmsnorm", {"seq": B * qlen, "dim": cfg.d_model}),
        _gemm(B if qlen == 1 else B, cfg.padded_vocab // tp, cfg.d_model),
    ]
    if tp > 1:
        head.append(CommCall("all_gather", B * cfg.padded_vocab // tp * 4.0, tp))
    calls.append(("head", 1, head))
    if cfg.family == "audio":
        enc = layer_calls(
            dataclasses.replace(cfg, family="dense"), B, cfg.enc_frames, cfg.enc_frames, tp
        )
        calls.append(("encoder", cfg.n_enc_layers, enc))
    return calls


# ----------------------------------------------------------------------
# communication regressor (paper: RF on profiled comm database; here a
# log-log regression per op fitted on profiled simulate_comm samples)
# ----------------------------------------------------------------------


class CommRegressor:
    """Profiled-collective database + regression (paper §V-D): per (op,
    participant-count) bucket, fit latency = alpha + beta*bytes on profiled
    samples — the standard alpha-beta structure."""

    def __init__(self):
        self.theta: dict = {}

    _NS = (2, 4, 8, 16)

    def fit(self, hw: TPUSpec, seed: int = 0):
        rng = np.random.default_rng(seed)
        for op in ("all_reduce", "all_gather", "reduce_scatter", "p2p"):
            for n in self._NS:
                rows, ys = [], []
                for _ in range(60):
                    nbytes = float(np.exp(rng.uniform(np.log(1e3), np.log(1e9))))
                    t = hwsim.simulate_comm(op, nbytes, n, hw)
                    rows.append([1.0, nbytes])
                    ys.append(t)
                A = np.asarray(rows)
                y = np.asarray(ys)
                # weight by 1/t: minimize *relative* error so the alpha
                # (latency) regime isn't drowned out by GB-sized samples
                Aw = A / y[:, None]
                self.theta[(op, n)], *_ = np.linalg.lstsq(Aw, np.ones_like(y), rcond=None)
        return self

    def predict(self, op: str, nbytes: float, n: int) -> float:
        if n <= 1 or nbytes <= 0:
            return 0.0
        nb = min(self._NS, key=lambda x: abs(math.log(x) - math.log(max(n, 2))))
        a, b = self.theta[(op, nb)]
        return float(max(a + b * nbytes, 1e-7))


# ----------------------------------------------------------------------
# E2E evaluation
# ----------------------------------------------------------------------


def _sum_calls(calls, kernel_time: Callable, comm_time: Callable) -> float:
    total = 0.0
    for _, reps, seq in calls:
        t = 0.0
        for c in seq:
            if isinstance(c, KernelCall):
                t += c.count * kernel_time(c.kind, c.X)
            else:
                t += c.count * comm_time(c.op, c.nbytes, c.n_units)
        total += reps * t
    return total


def step_time(
    cfg: ArchConfig, B: int, qlen: int, kvlen: int, *, tp: int,
    kernel_time: Callable, comm_time: Callable,
) -> float:
    return _sum_calls(model_calls(cfg, B, qlen, kvlen, tp), kernel_time, comm_time)


def request_latency(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    kernel_time: Callable, comm_time: Callable,
) -> float:
    """prefill + Simpson-integrated decode, with a GPipe-style PP surcharge."""
    pre = step_time(cfg, B, lin, lin, tp=tp, kernel_time=kernel_time, comm_time=comm_time)
    d0 = step_time(cfg, B, 1, lin, tp=tp, kernel_time=kernel_time, comm_time=comm_time)
    dm = step_time(cfg, B, 1, lin + lout // 2, tp=tp, kernel_time=kernel_time, comm_time=comm_time)
    d1 = step_time(cfg, B, 1, lin + lout, tp=tp, kernel_time=kernel_time, comm_time=comm_time)
    dec = lout * (d0 + 4 * dm + d1) / 6.0
    total = pre + dec
    if pp > 1:
        # stage boundary activations, per token step and per prefill
        boundary = (pp - 1) * (B * cfg.d_model * 2.0)
        total += comm_time("p2p", boundary * lin, 2) + lout * comm_time("p2p", boundary, 2)
        total *= 1.0 + 0.5 * (pp - 1) / pp  # bubble surcharge (single request)
    return total


def oracle_times(hw: TPUSpec):
    """(kernel_time, comm_time) backed by hwsim — the 'measured' system."""
    return (
        lambda kind, X: hwsim.simulate(kind, X, hw),
        lambda op, b, n: hwsim.simulate_comm(op, b, n, hw),
    )


def predictor_times(pw, hw: TPUSpec, comm: CommRegressor):
    return (
        lambda kind, X: pw.predict_latency(kind, X, hw),
        lambda op, b, n: comm.predict(op, b, n),
    )
