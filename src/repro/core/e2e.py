"""End-to-end inference prediction (paper §V-D).

The Workload Generator lowers an ArchConfig + request shape + parallelism
into the kernel-invocation sequence a serving engine would issue, plus the
collective calls of TP/EP/PP. The default pricing is additive (sequential
kernel execution — the paper's stated assumption); ``comm_overlap=True``
re-prices collectives against the cross-pipeline exposed-compute window
(``Estimate.overlapped``), bounded between pure compute and the additive
sum. Latency estimation is delegated to a
``repro.predict`` backend: ``request_estimate(cfg, ..., predictor=p)``
returns an ``Estimate`` with the total plus per-family/per-op breakdown and
the analytical ceiling; ``step_time``/``request_latency`` are the scalar
views, ``request_sweep`` prices the same request on many hardware at
once (``repro.predict.sweep``), and ``place_request`` ranks the fleet for
it under a placement objective (``repro.serve.placement``). The legacy ``kernel_time``/``comm_time``
two-lambda kwargs are kept as a deprecation shim (wrapped in
``CallableTimesPredictor``).

Modeling conventions (documented deviations):
  * one REGISTRY slice = one accelerator unit (the paper's "GPU"); TP/PP
    span units, the slice's chips are the intra-unit parallelism;
  * MoE EP over TP units: each unit runs ~M*topk/tp token-expert pairs on
    E/tp local experts; dispatch and combine are first-class
    ``CommCall("all_to_all", ...)``s whose payload is the dispatched
    (G, E, C, d) tensor — byte-exact against the executed model layer
    (``decomposer.ep_alltoall_bytes`` == ``dryrun.count_ep_alltoall_bytes``);
  * PP bubbles are the exact tick counts of the executed
    ``dist.pipeline`` schedules (GPipe, interleaved 1F1B, or zero-bubble
    ZB-H1), see ``pp_bubble``;
  * SSM (mamba2/hymba) lowers to the SSD chunked einsum structure expressed
    as gemm + elementwise calls (its MXU/VPU demands), an approximation
    noted in DESIGN.md;
  * decode-phase cost integrates over growing KV via Simpson's rule on
    3 sampled cache lengths (same approximation for oracle and predictors).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

from repro.configs.base import ArchConfig
from repro.core import hwsim
from repro.core.decomposer import COMPUTE_DTYPE_BYTES, ep_alltoall_bytes
from repro.core.hardware import TPUSpec

# call types + comm regressor live in the predict layer now; re-exported
# here for backward compatibility with pre-ISSUE-2 imports
from repro.predict.api import CommCall, Estimate, KernelCall  # noqa: F401
from repro.predict.backends import CallableTimesPredictor, get_predictor
from repro.predict.comm import CommRegressor  # noqa: F401
from repro.predict.sweep import SweepPredictor, SweepResult, check_prebuilt_exclusive


def _gemm(M, N, K, count=1):
    return KernelCall("gemm", {"M": int(M), "N": int(max(N, 1)), "K": int(max(K, 1))}, count)


def layer_calls(cfg: ArchConfig, B: int, qlen: int, kvlen: int, tp: int) -> list:
    """One decoder layer's kernel + comm sequence."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    T = B * qlen
    calls: list = []

    def attn_block():
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, (Hq + 2 * Hkv) * hd // tp, d),
            KernelCall(
                "attention",
                {
                    "bs": B,
                    "nkv": max(Hkv // tp, 1),
                    "group": max(Hq // Hkv, 1),
                    "hd": hd,
                    "qlen": qlen,
                    "kvlen": kvlen,
                    "causal": 1,
                },
            ),
            _gemm(T, d, Hq * hd // tp),
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    def ffn_block(dff):
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, dff // tp, d, count=2),  # gate + up
            KernelCall("silu_mul", {"seq": T, "dim": max(dff // tp, 1)}),
            _gemm(T, d, dff // tp),
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    def ssm_block():
        di, N, Q = cfg.d_inner, cfg.ssm_state, cfg.ssd_chunk
        proj = 2 * di + 2 * cfg.ssm_groups * N + cfg.ssm_heads
        out = [
            KernelCall("rmsnorm", {"seq": T, "dim": d}),
            _gemm(T, proj // tp, d),  # in_proj
            # SSD chunked einsums (intra-chunk quadratic + state path)
            _gemm(T, min(Q, max(qlen, 1)), N),  # C B^T scores
            _gemm(T, cfg.ssm_headdim, min(Q, max(qlen, 1))),  # scores @ x
            _gemm(T, cfg.ssm_headdim * N // max(tp, 1), 2),  # state update/out
            KernelCall("silu_mul", {"seq": T, "dim": max(di // tp, 1)}),
            _gemm(T, d, di // tp),  # out_proj
        ]
        if tp > 1:
            out.append(CommCall("all_reduce", T * d * 2.0, tp))
        return out

    fam = cfg.family
    if fam in ("dense", "audio", "vlm"):
        calls += attn_block()
        calls += ffn_block(cfg.d_ff)
        if fam == "vlm" and cfg.cross_every:
            # amortized gated cross-attn layer every (cross_every+1) layers
            frac = 1.0 / cfg.cross_every
            calls.append(
                KernelCall(
                    "attention",
                    {
                        "bs": B,
                        "nkv": max(Hkv // tp, 1),
                        "group": max(Hq // Hkv, 1),
                        "hd": hd,
                        "qlen": qlen,
                        "kvlen": cfg.n_img_tokens,
                        "causal": 0,
                    },
                    count=0 if qlen == 0 else 1,
                )
            )
    elif fam == "moe":
        calls += attn_block()
        calls.append(KernelCall("rmsnorm", {"seq": T, "dim": d}))
        E_unit = max(cfg.n_experts // tp, 1)
        pairs = T * cfg.top_k
        M_unit = max(int(math.ceil(pairs / tp)), 1)
        calls.append(_gemm(T, cfg.n_experts, d))  # router
        # EP dispatch/combine: the expert dim shards over the tp units, so
        # routed tokens cross the mesh twice as all-to-alls. The payload is
        # the dispatched-activation tensor (G, E, C, d) — the exact bytes
        # launch.dryrun.count_ep_alltoall_bytes derives from the executed
        # model layer (serving capacity: max(capacity_factor, 2.0), the
        # inference branch of models.moe._capacity).
        if tp > 1:
            a2a = ep_alltoall_bytes(
                {
                    "T": T,
                    "d": d,
                    "E": cfg.n_experts,
                    "topk": cfg.top_k,
                    "capacity_factor": max(cfg.capacity_factor, 2.0),
                    "moe_group": cfg.moe_group,
                    "dtype_bytes": COMPUTE_DTYPE_BYTES[cfg.compute_dtype],
                }
            )
            # the routed payload inherits the fused-MoE workload's routing
            # skew (same dirichlet model), so the comm oracle prices the
            # hot-chip serialization instead of a balanced exchange
            calls.append(CommCall("all_to_all", a2a, tp, skew=0.3))  # dispatch
        calls.append(
            KernelCall(
                "fused_moe",
                {
                    "M": M_unit,
                    "E": E_unit,
                    "topk": 1,
                    "H": d,
                    "N": cfg.moe_hidden,
                    "skew": 0.3,
                    "seed": 7,
                },
            )
        )
        if tp > 1:
            calls.append(CommCall("all_to_all", a2a, tp, skew=0.3))  # combine
        if cfg.dense_residual:
            calls += ffn_block(cfg.d_ff)
    elif fam == "ssm":
        calls += ssm_block()
    elif fam == "hybrid":
        calls += attn_block()
        calls += ssm_block()
        calls += ffn_block(cfg.d_ff)
    return calls


def apply_tuned(calls: list, tuned: Optional[dict]) -> list:
    """Merge a tuned block table (``repro.tune.TunedConfigs.for_hw(hw)``:
    kernel family -> block kwargs) into every matching kernel call's
    workload. Keys already present in a call's ``X`` win, so explicit
    per-call choices are never overridden; calls of untuned families pass
    through untouched."""
    if not tuned:
        return calls
    out: list = []
    for item in calls:
        if isinstance(item, KernelCall):
            blocks = tuned.get(item.kind)
            if blocks:
                item = KernelCall(
                    item.kind,
                    {**{k: int(v) for k, v in blocks.items()}, **item.X},
                    item.count,
                )
            out.append(item)
        elif isinstance(item, CommCall):
            out.append(item)
        else:  # (label, reps, sub-sequence) group
            label, reps, seq = item
            out.append((label, reps, apply_tuned(seq, tuned)))
    return out


def model_calls(
    cfg: ArchConfig, B: int, qlen: int, kvlen: int, tp: int,
    tuned: Optional[dict] = None,
) -> list:
    calls = []
    per_layer = layer_calls(cfg, B, qlen, kvlen, tp)
    calls.append(("layers", cfg.n_layers, per_layer))
    # LM head over every position: B*qlen tokens in prefill, B in decode
    head_tokens = B * qlen if qlen > 1 else B
    head = [
        KernelCall("rmsnorm", {"seq": B * qlen, "dim": cfg.d_model}),
        _gemm(head_tokens, cfg.padded_vocab // tp, cfg.d_model),
    ]
    if tp > 1:
        head.append(CommCall("all_gather", head_tokens * cfg.padded_vocab // tp * 4.0, tp))
    calls.append(("head", 1, head))
    # the audio encoder runs once per request, at prefill — decode steps
    # (qlen == 1) reuse its output, so they must not re-price it
    if cfg.family == "audio" and qlen > 1:
        enc = layer_calls(
            dataclasses.replace(cfg, family="dense"), B, cfg.enc_frames, cfg.enc_frames, tp
        )
        calls.append(("encoder", cfg.n_enc_layers, enc))
    return apply_tuned(calls, tuned)


def pp_boundary_hops(pp: int, schedule: str = "gpipe", interleave: int = 2) -> int:
    """Device hops an activation makes crossing stage boundaries: GPipe's
    contiguous placement crosses ``pp - 1``; the interleaved 1F1B placement
    routes every activation through all ``pp * interleave`` chunks, i.e.
    ``pp * interleave - 1`` ring hops. ZB-H1 keeps the 1F1B ring but the
    split backward (B then W ticks) re-crosses each chunk boundary with the
    input-grad wave, doubling boundary traffic to ``2*pp*interleave - 1``
    (the forward's ``pp*interleave - 1`` plus one B-phase hop per chunk).
    Single source of truth for ``request_calls`` and
    ``serve.trace.TraceRecorder``."""
    if pp <= 1:
        return 0
    if schedule == "zb-h1":
        return 2 * pp * interleave - 1
    return pp * interleave - 1 if schedule == "1f1b" else pp - 1


def request_calls(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_interleave: int = 2,
    tuned: Optional[dict] = None,
) -> list:
    """The full request's call sequence: prefill + Simpson-weighted decode
    samples (3 cache lengths integrate the growing KV) + PP stage-boundary
    activations. One batched ``Predictor.predict`` over this sequence
    replaces 4 ``step_time`` passes.

    Stage-boundary traffic follows the schedule: GPipe crosses ``pp - 1``
    boundaries per token; the interleaved 1F1B placement
    (``pp_schedule="1f1b"``) routes every activation through
    ``pp * pp_interleave - 1`` chunk boundaries, all of them device hops
    on the pipeline ring (``dist.pipeline``)."""
    groups = [("prefill", 1.0, model_calls(cfg, B, lin, lin, tp, tuned))]
    for label, w, kvlen in (
        ("decode_start", lout / 6.0, lin),
        ("decode_mid", 4.0 * lout / 6.0, lin + lout // 2),
        ("decode_end", lout / 6.0, lin + lout),
    ):
        groups.append((label, w, model_calls(cfg, B, 1, kvlen, tp, tuned)))
    if pp > 1:
        # stage boundary activations, per token step and per prefill
        boundary = pp_boundary_hops(pp, pp_schedule, pp_interleave) * (
            B * cfg.d_model * 2.0
        )
        groups.append(
            ("pp_boundary", 1.0, [
                CommCall("p2p", boundary * lin, 2),
                CommCall("p2p", boundary, 2, count=lout),
            ])
        )
    return groups


# ----------------------------------------------------------------------
# E2E evaluation
# ----------------------------------------------------------------------


def pp_bubble(
    pp: int,
    n_micro: Optional[int] = None,
    schedule: str = "gpipe",
    interleave: int = 2,
) -> float:
    """Pipeline bubble surcharge factor: executed schedule length over
    ideal per-device work, from the exact tick counts of
    ``dist.pipeline.schedule_ticks`` (validated against the executed
    ``shard_map`` schedules — see ``tests/test_parallelism.py``).

    ``n_micro`` defaults to ``2 * pp`` microbatches, the production
    convention this repo schedules requests at. For GPipe that default
    reduces to ``1 + (pp - 1) / (2 * pp)`` — numerically identical to the
    pre-ISSUE-5 heuristic surcharge, so existing estimates are unchanged;
    the interleaved 1F1B schedule (``schedule="1f1b"``) divides the
    fill/drain cost by ``interleave`` and is strictly cheaper whenever
    ``pp > 1``; the zero-bubble ``"zb-h1"`` splits the backward into B/W
    ticks that fill the warmup bubble, so its surcharge is <= 1F1B's at
    every (pp, n_micro, interleave) (strictly smaller off the
    ``n_micro % pp == 1`` tie region — the ordering theorem in
    ``dist.pipeline``). Returns 1.0 when not pipelined."""
    if pp <= 1:
        return 1.0
    from repro.dist.pipeline import _PHASES, schedule_ticks

    M = 2 * pp if n_micro is None else int(n_micro)
    ticks = schedule_ticks(pp, M, schedule, interleave)
    work = M * (interleave * _PHASES[schedule] if schedule != "gpipe" else 1)
    return ticks / work


# pre-ISSUE-5 private name; the GPipe default is numerically identical
_pp_bubble = pp_bubble


def _resolve_predictor(predictor, kernel_time, comm_time):
    if predictor is not None:
        if kernel_time is not None or comm_time is not None:
            raise TypeError("pass either predictor= or kernel_time/comm_time, not both")
        return predictor
    if kernel_time is None or comm_time is None:
        raise TypeError(
            "no predictor given: pass predictor=get_predictor(...) "
            "(or the legacy kernel_time=/comm_time= callables)"
        )
    return CallableTimesPredictor(kernel_time, comm_time)


def step_estimate(
    cfg: ArchConfig, B: int, qlen: int, kvlen: int, *, tp: int,
    predictor=None, kernel_time: Optional[Callable] = None,
    comm_time: Optional[Callable] = None, tuned: Optional[dict] = None,
) -> Estimate:
    """One serving step (all layers + head) as a full ``Estimate``.
    ``tuned`` (a ``TunedConfigs.for_hw(hw)`` table) prices the step with
    autotuned kernel block configs instead of the defaults."""
    pred = _resolve_predictor(predictor, kernel_time, comm_time)
    return pred.predict(model_calls(cfg, B, qlen, kvlen, tp, tuned))


def step_time(
    cfg: ArchConfig, B: int, qlen: int, kvlen: int, *, tp: int,
    predictor=None, kernel_time: Optional[Callable] = None,
    comm_time: Optional[Callable] = None,
) -> float:
    return step_estimate(
        cfg, B, qlen, kvlen, tp=tp, predictor=predictor,
        kernel_time=kernel_time, comm_time=comm_time,
    ).total_s


def request_estimate(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_microbatches: Optional[int] = None,
    pp_interleave: int = 2, comm_overlap: bool = False,
    predictor=None, kernel_time: Optional[Callable] = None,
    comm_time: Optional[Callable] = None, tuned: Optional[dict] = None,
) -> Estimate:
    """prefill + Simpson-integrated decode as one batched prediction, with
    the schedule's analytical PP bubble surcharge (``pp_bubble``) applied
    to the whole estimate. ``pp_schedule``/``pp_microbatches``/
    ``pp_interleave`` pick the pipeline schedule (GPipe default; the
    interleaved 1F1B of ``dist.pipeline`` shrinks the bubble at the same
    microbatch count, and the zero-bubble ``"zb-h1"`` shrinks it further).
    ``comm_overlap=True`` prices collectives against the exposed-compute
    window (``Estimate.overlapped``) instead of additively — applied
    before the bubble surcharge, which stretches the whole per-step
    timeline. ``tuned`` applies autotuned kernel block configs
    (``repro.tune.TunedConfigs.for_hw(hw)``)."""
    pred = _resolve_predictor(predictor, kernel_time, comm_time)
    est = pred.predict(request_calls(cfg, B, lin, lout, tp=tp, pp=pp,
                                     pp_schedule=pp_schedule,
                                     pp_interleave=pp_interleave,
                                     tuned=tuned))
    if comm_overlap:
        est = est.overlapped()
    if pp > 1:
        est = est.scaled(
            pp_bubble(pp, pp_microbatches, pp_schedule, pp_interleave)
        )
    return est


def request_sweep(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_microbatches: Optional[int] = None,
    pp_interleave: int = 2, comm_overlap: bool = False,
    hws=None, sweep: Optional[SweepPredictor] = None, backend: str = "synperf",
    **backend_kw,
) -> SweepResult:
    """``request_estimate`` across many devices: the same request call
    sequence priced on every hardware in ``hws`` (default: the full
    registry) with one grouping pass and a shared task/feature cache.
    ``comm_overlap=True`` overlap-prices every device's estimate.

    Pass a prebuilt ``sweep=SweepPredictor(...)`` to amortize backend
    construction and cache warmth across requests; otherwise ``backend`` +
    ``**backend_kw`` construct one per call (e.g. ``estimator=pw``)."""
    check_prebuilt_exclusive("sweep", sweep, hws, backend, backend_kw)
    sp = sweep if sweep is not None else SweepPredictor(hws, backend, **backend_kw)
    res = sp.predict(request_calls(cfg, B, lin, lout, tp=tp, pp=pp,
                                   pp_schedule=pp_schedule,
                                   pp_interleave=pp_interleave))
    if comm_overlap:
        res = res.overlapped()
    if pp > 1:
        res = res.scaled(
            pp_bubble(pp, pp_microbatches, pp_schedule, pp_interleave)
        )
    return res


def place_request(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_microbatches: Optional[int] = None,
    pp_interleave: int = 2, comm_overlap: bool = False,
    objective="latency", hws=None, backend: str = "synperf", router=None,
    **backend_kw,
):
    """Route one synthetic request across the hardware fleet: assemble the
    same call sequence as ``request_estimate`` (prefill + Simpson decode +
    PP boundary traffic, bubble surcharge included; ``comm_overlap=True``
    overlap-prices each candidate) and rank every fleet entry under
    ``objective`` (see ``repro.predict.objective``).

    Returns a ``repro.serve.placement.Placement``. Pass a prebuilt
    ``router=FleetRouter(...)`` to amortize backend construction and cache
    warmth across requests (``hws``/``backend``/kwargs then stay unset);
    ``n_tokens`` for per-token objectives is the generated-token count
    ``B * lout``."""
    from repro.serve.placement import FleetRouter

    check_prebuilt_exclusive("router", router, hws, backend, backend_kw)
    rt = router if router is not None else FleetRouter(hws, backend, **backend_kw)
    calls = request_calls(cfg, B, lin, lout, tp=tp, pp=pp,
                          pp_schedule=pp_schedule, pp_interleave=pp_interleave)
    return rt.route(calls, objective=objective, n_tokens=B * lout,
                    scale=pp_bubble(pp, pp_microbatches, pp_schedule,
                                    pp_interleave),
                    overlap=comm_overlap)


def simulate_fleet(
    cfg: ArchConfig, B: int, lin: int, lout: int, *,
    rate_rps: float, n_requests: int,
    tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_microbatches: Optional[int] = None,
    pp_interleave: int = 2,
    objective="latency", replicas=1, seed: int = 0, autoscale=None,
    drift=None, monitor=None,
    hws=None, backend: str = "synperf", router=None,
    **backend_kw,
):
    """Replay a Poisson stream of synthetic requests through the fleet
    with queueing delay: the single-class convenience over
    ``serve.fleet.FleetSimulator`` (mirrors ``place_request``, which this
    extends from isolated pricing to queue-aware p50/p95/p99 latency and
    utilization). ``drift=``/``monitor=`` pass through to
    ``FleetSimulator.replay`` — inject measured-vs-predicted drift and let
    a ``serve.monitor.ResidualMonitor`` re-route the fleet mid-replay
    (the report's ``reroutes`` log records each trip). Returns a
    ``serve.fleet.FleetReport``."""
    from repro.serve.fleet import FleetSimulator, WorkloadClass

    wc = WorkloadClass(
        "request", cfg, B=B, lin=lin, lout=lout, tp=tp, pp=pp,
        pp_schedule=pp_schedule, pp_microbatches=pp_microbatches,
        pp_interleave=pp_interleave,
    )
    sim = FleetSimulator(
        wc, router=router, hws=hws, backend=backend, objective=objective,
        replicas=replicas, autoscale=autoscale, **backend_kw,
    )
    return sim.replay(rate_rps=rate_rps, n_requests=n_requests, seed=seed,
                      drift=drift, monitor=monitor)


def request_latency(
    cfg: ArchConfig, B: int, lin: int, lout: int, *, tp: int = 1, pp: int = 1,
    pp_schedule: str = "gpipe", pp_microbatches: Optional[int] = None,
    pp_interleave: int = 2,
    predictor=None, kernel_time: Optional[Callable] = None,
    comm_time: Optional[Callable] = None,
) -> float:
    return request_estimate(
        cfg, B, lin, lout, tp=tp, pp=pp, pp_schedule=pp_schedule,
        pp_microbatches=pp_microbatches, pp_interleave=pp_interleave,
        predictor=predictor, kernel_time=kernel_time, comm_time=comm_time,
    ).total_s


# ----------------------------------------------------------------------
# deprecated two-lambda constructors (use repro.predict.get_predictor)
# ----------------------------------------------------------------------


def oracle_times(hw: TPUSpec):
    """Deprecated: use ``get_predictor("oracle", hw)``. Returns the legacy
    (kernel_time, comm_time) pair backed by hwsim — the 'measured' system."""
    return (
        lambda kind, X: hwsim.simulate(kind, X, hw),
        lambda op, b, n: hwsim.simulate_comm(op, b, n, hw),
    )


def predictor_times(pw, hw: TPUSpec, comm: CommRegressor):
    """Deprecated: use ``get_predictor("synperf", hw, estimator=pw,
    comm=comm)``. Returns the legacy (kernel_time, comm_time) pair."""
    return get_predictor("synperf", hw, estimator=pw, comm=comm).as_times()
