"""Feature Analyzer — multi-pipeline demand / theoretical-cycle features
(paper §IV-C, Table IV) over the task distribution from the scheduler.

Per pipeline p in {MXU, VPU, XU, HBM, VMEM}:
  * slice-level: total demand, theoretical cycles  N_p / (chips * Th_p)
  * max-chip: demand and theoretical cycles of the most loaded chip
  * imbalance ratio (max-chip / ideal share)
plus pipe-balance ratios and the hardware descriptor vector (Table II
analogue). ``theoretical_cycles`` (dominant pipe at slice level) normalizes
the target: efficiency = theoretical / actual.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.decomposer import TaskArray
from repro.core.hardware import TPUSpec

PIPES = ("mxu", "vpu", "xu", "hbm", "vmem")


def throughput(hw: TPUSpec, pipe: str) -> float:
    """Per-chip per-cycle throughput of pipeline p."""
    return {
        "mxu": hw.mxu_flops_per_cycle,
        "vpu": hw.vpu_ops_per_cycle,
        "xu": hw.xu_ops_per_cycle,
        "hbm": hw.hbm_bytes_per_cycle,
        "vmem": hw.vmem_bytes_per_cycle,
    }[pipe]


@dataclasses.dataclass
class FeatureSet:
    totals: dict
    total_cycles: dict
    max_chip: dict
    max_chip_cycles: dict
    n_tasks: int
    n_chips_used: int
    theoretical_cycles: float
    theoretical_s: float

    def vector(self, hw: TPUSpec) -> np.ndarray:
        eps = 1.0
        lg = lambda x: math.log10(max(x, eps))
        feats = []
        for p in PIPES:
            feats += [
                lg(self.totals[p]),
                lg(self.total_cycles[p]),
                lg(self.max_chip[p]),
                lg(self.max_chip_cycles[p]),
                self.max_chip[p] * hw.num_chips / max(self.totals[p], eps),
            ]
        feats += [
            lg(self.n_tasks),
            self.n_chips_used / hw.num_chips,
            lg(self.theoretical_cycles),
            *[
                self.total_cycles[p] / max(self.theoretical_cycles, eps)
                for p in PIPES
            ],
        ]
        feats += list(hw.as_vector())
        return np.asarray(feats, dtype=np.float32)


FEATURE_DIM = 5 * len(PIPES) + 3 + len(PIPES) + 11


def demand_summary(tasks: TaskArray, chip_of: np.ndarray, n_chips: int) -> tuple:
    """The hardware-independent half of :func:`analyze`: per-pipe total and
    max-chip demand plus chip usage, a function of (tasks, chip_of) only.
    Multi-hardware sweeps cache this per task signature
    (``repro.predict.batching.FeatureCache``) so only the cycle conversions
    below fan out per device."""
    demands = {
        "mxu": tasks.mxu,
        "vpu": tasks.vpu,
        "xu": tasks.xu,
        "hbm": tasks.hbm,
        "vmem": tasks.vmem,
    }
    totals, max_chip = {}, {}
    for p, d in demands.items():
        totals[p] = float(d.sum())
        per_chip = (
            np.bincount(chip_of, weights=d, minlength=n_chips)
            if len(d)
            else np.zeros(n_chips)
        )
        max_chip[p] = float(per_chip.max())
    used = int(len(np.unique(chip_of))) if len(chip_of) else 0
    return totals, max_chip, used, len(tasks)


def analyze_summary(summary: tuple, hw: TPUSpec) -> FeatureSet:
    """Per-hardware cycle conversion of a :func:`demand_summary` — pure
    float math, no task-array traversal."""
    totals, max_chip, used, n_tasks = summary
    max_chip_cycles, total_cycles = {}, {}
    n = hw.num_chips
    for p in PIPES:
        total_cycles[p] = totals[p] / (n * throughput(hw, p))
        max_chip_cycles[p] = max_chip[p] / throughput(hw, p)
    theoretical = max(max(total_cycles.values()), 1.0)
    return FeatureSet(
        totals=totals,
        total_cycles=total_cycles,
        max_chip=max_chip,
        max_chip_cycles=max_chip_cycles,
        n_tasks=n_tasks,
        n_chips_used=used,
        theoretical_cycles=theoretical,
        # kernel dispatch overhead is part of the spec (Table II analogue),
        # so the ideal-time normalizer includes it; without this, tiny
        # kernels collapse to efficiencies ~1e-2 that a sigmoid head cannot
        # resolve relatively
        theoretical_s=theoretical / (hw.clock_ghz * 1e9) + hw.launch_us * 1e-6,
    )


def analyze(tasks: TaskArray, chip_of: np.ndarray, hw: TPUSpec) -> FeatureSet:
    return analyze_summary(demand_summary(tasks, chip_of, hw.num_chips), hw)


def overlap_window_s(kernel_s: float, n_comm_launches: float) -> float:
    """Cross-pipeline exposed-compute window (ISSUE 10): the kernel time
    the network can hide under when a trace's ``n`` collective launches
    are spread through its ``kernel_s`` of compute.

    Model: launches issue uniformly through the compute — launch ``i``
    after ``i/(n+1)`` of it — so the serialized network stream can
    overlap the compute that *follows* its first launch,
    ``kernel_s * n / (n + 1)``. The window is 0 with no launches (nothing
    to overlap), ``kernel_s/2`` for a single mid-trace collective, and
    approaches (but never reaches) ``kernel_s`` as launches densify —
    which is what bounds ``Estimate.overlapped()`` between pure compute
    and the additive estimate. This is the trace-level cross-pipeline
    feature the decomposer's per-kernel pipe demands cannot express: it
    couples the compute pipes' occupancy with the ICI's.
    """
    if kernel_s <= 0.0 or n_comm_launches <= 0.0:
        return 0.0
    return kernel_s * n_comm_launches / (n_comm_launches + 1.0)
