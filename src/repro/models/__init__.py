"""Model zoo: segmented transformer/SSM/MoE stacks and the public ModelApi."""
