"""Mixture-of-Experts layer (top-k, capacity-based Switch/GSPMD dispatch).

Tokens are flattened and re-grouped into dispatch groups of ``cfg.moe_group``
tokens; within each group every expert has capacity
``C = ceil(group * top_k / E * capacity_factor)``. Dispatch/combine are dense
einsums over one-hot masks — the formulation GSPMD shards cleanly with
experts on the "model" axis (EP) and groups on the "data" axis. The einsum
overhead is ~E*C/(k*3*d_ff) of useful FLOPs (<3% at group=512 for the
assigned MoE archs); a sort-based dropless path is a recorded hillclimb item.

Decode (seq==1) collapses to a single group so expert capacity stays tiny.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, ffn, init_ffn


def init_moe(key, cfg: ArchConfig, dtype):
    d, f, E = cfg.d_model, cfg.moe_hidden, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype),
        "w_gate": dense_init(ks[1], (E, d, f), dtype, in_axis=1),
        "w_up": dense_init(ks[2], (E, d, f), dtype, in_axis=1),
        "w_down": dense_init(ks[3], (E, f, d), dtype, in_axis=1),
    }
    if cfg.dense_residual:
        p["dense"] = init_ffn(ks[4], cfg, dtype, d_ff=cfg.d_ff)
    return p


def _capacity(group: int, cfg: ArchConfig, train: bool) -> int:
    cf = cfg.capacity_factor if train else max(cfg.capacity_factor, 2.0)
    c = int(math.ceil(group * cfg.top_k / cfg.n_experts * cf))
    return max(c, cfg.top_k)


def dispatch_geometry(cfg: ArchConfig, T: int, *, train: bool) -> tuple:
    """``(G, Sg, C)`` the executed layer uses for ``T`` tokens: group
    count, group size (largest divisor of ``T`` <= ``cfg.moe_group``) and
    per-expert capacity. This is the single source of truth for the shape
    of the dispatched-activation tensor ``(G, E, C, d)`` — ``moe_layer``
    builds exactly this tensor, and ``launch.dryrun`` counts EP all-to-all
    bytes from it, so the dry-run ledger can never drift from what the
    model actually ships across the expert axis."""
    Sg = next(g for g in range(min(cfg.moe_group, T), 0, -1) if T % g == 0)
    return T // Sg, Sg, _capacity(Sg, cfg, train)


def moe_layer(p, x, cfg: ArchConfig, *, train: bool):
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    G, Sg, _C = dispatch_geometry(cfg, T, train=train)
    xg = xt.reshape(G, Sg, d)

    # ---- routing --------------------------------------------------------
    logits = (xg @ p["router"]).astype(jnp.float32)  # (G, Sg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, K)  # (G, Sg, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # ---- capacity assignment (priority: slot k, then token order) --------
    C = _C
    onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)  # (G, Sg, K, E)
    # rank within expert, counting slot-major: (k, s) flattened with k outer
    flat = jnp.moveaxis(onehot, 2, 1).reshape(G, K * Sg, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat  # tokens ahead of me
    pos = jnp.moveaxis(pos_flat.reshape(G, K, Sg, E), 1, 2)  # (G, Sg, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)  # (G, Sg, K)
    pos = pos.astype(jnp.int32)
    keep = pos < C
    top_w = top_w * keep  # dropped tokens lose their expert

    # ---- dispatch / combine tensors --------------------------------------
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # combine[g, s, e, c] = sum_k w[g,s,k] * onehot_e * onehot_c
    combine = jnp.einsum("gske,gskc->gsec", onehot * top_w[..., None], pos_oh)
    if cfg.moe_bf16_combine:  # §Perf: halve dispatch/combine HBM traffic
        combine = combine.astype(x.dtype)
    dispatch = (combine > 0).astype(x.dtype)

    from repro.dist.sharding import constrain

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (G, E, C, d)
    xe = constrain(xe, ("batch", "experts", None, None))
    # ---- expert FFN (SwiGLU) ---------------------------------------------
    gte = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    act = jax.nn.silu(gte) * up
    ye = jnp.einsum("gecf,efd->gecd", act, p["w_down"].astype(x.dtype))

    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    out = out.reshape(B, S, d)

    # ---- auxiliary load-balancing loss (Switch) ---------------------------
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids[..., 0], E, dtype=jnp.float32), axis=1)
        / Sg,
        axis=0,
    )
    aux = E * jnp.sum(me * ce)

    if cfg.dense_residual:
        out = out + ffn(p["dense"], x, cfg)
    return out, aux
