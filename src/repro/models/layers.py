"""Core neural-net layers shared by the model zoo.

Everything is a pure function over explicit parameter pytrees. Attention is
implemented flash-style (chunked over query blocks with block-local masked
softmax) so peak memory stays bounded for 32k prefill and the pure-jnp path
doubles as the numerical oracle for the Pallas flash-attention kernel.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

# ----------------------------------------------------------------------
# initialisation helpers
# ----------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.normal(key, shape)).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * lax.rsqrt(var + eps) * weight.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(dt)


def init_norm(key, cfg: ArchConfig, d: int, dtype):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.zeros((d,), dtype)}  # rmsnorm stores (scale - 1)


def apply_norm(p, x, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ----------------------------------------------------------------------
# rotary position embeddings (with partial-rotary support)
# ----------------------------------------------------------------------


def rope(x, positions, theta: float, pct: float = 1.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if pct <= 0.0:
        return x
    d = x.shape[-1]
    rot = int(d * pct) // 2 * 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ----------------------------------------------------------------------
# attention (chunked / flash-style, GQA, sliding window, softcap)
# ----------------------------------------------------------------------

NEG_INF = -2.0e38


def _block_attend(
    qb,  # (B, bq, Hkv, G, D)
    k,  # (B, Skv, Hkv, D)
    v,  # (B, Skv, Hkv, D)
    qpos,  # (B, bq) int32
    kpos,  # (B, Skv) int32
    *,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
    kv_valid=None,  # (B, Skv) bool — cache validity
    prefix: int = 0,  # always-visible global prefix (hymba meta tokens)
):
    """Full-row masked attention for one query block. fp32 softmax."""
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qb, k, preferred_element_type=jnp.float32
    )
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((qpos.shape[0], qpos.shape[1], kpos.shape[1]), bool)
    if causal:
        mask &= kpos[:, None, :] <= qpos[:, :, None]
    if window is not None:
        win_ok = kpos[:, None, :] > (qpos[:, :, None] - window)
        if prefix:
            win_ok |= (kpos < prefix)[:, None, :]
        mask &= win_ok
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)  # rows that are fully masked stay finite
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out


def triangular_attention(
    qg,  # (B, Sq, Hkv, G, D) grouped queries
    k,  # (B, Sq, Hkv, D)
    v,
    qpos,  # (B, Sq)
    kpos,  # (B, Sq)
    *,
    softcap: Optional[float],
    scale: float,
    q_block: int,
):
    """Block-sparse causal schedule (§Perf beyond-paper): instead of every
    query block scanning the full KV row (masked-out upper triangle still
    costs FLOPs and score-tensor traffic), scan the STATIC list of
    lower-triangular (q-block, kv-block) pairs — nb(nb+1)/2 block pairs
    instead of nb^2 — with online-softmax state per query block. Halves both
    the causal attention compute and the materialized score bytes.

    Requires Sq == Skv, no window/prefix/validity mask.
    """
    B, Sq, Hkv, G, D = qg.shape
    nb = Sq // q_block
    qb = q_block
    pairs = [(i, j) for i in range(nb) for j in range(i + 1)]
    iqs = jnp.array([p[0] for p in pairs], jnp.int32)
    jks = jnp.array([p[1] for p in pairs], jnp.int32)

    qg_b = jnp.moveaxis(qg.reshape(B, nb, qb, Hkv, G, D), 1, 0)  # (nb,B,qb,Hkv,G,D)
    k_b = jnp.moveaxis(k.reshape(B, nb, qb, Hkv, D), 1, 0)
    v_b = jnp.moveaxis(v.reshape(B, nb, qb, Hkv, D), 1, 0)
    qpos_b = jnp.moveaxis(qpos.reshape(B, nb, qb), 1, 0)
    kpos_b = jnp.moveaxis(kpos.reshape(B, nb, qb), 1, 0)

    f32 = jnp.float32
    m0 = jnp.full((nb, B, Hkv, G, qb, 1), NEG_INF, f32)
    l0 = jnp.zeros((nb, B, Hkv, G, qb, 1), f32)
    a0 = jnp.zeros((nb, B, Hkv, G, qb, D), f32)

    def step(carry, xs):
        m, l, acc = carry
        iq, j = xs
        qt = qg_b[iq]  # (B,qb,Hkv,G,D)
        kt, vt = k_b[j], v_b[j]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qt, kt, preferred_element_type=f32)
        s = s * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos_b[j][:, None, :] <= qpos_b[iq][:, :, None]  # (B,qb,qb)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_prev = m[iq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_new = jnp.maximum(m_new, -1e30)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l[iq] + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vt.dtype), vt).astype(f32)
        a_new = corr * acc[iq] + pv
        return (m.at[iq].set(m_new), l.at[iq].set(l_new), acc.at[iq].set(a_new)), None

    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (iqs, jks))
    out = acc / jnp.maximum(l, 1e-30)  # (nb,B,Hkv,G,qb,D)
    out = jnp.moveaxis(out, 0, 3)  # (B,Hkv,G,nb,qb,D)
    out = out.reshape(B, Hkv, G, Sq, D).transpose(0, 3, 1, 2, 4)
    return out.astype(qg.dtype)


def chunked_attention(
    q,  # (B, Sq, Hq, D)
    k,  # (B, Skv, Hkv, D)
    v,
    qpos,  # (B, Sq)
    kpos,  # (B, Skv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_block: int = 512,
    kv_valid=None,
    prefix: int = 0,
    flash_remat: bool = False,
    causal_sparse: bool = False,
):
    """Flash-style attention: scan over query blocks; each block sees either
    the full KV row (global) or a statically-sized sliding slice (local), so
    peak memory is O(bq * Skv) instead of O(Sq * Skv).

    flash_remat: rematerialize each block's scores/probabilities in the
    backward pass (the FA2 backward strategy) instead of letting autodiff
    stash stacked (nb, B, H, bq, Skv) f32 score tensors through HBM —
    §Perf iteration 1."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D)

    if (
        causal_sparse
        and causal
        and window is None
        and kv_valid is None
        and prefix == 0
        and Sq == Skv
        and Sq % q_block == 0
        and Sq // q_block >= 2
    ):
        out = triangular_attention(
            qg, k, v, qpos, kpos, softcap=softcap, scale=scale, q_block=q_block
        )
        return out.reshape(B, Sq, Hq, D)

    def attend_call(qb, kk, vv, qp, kp, kvv):
        return _block_attend(
            qb, kk, vv, qp, kp, causal=causal, window=window, softcap=softcap,
            scale=scale, prefix=prefix, kv_valid=kvv,
        )

    if flash_remat:
        attend_call = jax.checkpoint(attend_call)

    if Sq <= q_block:
        out = attend_call(qg, k, v, qpos, kpos, kv_valid)
        return out.reshape(B, Sq, Hq, D)

    if Sq % q_block:  # pad to a whole number of blocks; sliced off below
        pad = q_block - Sq % q_block
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=0)
        Sq_padded = Sq + pad
    else:
        Sq_padded = Sq
    nb = Sq_padded // q_block
    # (nb, B, bq, ...) blocked views
    qg_b = jnp.moveaxis(qg.reshape(B, nb, q_block, Hkv, G, D), 1, 0)
    qpos_b = jnp.moveaxis(qpos.reshape(B, nb, q_block), 1, 0)

    local = window is not None and (prefix + window + q_block) < Skv and causal
    if local:
        # statically-sized KV slice per block: the always-visible prefix plus
        # [qstart - window, qstart + bq)
        span = window + q_block

        def slice_kv(arr, start):
            tail = lax.dynamic_slice_in_dim(arr, start, span, axis=1)
            if prefix:
                return jnp.concatenate([arr[:, :prefix], tail], axis=1)
            return tail

        def body(_, xs):
            qb, qp, idx = xs
            start = jnp.clip(idx * q_block - window, prefix, Skv - span)
            ks, vs, kp = slice_kv(k, start), slice_kv(v, start), slice_kv(kpos, start)
            kvv = slice_kv(kv_valid, start) if kv_valid is not None else None
            return None, attend_call(qb, ks, vs, qp, kp, kvv)
    else:

        def body(_, xs):
            qb, qp, idx = xs
            return None, attend_call(qb, k, v, qp, kpos, kv_valid)

    _, out = lax.scan(body, None, (qg_b, qpos_b, jnp.arange(nb)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq_padded, Hq, D)
    return out[:, :Sq] if Sq_padded != Sq else out


# ----------------------------------------------------------------------
# attention layer (projections + rope + cache handling)
# ----------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    return q, k, v


def attention_layer(
    p,
    x,
    cfg: ArchConfig,
    positions,
    *,
    window: Optional[int],
    causal: bool = True,
    shard_hint: Optional[bool] = None,
    causal_sparse: Optional[bool] = None,
):
    """Self-attention for train/prefill. Returns (out, (k, v)) for caching."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    if shard_hint if shard_hint is not None else cfg.attn_shard_hint is True:
        # keep attention internals batch+head sharded; without this, the
        # seq-sharded prefill cache out-sharding propagates backwards and
        # GSPMD inserts per-q-block gathers/psums (§Perf iterations 2-3).
        # q is only pinned when its head dim actually shards — pinning a
        # non-divisible head count (gemma2's 8 on a 16-way axis) replicates
        # the whole attention compute across the model axis.
        from repro.dist.sharding import active_mesh, constrain, resolve_pspec

        k = constrain(k, ("batch", None, "tp", None))
        v = constrain(v, ("batch", None, "tp", None))
        mesh = active_mesh()
        if mesh is not None and resolve_pspec(q.shape, ("batch", None, "tp", None), mesh)[2] is not None:
            q = constrain(q, ("batch", None, "tp", None))
    out = chunked_attention(
        q, k, v, positions, positions,
        causal=causal, window=window, softcap=cfg.attn_softcap,
        q_block=cfg.q_block, prefix=cfg.meta_tokens,
        flash_remat=cfg.flash_remat,
        causal_sparse=(
            causal_sparse if causal_sparse is not None else cfg.causal_sparse is True
        ),
    )
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1) @ p["wo"]
    return out, (k, v)


def attention_decode(
    p,
    x,  # (B, 1, d)
    cfg: ArchConfig,
    cache_k,  # (B, Smax, Hkv, D)
    cache_v,
    positions,  # (B,) current absolute position of the new token
    *,
    window: Optional[int],
):
    """Single-token decode against a KV cache; returns (out, new_k, new_v)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, positions[:, None])
    cache_k = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(c, u, i, 0))(
        cache_k, k, positions
    )
    cache_v = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(c, u, i, 0))(
        cache_v, v, positions
    )
    Smax = cache_k.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32)[None], (B, Smax))
    valid = kpos <= positions[:, None]
    out = chunked_attention(
        q, cache_k, cache_v, positions[:, None], kpos,
        causal=True, window=window, softcap=cfg.attn_softcap,
        q_block=cfg.q_block, kv_valid=valid, prefix=cfg.meta_tokens,
    )
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, cache_k, cache_v


def init_cross_attention(key, cfg: ArchConfig, dtype):
    return init_attention(key, cfg, dtype)


def cross_attention_layer(p, x, kv_src, cfg: ArchConfig):
    """Cross-attention: queries from x, keys/values from kv_src (no RoPE)."""
    B, S, _ = x.shape
    Skv = kv_src.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    qpos = jnp.zeros((B, S), jnp.int32)
    kpos = jnp.zeros((B, Skv), jnp.int32)
    out = chunked_attention(
        q, k, v, qpos, kpos, causal=False, window=None, softcap=None,
        q_block=cfg.q_block,
    )
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def cross_attention_cached(p, x, ck, cv, cfg: ArchConfig):
    """Cross-attention at decode time against precomputed source K/V."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    Skv = ck.shape[1]
    qpos = jnp.zeros((B, S), jnp.int32)
    kpos = jnp.zeros((B, Skv), jnp.int32)
    out = chunked_attention(
        q, ck, cv, qpos, kpos, causal=False, window=None, softcap=None,
        q_block=cfg.q_block,
    )
    return out.reshape(B, S, -1) @ p["wo"]


# ----------------------------------------------------------------------
# feed-forward
# ----------------------------------------------------------------------


def init_ffn(key, cfg: ArchConfig, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act in ("silu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f), dtype),
            "w_up": dense_init(k2, (d, f), dtype),
            "w_down": dense_init(k3, (f, d), dtype),
        }
    return {"w_up": dense_init(k1, (d, f), dtype), "w_down": dense_init(k2, (f, d), dtype)}


def ffn(p, x, cfg: ArchConfig, use_pallas: bool = False):
    if cfg.act in ("silu", "geglu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        if use_pallas:
            from repro.kernels.silu_mul import ops as silu_ops

            h = silu_ops.act_mul(g, u, act=cfg.act)
        else:
            act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu, approximate=True)
            h = act(g) * u
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]


# ----------------------------------------------------------------------
# embedding / unembedding
# ----------------------------------------------------------------------


def init_embed(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    V, d = cfg.padded_vocab, cfg.d_model
    return {
        "tok": embed_init(k1, (V, d), dtype),
        "head": dense_init(k2, (d, V), dtype),
    }


def embed_tokens(p, tokens, cfg: ArchConfig, compute_dtype):
    x = jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def lm_logits(p, x, cfg: ArchConfig):
    logits = (x @ p["head"].astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy(logits, labels, valid, vocab_size: int):
    """Mean next-token cross entropy over valid positions. Padded vocab slots
    are masked out of the softmax."""
    V = logits.shape[-1]
    if V > vocab_size:
        pad_mask = jnp.arange(V) < vocab_size
        logits = jnp.where(pad_mask[None, None, :], logits, NEG_INF)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)


def chunked_cross_entropy(
    x,  # (B, S, d) final hidden states (positions predicting labels)
    embed_params,
    labels,  # (B, S) int32
    valid,  # (B, S) float
    cfg,
    block: int = 512,
):
    """Next-token CE without materializing (B, S, V) logits: scan over
    sequence blocks, rematerializing each block's logits in the backward pass
    (jax.checkpoint). Peak logits memory drops from S*V to block*V per batch
    row — the difference between ~TB and ~GB at 4k x 256k vocab."""
    B, S, d = x.shape
    if S % block:
        pad = block - S % block
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
        S += pad
    nb = S // block
    xb = jnp.moveaxis(x.reshape(B, nb, block, d), 1, 0)
    lb = jnp.moveaxis(labels.reshape(B, nb, block), 1, 0)
    vb = jnp.moveaxis(valid.reshape(B, nb, block), 1, 0)

    @jax.checkpoint
    def blk(xi, li, vi):
        logits = lm_logits(embed_params, xi, cfg)
        V = logits.shape[-1]
        if V > cfg.vocab_size:
            pad_mask = jnp.arange(V) < cfg.vocab_size
            logits = jnp.where(pad_mask[None, None, :], logits, NEG_INF)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * vi), jnp.sum(vi)

    def body(acc, xs):
        xi, li, vi = xs
        s, n = blk(xi, li, vi)
        return (acc[0] + s, acc[1] + n), None

    (tot, n), _ = lax.scan(body, (0.0, 0.0), (xb, lb, vb))
    return tot / jnp.maximum(n, 1.0)
