"""Public model API: build once from an ArchConfig, get pure functions.

`input_specs(cfg, shape)` produces ShapeDtypeStruct stand-ins for every model
input of a dry-run cell — weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T


class ModelApi(NamedTuple):
    cfg: ArchConfig
    init: Callable[[Any], Any]
    loss: Callable[[Any, Any], Any]  # (params, batch) -> (loss, metrics)
    prefill: Callable[[Any, Any], Any]  # (params, batch) -> (logits, caches)
    decode: Callable[[Any, Any, Any, Any], Any]  # (params, caches, tok, pos)
    init_cache: Callable[[int, int], Any]  # (batch, max_len) -> caches


def build_model(cfg: ArchConfig) -> ModelApi:
    def init(key):
        return T.init_params(cfg, key)

    def loss(params, batch):
        return T.train_loss(params, cfg, batch)

    def prefill(params, batch):
        hidden, _, caches = T.forward(params, cfg, batch, "prefill")
        # only the last position's logits are needed to start decoding;
        # slicing before the LM head keeps prefill head cost O(B*V)
        logits_last = T.full_logits(params, cfg, hidden[:, -1:, :])[:, 0, :]
        return logits_last, caches

    def decode(params, caches, tokens, positions):
        return T.decode_step(params, cfg, caches, tokens, positions)

    def init_cache(batch, max_len):
        return T.init_cache(cfg, batch, max_len)

    return ModelApi(cfg, init, loss, prefill, decode, init_cache)


# ----------------------------------------------------------------------
# dry-run input specs
# ----------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, B: int, S: int) -> dict:
    """Token batch (+ stubbed modality frontends) for train/prefill."""
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "audio":
        specs["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        specs["image_embeds"] = _sds(
            (B, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """All abstract inputs for the step function of a dry-run cell.

    train   -> {'batch': ...}
    prefill -> {'batch': ...}
    decode  -> {'cache': ..., 'tokens': (B,), 'positions': (B,)}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, B, S)}
    # decode: one new token with a KV cache of seq_len
    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(B, S))
    return {
        "cache": cache,
        "tokens": _sds((B,), jnp.int32),
        "positions": _sds((B,), jnp.int32),
    }


def materialize_batch(cfg: ArchConfig, B: int, S: int, seed: int = 0) -> dict:
    """Concrete random batch matching batch_specs (smoke tests/examples)."""
    k = jax.random.PRNGKey(seed)
    out = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size, dtype=jnp.int32)
    }
    if cfg.family == "audio":
        out["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(k, 1), (B, cfg.enc_frames, cfg.d_model)
        ).astype(cfg.compute_dtype)
    if cfg.family == "vlm":
        out["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(k, 2), (B, cfg.n_img_tokens, cfg.d_model)
        ).astype(cfg.compute_dtype)
    return out
