"""Mamba-2 / SSD (state-space duality) blocks.

Implements the chunked SSD algorithm: quadratic attention-like computation
inside fixed-size chunks plus a linear recurrence over chunk states
(lax.scan), which is the TPU-friendly formulation (MXU-heavy intra-chunk
einsums, sequential-but-tiny inter-chunk scan). Decode maintains a recurrent
(conv, ssm) state and costs O(1) per token.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rmsnorm


class SSMState(NamedTuple):
    conv: jax.Array  # (B, conv_dim, W-1) rolling window of recent inputs
    ssm: jax.Array  # (B, H, P, N) recurrent state


def conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm(key, cfg: ArchConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner
    G, N, H, W = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    ks = jax.random.split(key, 6)
    proj_out = 2 * di + 2 * G * N + H
    # dt bias: inverse softplus of dt ~ U[1e-3, 0.1]
    dt = jnp.exp(
        jax.random.uniform(ks[3], (H,)) * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (conv_dim(cfg), W))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim(cfg),), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (H,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + conv_dim(cfg)]
    dt = zxbcdt[..., di + conv_dim(cfg) :]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width W. xBC: (B, L, C); w: (C, W)."""
    W = w.shape[-1]
    pads = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + xBC.shape[1], :] * w[None, None, :, W - 1 - i]
        for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b, l, h, p) pre-multiplied by nothing (dt applied inside)
    dt: (b, l, h) positive; A: (h,) negative; B, C: (b, l, g, n)
    Returns y (b, l, h, p) and final state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    l_orig = l
    if l % chunk:
        # zero-pad the tail: dt=0 makes padded steps identity transitions
        # (decay exp(0)=1, zero state/output contribution)
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc, Q = l // chunk, chunk
    rep = h // g  # heads per B/C group

    f32 = jnp.float32
    xdt = (x.astype(f32) * dt[..., None].astype(f32)).reshape(b, nc, Q, h, p)
    dA = (dt.astype(f32) * A.astype(f32)[None, None, :]).reshape(b, nc, Q, h)
    Bc = B.astype(f32).reshape(b, nc, Q, g, n)
    Cc = C.astype(f32).reshape(b, nc, Q, g, n)
    # expand groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b, nc, Q, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    cum = jnp.cumsum(dA, axis=2)  # (b, nc, Q, h)

    # --- intra-chunk (block-diagonal) term -----------------------------
    # L[i, j] = exp(cum_i - cum_j + dA_j)  for i >= j  (decay from j to i,
    # including step j's own dt*A applied at input time j)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b, nc, Qi, Qj, h)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * Lmat
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # --- chunk states ---------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, nc, Q, h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end, xdt)

    # --- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, nc, h)

    def step(s_prev, inp):
        st, cd = inp  # (b, h, p, n), (b, h)
        s_new = s_prev * cd[:, :, None, None] + st
        return s_new, s_prev

    init = jnp.zeros((b, h, p, n), f32)
    final, prev_states = lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, nc, h, p, n)

    # --- state -> output -------------------------------------------------
    decay_from_start = jnp.exp(cum)  # (b, nc, Q, h)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, decay_from_start
    )
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y[:, :l_orig], final


def ssm_layer(p, x, cfg: ArchConfig):
    """Full Mamba-2 mixer for train/prefill. x: (B, L, d). Returns
    (out, SSMState) — the state enables prefill->decode handoff."""
    B, L, _ = x.shape
    di, G, N, H, P = (
        cfg.d_inner,
        cfg.ssm_groups,
        cfg.ssm_state,
        cfg.ssm_heads,
        cfg.ssm_headdim,
    )
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, L, H, P)
    Bm = xBC[..., di : di + G * N].reshape(B, L, G, N)
    Cm = xBC[..., di + G * N :].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssd_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    # conv state holds the *pre-activation* last W-1 inputs (oldest first)
    raw_xBC = _split_proj(cfg, zxbcdt)[1]
    W = cfg.conv_width
    pad = jnp.pad(raw_xBC, ((0, 0), (W - 1, 0), (0, 0)))
    conv_state = jnp.moveaxis(pad[:, L : L + W - 1, :], 1, 2)  # (B, C, W-1)
    state = SSMState(conv=conv_state.astype(x.dtype), ssm=final)
    return out, state


def ssm_decode(p, x, cfg: ArchConfig, state: SSMState):
    """One-token recurrent step. x: (B, 1, d)."""
    B = x.shape[0]
    di, G, N, H, P = (
        cfg.d_inner,
        cfg.ssm_groups,
        cfg.ssm_state,
        cfg.ssm_heads,
        cfg.ssm_headdim,
    )
    zxbcdt = x[:, 0, :] @ p["in_proj"]  # (B, proj)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # rolling conv window
    win = jnp.concatenate([state.conv, xBC[:, :, None]], axis=2)  # (B, C, W)
    # win[..., -1] is the newest input and pairs with conv_w[:, 0]
    conv_out = jnp.einsum(
        "bcw,cw->bc", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32)[:, ::-1]
    )
    xBC_a = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xs = xBC_a[..., :di].reshape(B, H, P)
    Bm = xBC_a[..., di : di + G * N].reshape(B, G, N)
    Cm = xBC_a[..., di + G * N :].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # (B, H)
    upd = (dt[:, :, None] * xs.astype(jnp.float32))[:, :, :, None] * Bh.astype(jnp.float32)[:, :, None, :]
    ssm = state.ssm * dA[:, :, None, None] + upd  # (B, H, P, N)
    y = jnp.einsum("bhpn,bhn->bhp", ssm, Ch.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, SSMState(conv=win[:, :, 1:].astype(x.dtype), ssm=ssm)


def init_ssm_state(cfg: ArchConfig, batch: int, dtype) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, conv_dim(cfg), cfg.conv_width - 1), dtype),
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    )
