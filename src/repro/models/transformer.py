"""Model zoo assembly: segmented layer stacks for all six families.

A model is a list of *segments*; each segment is a homogeneous stack of
layers scanned with ``lax.scan`` over stacked parameters (keeps HLO small and
compile times tractable for 95-layer models on 512 devices). Heterogeneous
layer patterns (gemma2 local/global alternation, hymba global islands,
llama-vision cross-attention groups) become multiple segments or composite
block bodies, so every scan body stays static — no traced branching on layer
kind.

Modes: 'train' (no cache), 'prefill' (build KV/SSM caches), 'decode'
(one token against caches).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


@dataclasses.dataclass
class Ctx:
    cfg: ArchConfig
    train: bool
    positions: Optional[jax.Array] = None  # (B, S) train/prefill
    dec_positions: Optional[jax.Array] = None  # (B,) decode
    img: Optional[jax.Array] = None  # VLM patch embeddings (B, P, d)
    enc_out: Optional[jax.Array] = None  # whisper encoder output (B, F, d)


def _cast(p, dtype, keep_f32=("A_log", "dt_bias", "D")):
    def f(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if a.dtype == jnp.float32 and name in keep_f32:
            return a
        return a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a

    return jax.tree_util.tree_map_with_path(f, p)


# ======================================================================
# block bodies — fwd(p, x, ctx, cache, mode) -> (x, aux, new_cache)
# ======================================================================


def _self_attn(p, x, ctx: Ctx, cache, mode, *, window, causal=True):
    cfg = ctx.cfg
    if mode == "decode":
        out, ck, cv = L.attention_decode(
            p, x, cfg, cache["k"], cache["v"], ctx.dec_positions, window=window
        )
        return out, {"k": ck, "v": cv}
    # attn_shard_hint: True = always, "train" = training only (§Perf It-7:
    # the prefill cache out-sharding interplay made the hint regress on
    # gemma2 prefill, while training-graph psums still benefit)
    hint = cfg.attn_shard_hint is True or (
        cfg.attn_shard_hint == "train" and mode == "train"
    )
    sparse = cfg.causal_sparse is True or (
        cfg.causal_sparse == "prefill" and mode == "prefill"
    )
    out, (k, v) = L.attention_layer(
        p, x, cfg, ctx.positions, window=window, causal=causal,
        shard_hint=hint, causal_sparse=sparse,
    )
    if mode == "prefill":
        return out, {"k": k, "v": v}
    return out, None


def dense_block(p, x, ctx: Ctx, cache, mode, *, window):
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, new_cache = _self_attn(p["attn"], h, ctx, cache, mode, window=window)
    if cfg.post_norms:
        attn_out = L.apply_norm(p["post_ln1"], attn_out, cfg)
    x = constrain(x + attn_out, ("batch", None, None))
    h = L.apply_norm(p["ln2"], x, cfg)
    ffn_out = L.ffn(p["ffn"], h, cfg, use_pallas=cfg.use_pallas)
    if cfg.post_norms:
        ffn_out = L.apply_norm(p["post_ln2"], ffn_out, cfg)
    x = constrain(x + ffn_out, ("batch", None, None))
    return x, 0.0, new_cache


def init_dense_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "ffn": L.init_ffn(ks[3], cfg, dtype),
    }
    if cfg.post_norms:
        p["post_ln1"] = L.init_norm(ks[0], cfg, cfg.d_model, dtype)
        p["post_ln2"] = L.init_norm(ks[2], cfg, cfg.d_model, dtype)
    return p


def pair_block(p, x, ctx: Ctx, cache, mode, *, window):
    """gemma2: one sliding-window layer followed by one global layer."""
    cache = cache or {"local": None, "global": None}
    x, a1, c1 = dense_block(p["local"], x, ctx, cache["local"], mode, window=window)
    x, a2, c2 = dense_block(p["global"], x, ctx, cache["global"], mode, window=None)
    new_cache = None if c1 is None else {"local": c1, "global": c2}
    return x, a1 + a2, new_cache


def moe_block(p, x, ctx: Ctx, cache, mode, *, window):
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, new_cache = _self_attn(p["attn"], h, ctx, cache, mode, window=window)
    x = constrain(x + attn_out, ("batch", None, None))
    h = L.apply_norm(p["ln2"], x, cfg)
    moe_out, aux = M.moe_layer(p["moe"], h, cfg, train=ctx.train)
    x = constrain(x + moe_out, ("batch", None, None))
    return x, aux, new_cache


def init_moe_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "moe": M.init_moe(ks[3], cfg, dtype),
    }


def ssm_block(p, x, ctx: Ctx, cache, mode):
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    h = L.apply_norm(p["ln1"], x, cfg)
    if mode == "decode":
        out, st = S.ssm_decode(p["mix"], h, cfg, S.SSMState(cache["conv"], cache["ssm"]))
        new_cache = {"conv": st.conv, "ssm": st.ssm}
    else:
        out, st = S.ssm_layer(p["mix"], h, cfg)
        new_cache = {"conv": st.conv, "ssm": st.ssm} if mode == "prefill" else None
    x = constrain(x + out, ("batch", None, None))
    return x, 0.0, new_cache


def init_ssm_block(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(k1, cfg, cfg.d_model, dtype),
        "mix": S.init_ssm(k2, cfg, dtype),
    }


def hybrid_block(p, x, ctx: Ctx, cache, mode, *, window):
    """hymba: parallel attention + SSM heads, mean of per-branch norms."""
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    cache = cache or {"attn": None, "ssm": None}
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, attn_cache = _self_attn(
        p["attn"], h, ctx, cache.get("attn"), mode, window=window
    )
    if mode == "decode":
        ssm_out, st = S.ssm_decode(
            p["mix"], h, cfg, S.SSMState(cache["ssm"]["conv"], cache["ssm"]["ssm"])
        )
    else:
        ssm_out, st = S.ssm_layer(p["mix"], h, cfg)
    mixed = 0.5 * (
        L.rmsnorm(attn_out, p["norm_attn"]) + L.rmsnorm(ssm_out, p["norm_ssm"])
    )
    x = constrain(x + mixed, ("batch", None, None))
    h = L.apply_norm(p["ln2"], x, cfg)
    x = constrain(x + L.ffn(p["ffn"], h, cfg, use_pallas=cfg.use_pallas), ("batch", None, None))
    new_cache = None
    if mode != "train":
        new_cache = {"attn": attn_cache, "ssm": {"conv": st.conv, "ssm": st.ssm}}
    return x, 0.0, new_cache


def init_hybrid_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "mix": S.init_ssm(ks[2], cfg, dtype),
        "norm_attn": jnp.zeros((cfg.d_model,), dtype),
        "norm_ssm": jnp.zeros((cfg.d_model,), dtype),
        "ln2": L.init_norm(ks[3], cfg, cfg.d_model, dtype),
        "ffn": L.init_ffn(ks[4], cfg, dtype),
    }


def cross_block(p, x, ctx: Ctx, cache, mode):
    """llama-3.2-vision gated cross-attention layer (queries: text; kv: image)."""
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    h = L.apply_norm(p["ln1"], x, cfg)
    if mode == "decode":
        out = L.cross_attention_cached(p["attn"], h, cache["ck"], cache["cv"], cfg)
        new_cache = cache
    else:
        out, (ck, cv) = L.cross_attention_layer(p["attn"], h, ctx.img, cfg)
        new_cache = {"ck": ck, "cv": cv} if mode == "prefill" else None
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * out
    h = L.apply_norm(p["ln2"], x, cfg)
    x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * L.ffn(p["ffn"], h, cfg)
    return constrain(x, ("batch", None, None)), 0.0, new_cache


def init_cross_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_cross_attention(ks[1], cfg, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "ln2": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "ffn": L.init_ffn(ks[3], cfg, dtype),
        "gate_ffn": jnp.zeros((), jnp.float32),
    }


def vlm_group(p, x, ctx: Ctx, cache, mode):
    """cross_every self-attn layers followed by one gated cross-attn layer."""
    cache = cache or {"self": None, "cross": None}

    def inner(carry, xs):
        x, aux = carry
        lp, lc = xs
        x, a, c = dense_block(lp, x, ctx, lc, mode, window=None)
        return (x, aux + a), c

    (x, aux), self_caches = lax.scan(inner, (x, 0.0), (p["self"], cache["self"]))
    x, a2, cross_cache = cross_block(p["cross"], x, ctx, cache["cross"], mode)
    new_cache = None
    if mode != "train":
        new_cache = {"self": self_caches, "cross": cross_cache}
    return x, aux + a2, new_cache


def init_vlm_group(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    inner_keys = jax.random.split(k1, cfg.cross_every)
    return {
        "self": jax.vmap(lambda k: init_dense_block(k, cfg, dtype))(inner_keys),
        "cross": init_cross_block(k2, cfg, dtype),
    }


def encdec_block(p, x, ctx: Ctx, cache, mode):
    """whisper decoder layer: causal self-attn + cross-attn(enc) + FFN."""
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    cache = cache or {"self": None, "cross": None}
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, self_cache = _self_attn(p["attn"], h, ctx, cache["self"], mode, window=None)
    x = x + attn_out
    h = L.apply_norm(p["ln_x"], x, cfg)
    if mode == "decode":
        xo = L.cross_attention_cached(
            p["xattn"], h, cache["cross"]["ck"], cache["cross"]["cv"], cfg
        )
        cross_cache = cache["cross"]
    else:
        xo, (ck, cv) = L.cross_attention_layer(p["xattn"], h, ctx.enc_out, cfg)
        cross_cache = {"ck": ck, "cv": cv} if mode == "prefill" else None
    x = x + xo
    h = L.apply_norm(p["ln2"], x, cfg)
    x = constrain(x + L.ffn(p["ffn"], h, cfg), ("batch", None, None))
    new_cache = None
    if mode != "train":
        new_cache = {"self": self_cache, "cross": cross_cache}
    return x, 0.0, new_cache


def init_encdec_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln_x": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "xattn": L.init_cross_attention(ks[3], cfg, dtype),
        "ln2": L.init_norm(ks[4], cfg, cfg.d_model, dtype),
        "ffn": L.init_ffn(ks[5], cfg, dtype),
    }


def enc_block(p, x, ctx: Ctx, cache, mode):
    """whisper encoder layer: bidirectional self-attn + FFN (no cache)."""
    cfg = ctx.cfg
    p = _cast(p, x.dtype)
    h = L.apply_norm(p["ln1"], x, cfg)
    out, _ = _self_attn(p["attn"], h, ctx, None, "train", window=None, causal=False)
    x = x + out
    h = L.apply_norm(p["ln2"], x, cfg)
    x = x + L.ffn(p["ffn"], h, cfg)
    return x, 0.0, None


# ======================================================================
# segment machinery
# ======================================================================


@dataclasses.dataclass
class Segment:
    name: str
    n: int
    init_one: Callable[[Any], Any]
    fwd: Callable  # (p, x, ctx, cache, mode) -> (x, aux, cache)

    def init(self, key):
        return jax.vmap(self.init_one)(jax.random.split(key, self.n))

    def apply(self, params, x, ctx: Ctx, mode: str, cache=None, remat=False):
        fwd = self.fwd

        if mode == "train":

            def one(lp, xx):
                y, a, _ = fwd(lp, xx, ctx, None, mode)
                return y, a

            if remat:
                one = jax.checkpoint(one)

            def body(carry, lp):
                x, aux = carry
                y, a = one(lp, x)
                return (y, aux + a), None

            (x, aux), _ = lax.scan(body, (x, 0.0), params)
            return x, aux, None

        if mode == "prefill":

            def body(carry, lp):
                x, aux = carry
                x, a, c = fwd(lp, x, ctx, None, mode)
                return (x, aux + a), c

            (x, aux), caches = lax.scan(body, (x, 0.0), params)
            return x, aux, caches

        # decode
        def body(x, xs):
            lp, lc = xs
            x, _, c = fwd(lp, x, ctx, lc, mode)
            return x, c

        x, caches = lax.scan(body, x, (params, cache))
        return x, 0.0, caches


def build_segments(cfg: ArchConfig) -> list[Segment]:
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.family == "dense":
        if cfg.layer_pattern == "alt_local_global":
            assert cfg.n_layers % 2 == 0
            init = lambda k: {
                "local": init_dense_block(jax.random.fold_in(k, 0), cfg, dt),
                "global": init_dense_block(jax.random.fold_in(k, 1), cfg, dt),
            }
            return [
                Segment(
                    "pairs",
                    cfg.n_layers // 2,
                    init,
                    partial(pair_block, window=cfg.window),
                )
            ]
        return [
            Segment(
                "dense",
                cfg.n_layers,
                lambda k: init_dense_block(k, cfg, dt),
                partial(dense_block, window=cfg.window),
            )
        ]
    if cfg.family == "moe":
        return [
            Segment(
                "moe",
                cfg.n_layers,
                lambda k: init_moe_block(k, cfg, dt),
                partial(moe_block, window=cfg.window),
            )
        ]
    if cfg.family == "ssm":
        return [
            Segment("ssm", cfg.n_layers, lambda k: init_ssm_block(k, cfg, dt), ssm_block)
        ]
    if cfg.family == "hybrid":
        # global attention islands at first / middle / last layer
        n = cfg.n_layers
        init = lambda k: init_hybrid_block(k, cfg, dt)
        gl = partial(hybrid_block, window=None)
        loc = partial(hybrid_block, window=cfg.window)
        globals_at = sorted(set([0, n // 2, n - 1]))
        segs, prev = [], -1
        for gi, g in enumerate(globals_at):
            run = g - prev - 1
            if run > 0:
                segs.append(Segment(f"loc_{gi}", run, init, loc))
            segs.append(Segment(f"g_{gi}", 1, init, gl))
            prev = g
        tail = n - 1 - globals_at[-1]
        if tail > 0:
            segs.append(Segment("loc_tail", tail, init, loc))
        return segs
    if cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_every
        return [
            Segment("vlm", n_groups, lambda k: init_vlm_group(k, cfg, dt), vlm_group)
        ]
    if cfg.family == "audio":
        return [
            Segment(
                "dec", cfg.n_layers, lambda k: init_encdec_block(k, cfg, dt), encdec_block
            )
        ]
    raise ValueError(cfg.family)


# ======================================================================
# full model
# ======================================================================

MAX_DEC_POS = 32768  # whisper learned decoder-position table size


def init_params(cfg: ArchConfig, key):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params = {
        "embed": L.init_embed(ks[0], cfg, dt),
        "final_norm": L.init_norm(ks[1], cfg, cfg.d_model, dt),
        "segments": [seg.init(jax.random.fold_in(ks[2], i)) for i, seg in enumerate(build_segments(cfg))],
    }
    if cfg.meta_tokens:
        params["meta"] = L.embed_init(ks[3], (cfg.meta_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        params["enc"] = Segment(
            "enc", cfg.n_enc_layers, lambda k: init_encdec_enc(k, cfg, dt), enc_block
        ).init(ks[4])
        params["enc_pos"] = L.embed_init(ks[5], (cfg.enc_frames, cfg.d_model), dt)
        params["dec_pos"] = L.embed_init(ks[6], (MAX_DEC_POS, cfg.d_model), dt)
        params["enc_norm"] = L.init_norm(ks[7], cfg, cfg.d_model, dt)
    return params


def init_encdec_enc(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "ffn": L.init_ffn(ks[3], cfg, dtype),
    }


def _run_encoder(params, cfg: ArchConfig, frames, ctx: Ctx):
    cdt = jnp.dtype(cfg.compute_dtype)
    F = frames.shape[1]
    x = frames.astype(cdt) + params["enc_pos"][:F][None].astype(cdt)
    seg = Segment("enc", cfg.n_enc_layers, lambda k: None, enc_block)
    enc_ctx = dataclasses.replace(
        ctx, positions=jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (x.shape[0], F))
    )
    x, _, _ = seg.apply(params["enc"], x, enc_ctx, "train", remat=cfg.remat == "layer")
    return L.apply_norm(params["enc_norm"], x, cfg)


def _embed_input(params, cfg: ArchConfig, tokens, base_positions):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = L.embed_tokens(params["embed"], tokens, cfg, cdt)
    if cfg.meta_tokens:
        B = tokens.shape[0]
        meta = jnp.broadcast_to(
            params["meta"][None].astype(cdt), (B, cfg.meta_tokens, cfg.d_model)
        )
        x = jnp.concatenate([meta, x], axis=1)
        m = cfg.meta_tokens
        pos = jnp.concatenate(
            [
                jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None], (B, m)),
                base_positions + m,
            ],
            axis=1,
        )
    else:
        pos = base_positions
    if cfg.family == "audio":
        x = x + jnp.take(params["dec_pos"], base_positions, axis=0).astype(cdt)
    return x, pos


def forward(params, cfg: ArchConfig, batch, mode: str):
    """train/prefill forward. batch: dict(tokens, [frames|image_embeds]).

    Returns (hidden, aux, caches) — hidden is the post-final-norm residual
    stream (meta tokens stripped); callers turn it into logits (chunked CE
    for training, last-position logits for prefill) so the (B, S, V) logits
    tensor is never materialized at scale."""
    tokens = batch["tokens"]
    B, Stok = tokens.shape
    base_pos = jnp.broadcast_to(jnp.arange(Stok, dtype=jnp.int32)[None], (B, Stok))
    ctx = Ctx(cfg=cfg, train=(mode == "train"))
    if cfg.family == "vlm":
        ctx.img = batch["image_embeds"].astype(jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        ctx.enc_out = _run_encoder(params, cfg, batch["frames"], ctx)
    x, pos = _embed_input(params, cfg, tokens, base_pos)
    ctx.positions = pos
    x = constrain(x, ("batch", None, None))

    caches = []
    aux = 0.0
    for seg, seg_params in zip(build_segments(cfg), params["segments"]):
        x, a, c = seg.apply(
            seg_params, x, ctx, mode, remat=(cfg.remat == "layer" and mode == "train")
        )
        aux = aux + a
        caches.append(c)
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens :, :]
    return x, aux, (caches if mode == "prefill" else None)


def full_logits(params, cfg: ArchConfig, hidden):
    """Materialize logits for every position (smoke tests / tiny models)."""
    return L.lm_logits(params["embed"], hidden, cfg)


def decode_step(params, cfg: ArchConfig, caches, tokens, positions):
    """One decode step. tokens: (B,) int32; positions: (B,) absolute position
    of the new token (0-based, excluding meta tokens). Returns (logits, caches)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens[:, None], cfg, cdt)
    if cfg.family == "audio":
        x = x + jnp.take(params["dec_pos"], positions[:, None], axis=0).astype(cdt)
    dec_pos = positions + (cfg.meta_tokens or 0)
    ctx = Ctx(cfg=cfg, train=False, dec_positions=dec_pos)
    new_caches = []
    for seg, seg_params, seg_cache in zip(build_segments(cfg), params["segments"], caches):
        x, _, c = seg.apply(seg_params, x, ctx, "decode", cache=seg_cache)
        new_caches.append(c)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits[:, 0, :], new_caches


def train_loss(params, cfg: ArchConfig, batch):
    hidden, aux, _ = forward(params, cfg, batch, "train")
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    valid = jnp.ones_like(labels, jnp.float32)
    ce = L.chunked_cross_entropy(
        hidden[:, :-1, :], params["embed"], labels, valid, cfg, block=cfg.q_block
    )
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def pad_cache(caches, cfg: ArchConfig, max_len: int):
    """Pad prefill-produced self-attention KV caches (seq dim) out to
    ``max_len`` (+ meta tokens) so decode steps can append. Cross-attention
    KV and SSM states are fixed-size and pass through."""
    target = max_len + (cfg.meta_tokens or 0)

    def f(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and leaf.ndim >= 4:
            cur = leaf.shape[-3]
            if cur < target:
                pads = [(0, 0)] * leaf.ndim
                pads[-3] = (0, target - cur)
                return jnp.pad(leaf, pads)
        return leaf

    return jax.tree_util.tree_map_with_path(f, caches)


# ----------------------------------------------------------------------
# cache construction (zeros; used via eval_shape for dry-run input specs)
# ----------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Zero caches matching decode_step's expectations. max_len includes the
    token about to be written (excluding meta tokens, which are added here)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    S_cache = max_len + (cfg.meta_tokens or 0)

    def kv():
        return {
            "k": jnp.zeros((batch, S_cache, cfg.n_kv_heads, hd), cdt),
            "v": jnp.zeros((batch, S_cache, cfg.n_kv_heads, hd), cdt),
        }

    def ssm_state():
        return {
            "conv": jnp.zeros((batch, S.conv_dim(cfg), cfg.conv_width - 1), cdt),
            "ssm": jnp.zeros(
                (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
            ),
        }

    def stack(tree_fn, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), tree_fn())

    caches = []
    for seg in build_segments(cfg):
        if seg.name in ("dense", "moe"):
            caches.append(stack(kv, seg.n))
        elif seg.name == "pairs":
            caches.append(stack(lambda: {"local": kv(), "global": kv()}, seg.n))
        elif seg.name == "ssm":
            caches.append(stack(ssm_state, seg.n))
        elif seg.name.startswith(("g_", "loc_")):
            caches.append(stack(lambda: {"attn": kv(), "ssm": ssm_state()}, seg.n))
        elif seg.name == "vlm":
            caches.append(
                stack(
                    lambda: {
                        "self": jax.tree.map(
                            lambda a: jnp.broadcast_to(a[None], (cfg.cross_every, *a.shape)),
                            kv(),
                        ),
                        "cross": {
                            "ck": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, hd), cdt),
                            "cv": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, hd), cdt),
                        },
                    },
                    seg.n,
                )
            )
        elif seg.name == "dec":
            caches.append(
                stack(
                    lambda: {
                        "self": kv(),
                        "cross": {
                            "ck": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads, hd), cdt),
                            "cv": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads, hd), cdt),
                        },
                    },
                    seg.n,
                )
            )
        else:
            raise ValueError(seg.name)
    return caches
