"""Optimizers: pure-JAX AdamW with schedules and global-norm clipping."""
