"""AdamW with fp32 moments, decoupled weight decay, global-norm clipping and
LR schedules — pure JAX (no optax in this environment; the substrate is built
from scratch per assignment scope)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jax.Array
    mu: any
    nu: any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable  # step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), {
            "grad_norm": gnorm,
            "lr": lr,
        }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
