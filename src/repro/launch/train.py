"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 4 --seq 32 --ckpt-dir /tmp/ckpt

Runs the full Trainer (data pipeline -> pjit train step -> checkpoints ->
watchdog). With --mesh data,model=RxC it builds a sharded mesh (requires the
matching --devices host-device override, set before jax initializes)."""
import argparse
import logging
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--async-save", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. '2x2' => (data,model) mesh")
    ap.add_argument("--devices", type=int, default=0, help="host device override")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig
    from repro.dist.sharding import batch_pspecs, to_named, use_mesh
    from repro.train.step import (
        TrainConfig,
        init_train_state,
        make_optimizer,
        train_state_pspecs,
    )
    from repro.train.trainer import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    data = DataConfig(batch=args.batch, seq_len=args.seq)
    tc = TrainConfig(
        lr=args.lr,
        total_steps=args.steps,
        warmup=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        async_save=args.async_save,
    )

    mesh = None
    state_sh = batch_sh = None
    if args.mesh:
        r, c = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((r, c), ("data", "model"))

    if mesh is not None:
        with use_mesh(mesh):
            from repro.models.registry import build_model

            api = build_model(cfg)
            optimizer = make_optimizer(tc)
            state_shapes = jax.eval_shape(
                lambda: init_train_state(
                    api, optimizer, jax.random.PRNGKey(0),
                    compress_grads=tc.compress_grads,
                )
            )
            state_sh = to_named(train_state_pspecs(state_shapes, mesh), mesh)
            from repro.models.registry import batch_specs

            batch_sh = to_named(
                batch_pspecs(batch_specs(cfg, args.batch, args.seq), mesh), mesh
            )
            trainer = Trainer(cfg, data, tc, tcfg, mesh=mesh,
                              state_shardings=state_sh, batch_shardings=batch_sh)
            step, _, losses = trainer.run()
    else:
        trainer = Trainer(cfg, data, tc, tcfg)
        step, _, losses = trainer.run()
    print(f"finished at step {step}; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
