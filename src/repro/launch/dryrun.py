import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input shape) cell and both production meshes
(single pod 16x16, multi-pod 2x16x16) this lowers + compiles the step
function against ShapeDtypeStruct inputs, records ``memory_analysis()`` /
``cost_analysis()``, and parses the post-SPMD optimized HLO for collective
operand bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) — the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.configs import SHAPES, all_cells, get_arch
from repro.dist.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    to_named,
    use_mesh,
)
from repro.launch.mesh import make_production_mesh, mesh_tag
from repro.models.registry import build_model, input_specs
from repro.train.step import (
    TrainConfig,
    init_train_state,
    make_optimizer,
    make_train_step,
    train_state_pspecs,
)


# ----------------------------------------------------------------------
# HLO collective parsing
# ----------------------------------------------------------------------

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in optimized HLO.

    Returns {op_kind: {'bytes': int, 'count': int}} plus a '_total'."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # ops look like: %name = bf16[128,32]{1,0} all-gather(...), replica_groups=...
        m = re.match(r"^%?[\w.-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*([a-z0-9-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):  # e.g. all-reduce-start
                kind = k
                break
        if kind is None or op.endswith("-done"):
            continue
        out[kind]["bytes"] += _shape_bytes(m.group(1))
        out[kind]["count"] += 1
    out["_total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    out["_total_count"] = sum(v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


# ----------------------------------------------------------------------
# expert-parallel all-to-all ledger (counted from the model itself)
# ----------------------------------------------------------------------

def count_ep_alltoall_bytes(cfg, B: int, qlen: int, *, train: bool = False) -> dict:
    """Count the EP dispatch/combine all-to-all payload of one MoE layer
    straight from the executed model implementation.

    ``repro.models.moe.dispatch_geometry`` is the same code path
    ``moe_layer`` uses to build the dispatched-activation tensor
    ``(G, E, C, d)`` — the tensor the expert mesh axis re-shards — so this
    is the dry-run's ground-truth byte ledger for EP traffic, in the
    layer's compute dtype. ``core.decomposer.ep_alltoall_bytes`` must
    reproduce ``dispatch_bytes``/``combine_bytes`` *exactly* from its
    workload dict (pinned per MoE arch by ``tests/test_parallelism.py``
    and gated in ``benchmarks/bench_parallelism.py``); the decomposer's
    ``CommCall``s and this ledger therefore price the same tensor the
    optimized-HLO collective pass above streams.

    Returns per-hop and per-layer byte counts plus the geometry:
    ``{"dispatch_bytes", "combine_bytes", "layer_bytes", "model_bytes",
    "G", "group", "capacity"}`` (``model_bytes`` = per-layer x n_layers —
    the whole step's EP traffic)."""
    from repro.core.decomposer import COMPUTE_DTYPE_BYTES
    from repro.models.moe import dispatch_geometry

    if not cfg.n_experts:
        raise ValueError(f"{cfg.name} is not an MoE architecture")
    T = B * qlen
    G, Sg, C = dispatch_geometry(cfg, T, train=train)
    b = COMPUTE_DTYPE_BYTES[cfg.compute_dtype]
    hop = float(G * cfg.n_experts * C * cfg.d_model * b)
    return {
        "dispatch_bytes": hop,
        "combine_bytes": hop,
        "layer_bytes": 2.0 * hop,
        "model_bytes": 2.0 * hop * cfg.n_layers,
        "G": G,
        "group": Sg,
        "capacity": C,
    }


# ----------------------------------------------------------------------
# per-cell lowering
# ----------------------------------------------------------------------


def state_pspecs(state_shapes, mesh):
    return train_state_pspecs(state_shapes, mesh)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, pipeline: bool = False):
    """Lower + compile one cell. Returns (lowered, compiled, meta).

    ``pipeline=True`` lowers against the pipeline-parallel production
    mesh (4-way ``pipe`` axis, see ``launch.mesh``); parameter/batch
    sharding rules replicate over the ``pipe`` axis (only the ``"pipe"``
    role claims it), so the lowering stays coherent while the mesh leaves
    room for ``dist.pipeline.pipeline_forward`` stage placement."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        raise ValueError(f"{arch} x {shape_name}: documented skip (DESIGN.md)")
    mesh = make_production_mesh(multi_pod=multi_pod, pipeline=pipeline)
    api = build_model(cfg)
    specs = input_specs(cfg, shape)

    with use_mesh(mesh):
        params_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        p_sh = to_named(param_pspecs(params_shapes, mesh), mesh)

        if shape.kind == "train":
            tc = TrainConfig()
            optimizer = make_optimizer(tc)
            state_shapes = jax.eval_shape(
                lambda: init_train_state(
                    api, optimizer, jax.random.PRNGKey(0),
                    compress_grads=tc.compress_grads,
                )
            )
            s_spec = state_pspecs(state_shapes, mesh)
            s_sh = to_named(s_spec, mesh)
            b_sh = to_named(batch_pspecs(specs["batch"], mesh), mesh)
            step_fn = make_train_step(api, optimizer, tc)
            lowered = jax.jit(
                step_fn,
                in_shardings=(s_sh, b_sh),
                out_shardings=(s_sh, None),
                donate_argnums=(0,),
            ).lower(state_shapes, specs["batch"])
        elif shape.kind == "prefill":
            from repro.dist.sharding import resolve_pspec

            b_sh = to_named(batch_pspecs(specs["batch"], mesh), mesh)
            cache_shapes = jax.eval_shape(
                lambda p, b: api.prefill(p, b)[1], params_shapes, specs["batch"]
            )
            c_out = to_named(cache_pspecs(cache_shapes, mesh), mesh)
            logits_sh = NamedSharding(
                mesh,
                resolve_pspec((shape.global_batch, cfg.padded_vocab), ("batch", "tp"), mesh),
            )
            lowered = jax.jit(
                api.prefill,
                in_shardings=(p_sh, b_sh),
                out_shardings=(logits_sh, c_out),
            ).lower(params_shapes, specs["batch"])
        else:  # decode
            from repro.dist.sharding import resolve_pspec

            c_sh = to_named(cache_pspecs(specs["cache"], mesh), mesh)
            tok_sh = to_named(batch_pspecs({"t": specs["tokens"]}, mesh), mesh)["t"]
            logits_sh = NamedSharding(
                mesh,
                resolve_pspec((shape.global_batch, cfg.padded_vocab), ("batch", "tp"), mesh),
            )
            lowered = jax.jit(
                api.decode,
                in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                out_shardings=(logits_sh, c_sh),
                donate_argnums=(1,),
            ).lower(params_shapes, specs["cache"], specs["tokens"], specs["positions"])

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag(multi_pod=multi_pod, pipeline=pipeline),
        "n_devices": mesh.devices.size,
        "compile_s": round(compile_s, 1),
    }
    return lowered, compiled, meta


def analyze(lowered, compiled, meta) -> dict:
    from repro.roofline.hlo_cost import analyze_hlo

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax 0.4.x returns a one-element list
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)}
    text = compiled.as_text()
    walk = analyze_hlo(text)  # loop-aware per-device costs (see roofline/)
    out = dict(meta)
    # raw XLA numbers (while bodies counted once — kept for reference)
    out["xla_flops_raw"] = cost.get("flops")
    out["xla_bytes_raw"] = cost.get("bytes accessed")
    # loop-aware per-device numbers used by §Roofline
    out["flops"] = walk.flops
    out["dot_flops"] = walk.dot_flops
    out["vector_ops"] = walk.vector_ops
    out["transcendentals"] = walk.transcendentals
    out["hbm_bytes"] = walk.hbm_bytes
    out["memory"] = mem_d
    out["collectives"] = {
        **walk.collectives,
        "_total_bytes": walk.collective_bytes,
    }
    out["unknown_ops"] = walk.unknown_ops
    out["hlo_lines"] = len(text.splitlines())
    cfg = get_arch(meta["arch"])
    if cfg.n_experts:
        # the analytical EP all-to-all ledger next to the HLO-counted
        # collectives: per-layer dispatch/combine bytes of the dispatched
        # (G, E, C, d) tensor, from the model's own grouping/capacity code
        shape = SHAPES[meta["shape"]]
        qlen = 1 if shape.kind == "decode" else shape.seq_len
        out["ep_alltoall"] = count_ep_alltoall_bytes(
            cfg, shape.global_batch, qlen, train=shape.kind == "train"
        )
    return out


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, print_analysis=True, hlo_path=None,
    pipeline: bool = False,
) -> dict:
    lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod, pipeline)
    result = analyze(lowered, compiled, meta)
    if hlo_path:
        import zstandard

        with open(hlo_path, "wb") as f:
            f.write(zstandard.compress(compiled.as_text().encode()))
    if print_analysis:
        print(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="lower against the pipeline-parallel production "
                         "mesh (4-way pipe axis; see launch.mesh)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{mesh_tag(multi_pod=mp, pipeline=args.pipeline)}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip cached] {tag}")
                continue
            print(f"[dry-run] {tag}", flush=True)
            try:
                hlo_dir = os.path.join(args.out, "hlo")
                os.makedirs(hlo_dir, exist_ok=True)
                result = run_cell(
                    arch, shape_name, mp, print_analysis=False,
                    hlo_path=os.path.join(hlo_dir, tag + ".hlo.zst"),
                    pipeline=args.pipeline,
                )
                with open(path, "w") as f:
                    json.dump(result, f, indent=2, default=str)
                print(
                    f"  ok: flops={result['flops']:.3e} "
                    f"coll={result['collectives']['_total_bytes']:.3e}B "
                    f"compile={result['compile_s']}s",
                    flush=True,
                )
            except Exception:  # noqa: BLE001
                n_fail += 1
                with open(path + ".fail", "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAIL ({tag}) — see {path}.fail", flush=True)
    print(f"done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
