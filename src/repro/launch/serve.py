"""Serving launcher: batched requests through the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8 --max-new 8
"""
import argparse
import sys
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.serve.engine import Request, ServeEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    engine = ServeEngine(cfg, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        L = max(4, args.prompt_len + int(rng.integers(-4, 5)))
        prompt = rng.integers(1, cfg.vocab_size, size=L).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new=args.max_new,
                              temperature=args.temperature))
    t0 = time.perf_counter()
    results = []
    while engine.queue:
        results += engine.step_batch()
    wall = time.perf_counter() - t0
    total_new = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        print(f"req {r.rid}: {r.tokens[:8]}... prefill={r.prefill_s*1e3:.1f}ms "
              f"decode={r.decode_s*1e3:.1f}ms")
    print(f"served {len(results)} requests / {total_new} tokens in {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
