"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small-scale runs."""
    return jax.make_mesh(tuple(shape), tuple(axes))
