"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pipeline: bool = False):
    """The production meshes the dry-run lowers against.

    Default: one pod as 16 data x 16 model; ``multi_pod`` stacks a leading
    2-pod axis. ``pipeline`` carves a 4-way ``pipe`` axis out of the pod
    (4 stages x 8 data x 8 model — same 256 chips): the axis
    ``dist.pipeline.pipeline_forward`` schedules over and
    ``dist.sharding`` resolves the ``"pipe"`` role onto. Combined with
    ``multi_pod`` this is the 512-chip 2 x 4 x 8 x 8 mesh."""
    if pipeline:
        shape = (2, 4, 8, 8) if multi_pod else (4, 8, 8)
        axes = (("pod",) if multi_pod else ()) + ("pipe", "data", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_tag(*, multi_pod: bool = False, pipeline: bool = False) -> str:
    """Short mesh label used in dry-run artifact names/metadata."""
    if pipeline:
        return "2x4x8x8pp" if multi_pod else "4x8x8pp"
    return "2x16x16" if multi_pod else "16x16"


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small-scale runs."""
    return jax.make_mesh(tuple(shape), tuple(axes))
