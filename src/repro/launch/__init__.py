"""Entry points: training/serving launchers, mesh construction, dry-run lowering."""
