"""Placement objectives: how a fleet router scores one hardware's
``Estimate`` for a workload.

The predict layer answers "how long does this trace take on hw X?"
(seconds); an *objective* turns that answer into a ranking criterion —
lower score is always better. Objectives are deliberately tiny, pure
functions of ``(hw, Estimate)`` plus optional workload metadata, so new
criteria (energy, queueing headroom, ...) slot in without touching the
router::

    from repro.predict.objective import get_objective

    obj = get_objective("cost")                     # $ for the trace
    obj = get_objective("latency")                  # seconds
    obj = get_objective("cost_per_token")           # $ / generated token
    obj = get_objective("slo_cheapest", slo_s=0.5)  # cheapest under an SLO

Units and conventions:

  * ``Estimate`` latencies are **seconds** for the whole priced trace;
  * cost is **USD** for the trace: ``total_s / 3600 * usd_per_chip_hour *
    num_chips`` — the whole slice is billed while the workload runs, idle
    chips included (the registry's ``usd_per_chip_hour`` is the list
    price per chip);
  * ``n_tokens`` is the number of *generated* tokens the trace produced
    (``TraceRecorder.generated_tokens``; ``B * lout`` for a synthetic
    request) — prompt tokens are an input cost, not an output;
  * infeasible is not unrankable: ``feasible()`` marks SLO violations,
    and the router ranks infeasible hardware after every feasible one
    (still ordered by score) instead of dropping it from the table.

Hardware without a price (``usd_per_chip_hour is None``) makes cost-family
objectives raise ``UnpricedHardwareError``; ``FleetRouter`` converts that
into a skip-with-warning so one unpriced registry entry cannot abort a
fleet-wide routing pass.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Union

from repro.core.hardware import TPUSpec
from repro.predict.api import Estimate


class UnpricedHardwareError(ValueError):
    """A cost objective was asked about hardware with no
    ``usd_per_chip_hour``. ``FleetRouter`` catches this and skips the
    entry with a warning instead of aborting the sweep."""

    def __init__(self, hw_name: str, objective: str) -> None:
        self.hw_name = hw_name
        self.objective = objective
        super().__init__(
            f"objective {objective!r} needs a price but hardware {hw_name!r} "
            "has usd_per_chip_hour=None; set it on the TPUSpec (registry "
            "entries are priced) or use the 'latency' objective"
        )


def trace_cost_usd(hw: TPUSpec, est: Estimate, objective: str = "cost") -> float:
    """USD to run the estimated trace on ``hw``: the whole slice is billed
    for ``est.total_s`` seconds at the ``usd_per_slice_hour`` rate."""
    if hw.usd_per_slice_hour is None:
        raise UnpricedHardwareError(hw.name, objective)
    return est.total_s / 3600.0 * hw.usd_per_slice_hour


class Objective:
    """Base placement objective: ``score`` (lower = better) + ``feasible``.

    ``score`` may use ``n_tokens`` (generated-token count) when the
    criterion is per-token; implementations must raise an actionable error
    when required metadata is missing rather than silently scoring 0."""

    name = "base"

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        raise NotImplementedError

    def feasible(self, hw: TPUSpec, est: Estimate) -> bool:
        return True

    def describe(self) -> str:
        return self.name


class LatencyObjective(Objective):
    """Score = predicted trace latency in seconds."""

    name = "latency"

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        return est.total_s


class CostObjective(Objective):
    """Score = USD for the trace (slice-hours x list price)."""

    name = "cost"

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        return trace_cost_usd(hw, est, self.name)


class CostPerTokenObjective(Objective):
    """Score = USD per *generated* token. Needs ``n_tokens``."""

    name = "cost_per_token"

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        if not n_tokens:
            raise ValueError(
                "objective 'cost_per_token' needs n_tokens > 0 (generated "
                "tokens: TraceRecorder.generated_tokens for a recorded "
                "trace, B * lout for a synthetic request)"
            )
        return trace_cost_usd(hw, est, self.name) / n_tokens


class SLOCheapestObjective(Objective):
    """Cheapest hardware whose predicted latency meets an SLO: feasible iff
    ``est.total_s <= slo_s``; score = trace cost, so the router ranks
    feasible entries by price and only then falls back to SLO violators
    (also by price — "least over budget" is not the criterion; violators
    are flagged infeasible in the placement table)."""

    name = "slo_cheapest"

    def __init__(self, slo_s: float) -> None:
        if slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {slo_s}")
        self.slo_s = slo_s

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        return trace_cost_usd(hw, est, self.name)

    def feasible(self, hw: TPUSpec, est: Estimate) -> bool:
        return est.total_s <= self.slo_s

    def describe(self) -> str:
        return f"{self.name}(slo={self.slo_s*1e3:.1f}ms)"


class ResidualCorrectedObjective(Objective):
    """Wrap any objective so it scores *residual-corrected* estimates:
    before delegating to ``base``, the hardware's estimate is rescaled by
    its measured-vs-predicted correction factor (``corrections[hw.name]``,
    default 1.0 — uncorrected).

    The factors come from a ``repro.serve.monitor.ResidualMonitor``'s
    :meth:`~repro.serve.monitor.ResidualMonitor.corrections` — per-hw EWMA
    residual ratios of a live fleet. Re-running ``FleetRouter.route_many``
    under this wrapper is how the drift control loop re-places workloads
    against what the fleet *measures* instead of what the frozen predictor
    believed at fit time; ``FleetRouter.route_corrected`` and
    ``FleetSimulator.replay(monitor=...)`` build it for you."""

    name = "residual_corrected"

    def __init__(self, base: Union[str, Objective],
                 corrections: dict[str, float]) -> None:
        self.base = get_objective(base)
        for hw_name, factor in corrections.items():
            if not (factor > 0 and math.isfinite(factor)):
                raise ValueError(
                    f"correction factor for {hw_name!r} must be finite and "
                    f"> 0, got {factor}"
                )
        self.corrections = dict(corrections)

    def _corrected(self, hw: TPUSpec, est: Estimate) -> Estimate:
        factor = self.corrections.get(hw.name, 1.0)
        return est if factor == 1.0 else est.scaled(factor)

    def score(self, hw: TPUSpec, est: Estimate, *, n_tokens: Optional[float] = None) -> float:
        return self.base.score(hw, self._corrected(hw, est), n_tokens=n_tokens)

    def feasible(self, hw: TPUSpec, est: Estimate) -> bool:
        return self.base.feasible(hw, self._corrected(hw, est))

    def describe(self) -> str:
        facts = ", ".join(
            f"{hw}x{f:.3g}" for hw, f in sorted(self.corrections.items())
        )
        return f"{self.name}({self.base.describe()}; {facts or 'no corrections'})"


OBJECTIVES = {
    "latency": LatencyObjective,
    "cost": CostObjective,
    "cost_per_token": CostPerTokenObjective,
    "slo_cheapest": SLOCheapestObjective,
    "residual_corrected": ResidualCorrectedObjective,
}


def get_objective(spec: Union[str, Objective], **kwargs: Any) -> Objective:
    """Resolve an objective: an ``Objective`` instance passes through,
    a name constructs from :data:`OBJECTIVES` (``slo_cheapest`` requires
    ``slo_s=``)."""
    if isinstance(spec, Objective):
        if kwargs:
            raise TypeError("kwargs only apply when constructing by name")
        return spec
    try:
        cls = OBJECTIVES[spec]
    except KeyError:
        raise KeyError(
            f"unknown objective {spec!r}; registered: {sorted(OBJECTIVES)}"
        ) from None
    return cls(**kwargs)
