"""Predictor API surface: the call types every workload generator emits,
the ``Estimate`` result every backend returns, and the ``Predictor``
protocol that ties them together.

This module is the bottom of the predict-layer dependency stack — it must
not import anything from ``repro.core`` so that ``repro.core.e2e`` (the
workload generator) can re-export the call types without a cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Protocol, runtime_checkable


@dataclasses.dataclass
class KernelCall:
    """One kernel invocation: family name + the workload dict the
    decomposer understands. ``count`` repeats the call (may be fractional
    for amortized calls, e.g. Simpson decode weights)."""

    kind: str
    X: dict
    count: float = 1


@dataclasses.dataclass
class CommCall:
    """One collective: op name, payload bytes, participant count.

    ``skew`` is the routing-imbalance knob for all-to-alls (the same
    dirichlet skew the fused-MoE decomposition's ``routing_counts``
    uses): 0 = balanced traffic (the legacy contention model, exactly),
    larger = a hotter chip serializing the exchange. Backends that model
    congestion (the hwsim oracle) price it; alpha-beta regressor
    backends are fitted on balanced traffic and apply the analytical
    hot-chip factor on top."""

    op: str
    nbytes: float
    n_units: int
    count: float = 1
    skew: float = 0.0


# a call sequence may nest groups: (label, repetitions, sub-sequence),
# e.g. model_calls() emits [("layers", n_layers, [...]), ("head", 1, [...])]
CallSeq = Iterable


def flatten_calls(calls: CallSeq, weight: float = 1.0, _out: Optional[list] = None) -> list:
    """Flatten a (possibly nested) call sequence into ``(call, weight)``
    pairs, folding group repetitions and per-call counts into the weight."""
    out = [] if _out is None else _out
    for item in calls:
        if isinstance(item, (KernelCall, CommCall)):
            out.append((item, weight * item.count))
        else:  # (label, reps, sub-sequence) group
            _, reps, seq = item
            flatten_calls(seq, weight * reps, out)
    return out


class UntrainedFamilyError(RuntimeError):
    """Raised when a backend is asked to predict a kernel family it has no
    model for and the fallback policy is ``"error"`` (the default — silent
    oracle substitution hid real coverage gaps, see ISSUE 2)."""

    def __init__(self, backend: str, kind: str, supported: Iterable[str]) -> None:
        self.backend = backend
        self.kind = kind
        self.supported = sorted(supported)
        super().__init__(
            f"predictor {backend!r} has no model for kernel family {kind!r} "
            f"(trained families: {self.supported}); pass "
            f'fallback="oracle" or fallback="roofline" to get_predictor() '
            f"for an explicit substitute, or train the missing family"
        )


@dataclasses.dataclass
class Estimate:
    """Batched prediction result.

    ``theoretical_s`` is the analytical ceiling (sum of per-call
    dominant-pipe roofline times); it is ``None`` only for the legacy
    two-lambda adapter, which has no feature analyzer to ask.
    ``fallbacks`` records which families were served by a substitute
    backend (explicit-fallback policy) — empty when every family had a
    model.

    ``overlap_window_s`` is the cross-pipeline exposed-compute window
    (``repro.core.features.overlap_window_s``): the kernel time the
    network can hide under when collectives launch as early as their
    operands exist. ``total_s`` is still the *additive* (serialized)
    sum — :meth:`overlapped` re-prices with the window subtracted from
    the comm component, bounded below by pure compute.
    """

    total_s: float
    kernel_s: float
    comm_s: float
    theoretical_s: Optional[float]
    by_family: dict
    by_comm_op: dict
    n_kernel_calls: float
    n_comm_calls: float
    fallbacks: dict
    #: exposed-compute window the comm can hide under (None for backends
    #: that cannot derive it, e.g. the legacy two-lambda adapter)
    overlap_window_s: Optional[float] = None

    def scaled(self, k: float) -> "Estimate":
        """Scale every latency component by ``k`` (e.g. the pipeline
        bubble surcharge); call counts and fallback records are kept."""
        return Estimate(
            total_s=self.total_s * k,
            kernel_s=self.kernel_s * k,
            comm_s=self.comm_s * k,
            theoretical_s=None if self.theoretical_s is None else self.theoretical_s * k,
            by_family={f: t * k for f, t in self.by_family.items()},
            by_comm_op={o: t * k for o, t in self.by_comm_op.items()},
            n_kernel_calls=self.n_kernel_calls,
            n_comm_calls=self.n_comm_calls,
            fallbacks=dict(self.fallbacks),
            overlap_window_s=(
                None if self.overlap_window_s is None else self.overlap_window_s * k
            ),
        )

    def overlapped(self, window_s: Optional[float] = None) -> "Estimate":
        """Overlap-aware re-pricing: per-step comm becomes
        ``max(0, comm_s - window)`` instead of additive.

        ``window_s`` defaults to the estimate's own ``overlap_window_s``
        (falling back to 0.0 — i.e. the additive estimate — when the
        backend could not derive one). The window never exceeds
        ``kernel_s`` by construction, so the overlapped total is always
        bounded: ``kernel_s <= total_s' <= kernel_s + comm_s`` — never
        below pure compute, never above the additive estimate (the
        regression ``tests``/``bench_parallelism`` gate). The per-op
        breakdown is rescaled proportionally so it still sums to the
        exposed comm time.
        """
        w = self.overlap_window_s if window_s is None else window_s
        w = 0.0 if w is None else min(max(w, 0.0), self.kernel_s)
        exposed = max(0.0, self.comm_s - w)
        shrink = exposed / self.comm_s if self.comm_s > 0 else 0.0
        return Estimate(
            total_s=self.kernel_s + exposed,
            kernel_s=self.kernel_s,
            comm_s=exposed,
            theoretical_s=self.theoretical_s,
            by_family=dict(self.by_family),
            by_comm_op={o: t * shrink for o, t in self.by_comm_op.items()},
            n_kernel_calls=self.n_kernel_calls,
            n_comm_calls=self.n_comm_calls,
            fallbacks=dict(self.fallbacks),
            overlap_window_s=w,
        )

    def pretty(self) -> str:
        parts = [f"total={self.total_s*1e3:.2f}ms"]
        if self.theoretical_s is not None:
            parts.append(f"ceiling={self.theoretical_s*1e3:.2f}ms")
        fams = sorted(self.by_family.items(), key=lambda kv: -kv[1])
        parts += [f"{f}={t*1e3:.2f}ms" for f, t in fams]
        parts += [f"{o}={t*1e3:.2f}ms" for o, t in sorted(self.by_comm_op.items())]
        if self.fallbacks:
            parts.append("fallbacks=" + ",".join(f"{k}->{v}" for k, v in sorted(self.fallbacks.items())))
        return "  ".join(parts)


@runtime_checkable
class Predictor(Protocol):
    """What every backend implements: batched estimation over call
    sequences plus scalar conveniences for one-off queries."""

    def predict(self, calls: CallSeq) -> Estimate: ...

    def kernel_time(self, kind: str, X: dict) -> float: ...

    def comm_time(
        self, op: str, nbytes: float, n_units: int, skew: float = 0.0
    ) -> float: ...
