"""Predictor backends behind one registry (paper §IV estimator + §VI
baselines + the roofline bound + the hwsim oracle)::

    get_predictor("synperf", hw, estimator=pw)   # PipeWeave per-family MLPs
    get_predictor("roofline", hw)                # analytical ceiling
    get_predictor("linear", hw, models={...})    # fitted §VI baselines
    get_predictor("oracle", hw)                  # hwsim ("measured")

All backends share the batched path: calls are grouped per kernel family
(deduplicated by canonical workload), featurization is memoized, and the
ML backends run one vectorized forward per family. Families a backend has
no model for follow an *explicit* fallback policy — ``"error"`` (default),
``"oracle"`` or ``"roofline"`` — and every substitution is recorded in
``Estimate.fallbacks``; nothing falls back silently.
"""
from __future__ import annotations

import glob
import os
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from repro.core import hwsim
from repro.core.dataset import KernelDataset
from repro.core.features import overlap_window_s
from repro.core.hardware import TPUSpec
from repro.predict.api import CallSeq, Estimate, KernelCall, UntrainedFamilyError
from repro.predict.batching import FeatureCache, group_calls
from repro.predict.comm import CommRegressor

if TYPE_CHECKING:
    from repro.core.estimator import PipeWeave


class BasePredictor:
    """Shared batched-estimation engine. Subclasses provide
    ``_family_latencies`` (vectorized per-family prediction) and may
    restrict ``families()``; everything else — grouping, featurize
    memoization, fallback policy, comm, Estimate assembly — lives here."""

    name = "base"
    #: legacy adapters have no feature analyzer; they set this False and
    #: report ``Estimate.theoretical_s = None``
    compute_theoretical = True

    def __init__(
        self,
        hw: TPUSpec | None,
        *,
        comm: CommRegressor | None = None,
        fallback: str = "error",
        cache: FeatureCache | None = None,
    ) -> None:
        if fallback not in ("error", "oracle", "roofline"):
            raise ValueError(f"fallback must be error|oracle|roofline, got {fallback!r}")
        self.hw = hw
        self.fallback = fallback
        self.cache = cache if cache is not None else FeatureCache()
        self._comm = comm

    # -- extension points -------------------------------------------------

    def families(self) -> set | None:
        """Kernel families this backend has a model for; None = any the
        decomposer understands."""
        return None

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        raise NotImplementedError

    # -- comm -------------------------------------------------------------

    @property
    def comm(self) -> CommRegressor:
        """The comm half of the backend; auto-fitted on first use."""
        if self._comm is None:
            self._comm = CommRegressor().fit(self.hw)
        return self._comm

    def _comm_latency(
        self, op: str, nbytes: float, n_units: int, skew: float = 0.0
    ) -> float:
        # the alpha-beta regressor is fitted on balanced traffic; routing
        # skew stretches the exchange by the analytical hot-chip factor
        # (the same model the hwsim oracle prices natively)
        t = self.comm.predict(op, nbytes, n_units)
        if op == "all_to_all" and skew > 0.0:
            t *= hwsim.a2a_hot_ratio(skew, n_units)
        return t

    # -- batched prediction ----------------------------------------------

    def _theoretical_latencies(self, kind: str, workloads: list) -> np.ndarray:
        """Analytical (roofline) ceiling per workload, via the cache."""
        return np.asarray(
            [self.cache.featureset(kind, X, self.hw).theoretical_s for X in workloads],
            np.float64,
        )

    def _oracle_latencies(self, kind: str, workloads: list) -> np.ndarray:
        return np.asarray(
            [hwsim.simulate(kind, X, self.hw) for X in workloads], np.float64
        )

    def _fallback_latencies(self, kind: str, workloads: list) -> np.ndarray:
        if self.fallback == "error":
            raise UntrainedFamilyError(self.name, kind, self.families() or ())
        if self.fallback == "oracle":
            return self._oracle_latencies(kind, workloads)
        return self._theoretical_latencies(kind, workloads)

    def predict(self, calls: CallSeq) -> Estimate:
        return self.predict_grouped(*group_calls(calls))

    def predict_grouped(self, families: dict, comms: dict) -> Estimate:
        """Estimate pre-grouped calls (the output of ``group_calls``).
        ``SweepPredictor`` uses this to flatten+group a trace once and fan
        out only the per-hardware stages."""
        by_family: dict = {}
        fallbacks: dict = {}
        kernel_s = 0.0
        theo_s = 0.0
        n_kernel = 0.0
        supported = self.families()
        for kind, grp in families.items():
            if supported is None or kind in supported:
                lats = np.asarray(self._family_latencies(kind, grp.workloads), np.float64)
            else:
                lats = self._fallback_latencies(kind, grp.workloads)
                fallbacks[kind] = self.fallback
            w = grp.weight_array
            fam_s = float(lats @ w)
            by_family[kind] = fam_s
            kernel_s += fam_s
            n_kernel += float(w.sum())
            if self.compute_theoretical:
                theo_s += float(self._theoretical_latencies(kind, grp.workloads) @ w)
        by_comm: dict = {}
        comm_s = 0.0
        n_comm = 0.0
        for (op, nbytes, n_units, skew), w in comms.items():
            t = w * self._comm_latency(op, nbytes, n_units, skew)
            by_comm[op] = by_comm.get(op, 0.0) + t
            comm_s += t
            n_comm += w
        return Estimate(
            total_s=kernel_s + comm_s,
            kernel_s=kernel_s,
            comm_s=comm_s,
            theoretical_s=theo_s if self.compute_theoretical else None,
            by_family=by_family,
            by_comm_op=by_comm,
            n_kernel_calls=n_kernel,
            n_comm_calls=n_comm,
            fallbacks=fallbacks,
            # cross-pipeline exposed-compute window (features.overlap_window_s):
            # what Estimate.overlapped() subtracts from the comm component
            overlap_window_s=overlap_window_s(kernel_s, n_comm),
        )

    # -- scalar conveniences ----------------------------------------------

    def kernel_time(self, kind: str, X: dict) -> float:
        return self.predict([KernelCall(kind, X)]).kernel_s

    def comm_time(
        self, op: str, nbytes: float, n_units: int, skew: float = 0.0
    ) -> float:
        return self._comm_latency(op, nbytes, n_units, skew)

    def as_times(self) -> tuple:
        """Legacy ``(kernel_time, comm_time)`` lambda pair (the old
        ``oracle_times``/``predictor_times`` plumbing)."""
        return (
            lambda kind, X: self.kernel_time(kind, X),
            lambda op, nbytes, n: self.comm_time(op, nbytes, n),
        )


class SynPerfPredictor(BasePredictor):
    """The paper's hybrid predictor: cached analytical featurization + one
    vectorized per-family MLP forward, latency = theoretical / efficiency."""

    name = "synperf"

    def __init__(
        self, hw: TPUSpec, estimator: "PipeWeave | str | None" = None, **kw: Any
    ) -> None:
        super().__init__(hw, **kw)
        from repro.core.estimator import PipeWeave

        if estimator is None:
            estimator = _load_cached_pipeweave()
        elif isinstance(estimator, str):
            estimator = PipeWeave.load(estimator)
        self.estimator = estimator

    def families(self) -> set:
        return set(self.estimator.models)

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        vecs = np.stack([self.cache.vector(kind, X, self.hw) for X in workloads])
        eff = self.estimator.predict_eff(kind, vecs)
        return self._theoretical_latencies(kind, workloads) / eff


class RooflinePredictor(BasePredictor):
    """Perfect-efficiency first-order model: latency = analytical ceiling."""

    name = "roofline"

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        return self._theoretical_latencies(kind, workloads)


class OraclePredictor(BasePredictor):
    """hwsim-backed 'measured' times — the ground-truth system every other
    backend is scored against. Comm always comes from the comm oracle."""

    name = "oracle"

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        return self._oracle_latencies(kind, workloads)

    def _comm_latency(
        self, op: str, nbytes: float, n_units: int, skew: float = 0.0
    ) -> float:
        return hwsim.simulate_comm(op, nbytes, n_units, self.hw, skew)


class BaselinePredictor(BasePredictor):
    """Wraps the fitted §VI-A baselines (``repro.core.baselines``) — one
    fitted model per kernel family — behind the batched interface by
    building a single per-family KernelDataset per predict() call."""

    name = "baseline"

    def __init__(
        self, hw: TPUSpec, models: dict | None = None, baseline: str = "", **kw: Any
    ) -> None:
        super().__init__(hw, **kw)
        if not models:
            raise TypeError(
                f"predictor {baseline or 'baseline'!r} needs fitted per-family models: "
                "get_predictor(name, hw, models={kind: BASELINES[name]().fit(ds)})"
                " — see benchmarks/common.py:get_baseline"
            )
        self.models = models
        if baseline:
            self.name = baseline

    def families(self) -> set:
        return set(self.models)

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        vecs = np.stack([self.cache.vector(kind, X, self.hw) for X in workloads])
        theo = self._theoretical_latencies(kind, workloads)
        ds = KernelDataset(
            kind=kind,
            X=vecs,
            y_eff=np.ones(len(workloads), np.float32),
            theoretical_s=theo,
            actual_s=theo,
            hw_names=[self.hw.name] * len(workloads),
            workloads=list(workloads),
        )
        return np.maximum(np.asarray(self.models[kind].predict(ds), np.float64), 1e-9)


class CallableTimesPredictor(BasePredictor):
    """Adapter for the legacy two-lambda plumbing: wraps raw
    ``kernel_time(kind, X)`` / ``comm_time(op, nbytes, n)`` callables.
    Still deduplicates repeated shapes, but cannot batch model forwards or
    report the analytical ceiling (``Estimate.theoretical_s`` is None)."""

    name = "callable"
    compute_theoretical = False

    def __init__(self, kernel_time: Callable, comm_time: Callable) -> None:
        super().__init__(hw=None)
        self._kernel_time = kernel_time
        self._comm_time = comm_time

    def _family_latencies(self, kind: str, workloads: list) -> np.ndarray:
        return np.asarray([self._kernel_time(kind, X) for X in workloads], np.float64)

    def _comm_latency(
        self, op: str, nbytes: float, n_units: int, skew: float = 0.0
    ) -> float:
        # the legacy two-lambda callables predate the skew knob; balanced
        # pricing keeps the deprecation shim bit-stable
        return self._comm_time(op, nbytes, n_units)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------


def _baseline_factory(name: str) -> Callable[..., "BaselinePredictor"]:
    def make(hw: TPUSpec, **kw: Any) -> BaselinePredictor:
        return BaselinePredictor(hw, baseline=name, **kw)

    return make


PREDICTORS = {
    "synperf": SynPerfPredictor,
    "roofline": RooflinePredictor,
    "oracle": OraclePredictor,
    "linear": _baseline_factory("linear"),
    "habitat": _baseline_factory("habitat"),
    "neusight": _baseline_factory("neusight"),
}


def get_predictor(name: str, hw: TPUSpec, **kwargs: Any) -> BasePredictor:
    """One constructor for every backend.

    Common kwargs: ``comm`` (a fitted CommRegressor; auto-fitted on ``hw``
    when omitted), ``fallback`` ("error" | "oracle" | "roofline"),
    ``cache`` (a shared FeatureCache). Backend-specific: ``estimator`` (a
    PipeWeave or pickle path) for "synperf"; ``models`` ({kind: fitted
    baseline}) for "linear"/"habitat"/"neusight".
    """
    try:
        factory = PREDICTORS[name]
    except KeyError:
        raise KeyError(
            f"unknown predictor {name!r}; registered: {sorted(PREDICTORS)}"
        ) from None
    return factory(hw, **kwargs)


def _load_cached_pipeweave() -> "PipeWeave":
    """Default estimator for ``get_predictor("synperf", hw)`` with no
    explicit ``estimator=``: the newest PipeWeave pickle in the benchmark
    cache (written by ``benchmarks.common.get_pipeweave``)."""
    from repro.core.estimator import PipeWeave

    cache_dir = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")
    candidates = sorted(
        glob.glob(os.path.join(cache_dir, "pipeweave_*.pkl")),
        key=os.path.getmtime,
        reverse=True,
    )
    for path in candidates:
        try:
            return PipeWeave.load(path)
        except RuntimeError:
            continue  # stale / unversioned cache entry
    raise RuntimeError(
        'get_predictor("synperf", hw) found no trained estimator: pass '
        "estimator=<PipeWeave or pickle path>, or populate the benchmark "
        f"cache ({cache_dir}) via benchmarks.common.get_pipeweave()"
    )
