"""repro.predict — the unified predictor API (SynPerf §IV as a library).

One interface for every latency estimator in the repo: a ``Predictor``
turns a list (or nested groups) of ``KernelCall``/``CommCall`` into an
``Estimate`` — total latency plus per-kernel-family / per-comm-op
breakdowns and the analytical roofline ceiling. Backends (the PipeWeave
MLPs, the §VI baselines, the analytical roofline, the hwsim oracle) live
behind one constructor::

    from repro.predict import get_predictor
    est = get_predictor("synperf", hw, estimator=pw).predict(calls)

Batched prediction groups calls by (kind, canonical workload), memoizes
``featurize`` across repeated shapes, and runs one vectorized MLP forward
per kernel family — see ``repro/predict/batching.py`` and
``docs/predict.md``.

Multi-hardware sweeps (the paper's generalization protocol) run one trace
against many registry entries sharing one grouping pass and one task-level
cache::

    from repro.predict import SweepPredictor
    res = SweepPredictor(["tpu-v5e", "tpu-v6e"], estimator=pw).predict(calls)
"""
from repro.predict.api import (
    CommCall,
    Estimate,
    KernelCall,
    Predictor,
    UntrainedFamilyError,
    flatten_calls,
)
from repro.predict.batching import FeatureCache, canonical_x, group_calls, task_sig
from repro.predict.comm import CommRegressor
from repro.predict.objective import (
    OBJECTIVES,
    Objective,
    UnpricedHardwareError,
    get_objective,
    trace_cost_usd,
)
from repro.predict.sweep import SweepComparison, SweepPredictor, SweepResult, hw_split
from repro.predict.backends import (
    PREDICTORS,
    BaselinePredictor,
    BasePredictor,
    CallableTimesPredictor,
    OraclePredictor,
    RooflinePredictor,
    SynPerfPredictor,
    get_predictor,
)

__all__ = [
    "CommCall",
    "CommRegressor",
    "Estimate",
    "FeatureCache",
    "KernelCall",
    "OBJECTIVES",
    "Objective",
    "PREDICTORS",
    "Predictor",
    "UnpricedHardwareError",
    "UntrainedFamilyError",
    "BaselinePredictor",
    "BasePredictor",
    "CallableTimesPredictor",
    "OraclePredictor",
    "RooflinePredictor",
    "SweepComparison",
    "SweepPredictor",
    "SweepResult",
    "SynPerfPredictor",
    "canonical_x",
    "flatten_calls",
    "get_objective",
    "get_predictor",
    "group_calls",
    "hw_split",
    "task_sig",
    "trace_cost_usd",
]
