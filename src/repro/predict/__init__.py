"""repro.predict — the unified predictor API (SynPerf §IV as a library).

One interface for every latency estimator in the repo: a ``Predictor``
turns a list (or nested groups) of ``KernelCall``/``CommCall`` into an
``Estimate`` — total latency plus per-kernel-family / per-comm-op
breakdowns and the analytical roofline ceiling. Backends (the PipeWeave
MLPs, the §VI baselines, the analytical roofline, the hwsim oracle) live
behind one constructor::

    from repro.predict import get_predictor
    est = get_predictor("synperf", hw, estimator=pw).predict(calls)

Batched prediction groups calls by (kind, canonical workload), memoizes
``featurize`` across repeated shapes, and runs one vectorized MLP forward
per kernel family — see ``repro/predict/batching.py`` and
``docs/predict.md``.
"""
from repro.predict.api import (
    CommCall,
    Estimate,
    KernelCall,
    Predictor,
    UntrainedFamilyError,
    flatten_calls,
)
from repro.predict.batching import FeatureCache, canonical_x, group_calls
from repro.predict.comm import CommRegressor
from repro.predict.backends import (
    PREDICTORS,
    BaselinePredictor,
    BasePredictor,
    CallableTimesPredictor,
    OraclePredictor,
    RooflinePredictor,
    SynPerfPredictor,
    get_predictor,
)

__all__ = [
    "CommCall",
    "CommRegressor",
    "Estimate",
    "FeatureCache",
    "KernelCall",
    "PREDICTORS",
    "Predictor",
    "UntrainedFamilyError",
    "BaselinePredictor",
    "BasePredictor",
    "CallableTimesPredictor",
    "OraclePredictor",
    "RooflinePredictor",
    "SynPerfPredictor",
    "canonical_x",
    "flatten_calls",
    "get_predictor",
    "group_calls",
]
