"""Multi-hardware sweep prediction (the paper's generalization protocol).

SynPerf's headline claim is one estimator generalizing *across hardware*:
the same kernel trace priced on every registry entry, errors reported per
kernel family over the seen/unseen split. ``SweepPredictor`` runs that
protocol as one pass:

    sweep = SweepPredictor(REGISTRY, estimator=pw)
    res = sweep.predict(trace)          # {hw name: Estimate}
    cmp = sweep.compare(trace)          # measured (oracle) vs predicted

Cost model — why a sweep is cheaper than N independent predicts:

  1. the trace is flattened and grouped by (kind, canonical shape) once
     (``group_calls`` dominates single-hw predict on long traces);
  2. decompose+schedule run once per (kind, shape, task-signature) — most
     hardware shares a signature (``batching.task_sig``), so task
     construction does not fan out per device;
  3. only ``analyze`` + the feature vector + one vectorized MLP forward
     per (family, hw) are per-device.

``benchmarks/bench_sweep.py`` asserts the resulting wall-clock: a sweep
over 6 hardware on the 12k-call decode trace stays under 3x a single-hw
predict (vs ~6x for independent passes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ItemsView, Iterable, Iterator, Optional

import numpy as np

from repro.core.hardware import REGISTRY, TPUSpec, get_hw
from repro.predict.api import CallSeq, CommCall, Estimate, KernelCall
from repro.predict.batching import FeatureCache, group_calls


def _resolve_hws(hws: Optional[Iterable]) -> list[TPUSpec]:
    if hws is None:
        return list(REGISTRY.values())
    out = []
    for h in hws:
        out.append(get_hw(h) if isinstance(h, str) else h)
    if not out:
        raise ValueError("SweepPredictor needs at least one hardware")
    names = [h.name for h in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate hardware in sweep: {names}")
    return out


def check_prebuilt_exclusive(
    name: str, prebuilt: object, hws: Optional[Iterable], backend: str, backend_kw: dict
) -> None:
    """Shared guard for the ``sweep=``/``router=`` convenience kwargs:
    a prebuilt object already carries its hardware list and backends, so
    combining it with construction kwargs is ambiguous and refused."""
    if prebuilt is not None and (hws is not None or backend != "synperf" or backend_kw):
        raise TypeError(
            f"pass either {name}= (a prebuilt object) or "
            "hws=/backend=/backend kwargs, not both"
        )


def hw_split(name: str) -> str:
    """``"seen"`` / ``"unseen"`` for registry entries (the paper's
    training/held-out hardware split), ``"?"`` for off-registry specs."""
    spec = REGISTRY.get(name)
    return "?" if spec is None else ("seen" if spec.seen else "unseen")


_split = hw_split  # backward-compatible private alias


@dataclasses.dataclass
class SweepResult:
    """Per-hardware estimates for one trace. Mapping-ish: iterate items(),
    index by hw name."""

    estimates: dict  # hw name -> Estimate, sweep order

    def __getitem__(self, hw_name: str) -> Estimate:
        return self.estimates[hw_name]

    def __iter__(self) -> Iterator:
        return iter(self.estimates)

    def __len__(self) -> int:
        return len(self.estimates)

    def items(self) -> ItemsView:
        return self.estimates.items()

    def totals(self) -> dict:
        return {name: est.total_s for name, est in self.estimates.items()}

    def scaled(self, k: float) -> "SweepResult":
        return SweepResult({n: e.scaled(k) for n, e in self.estimates.items()})

    def overlapped(self) -> "SweepResult":
        """Overlap-aware re-pricing of every device's estimate
        (``Estimate.overlapped``): each uses its own exposed-compute
        window, so slower devices (longer kernel time for the same trace)
        hide proportionally more of the same collectives."""
        return SweepResult({n: e.overlapped() for n, e in self.estimates.items()})

    def table(self) -> str:
        """Per-hw latency table, seen/unseen tagged, fastest first."""
        rows = sorted(self.estimates.items(), key=lambda kv: kv[1].total_s)
        lines = [f"{'hardware':<14} {'split':<7} {'total':>10} {'kernel':>10} "
                 f"{'comm':>10} {'ceiling':>10}"]
        for name, est in rows:
            ceil = "-" if est.theoretical_s is None else f"{est.theoretical_s*1e3:.2f}ms"
            lines.append(
                f"{name:<14} {_split(name):<7} {est.total_s*1e3:>8.2f}ms "
                f"{est.kernel_s*1e3:>8.2f}ms {est.comm_s*1e3:>8.2f}ms {ceil:>10}"
            )
        return "\n".join(lines)


@dataclasses.dataclass
class SweepComparison:
    """Measured-vs-predicted over a sweep: one row per (hw, family) plus
    per-request totals — the data behind the paper's Table IX layout.

    All latencies are **seconds for the whole compared trace** (the sum of
    every recorded/weighted step), not per-step or per-token values;
    "measured" means the ``reference`` backend of :meth:`SweepPredictor
    .compare` (default: the hwsim oracle), not this process's wall-clock.
    """

    #: hw name -> family -> (measured_s, predicted_s), trace totals
    by_family: dict
    #: hw name -> (measured_total_s, predicted_total_s), trace totals
    totals: dict

    def err_pct(self, hw_name: str) -> float:
        """Absolute relative total-latency error for one hardware, in
        percent (``|predicted - measured| / measured * 100``)."""
        m, p = self.totals[hw_name]
        return abs(p - m) / max(m, 1e-12) * 100.0

    def split_mape(self) -> dict:
        """``{"seen": ..., "unseen": ...}`` mean absolute total-latency
        error in **percent** over the registry's seen/unseen hardware
        split — the generalization headline numbers. Each hardware
        contributes its whole-trace :meth:`err_pct` (an error on totals,
        not a mean of per-kernel errors); off-registry specs (split
        ``"?"``) are excluded, and an empty split is ``nan`` — callers
        like :meth:`table` must omit it rather than print ``nan%``."""
        out = {"seen": [], "unseen": []}
        for name in self.totals:
            split = hw_split(name)
            if split != "?":
                out[split].append(self.err_pct(name))
        return {k: float(np.mean(v)) if v else float("nan") for k, v in out.items()}

    def family_mape(self) -> dict:
        """``{family: error_pct}`` — mean absolute error in **percent** of
        each kernel family's *per-trace total seconds*, averaged across
        all swept hardware (the Table VIII analogue). Comm ops are not
        included: only kernel families appear in ``by_family``."""
        errs: dict = {}
        for fams in self.by_family.values():
            for fam, (m, p) in fams.items():
                errs.setdefault(fam, []).append(abs(p - m) / max(m, 1e-12) * 100.0)
        return {f: float(np.mean(v)) for f, v in errs.items()}

    def table(self) -> str:
        lines = [f"{'hardware':<14} {'split':<7} {'measured':>10} {'predicted':>10} {'err':>7}"]
        for name, (m, p) in sorted(self.totals.items(), key=lambda kv: kv[1][0]):
            lines.append(
                f"{name:<14} {_split(name):<7} {m*1e3:>8.2f}ms {p*1e3:>8.2f}ms "
                f"{self.err_pct(name):>6.1f}%"
            )
        sm = self.split_mape()
        for split in ("seen", "unseen"):
            if not np.isnan(sm[split]):
                lines.append(f"{'mean':<14} {split:<7} {'':>10} {'':>10} {sm[split]:>6.1f}%")
        return "\n".join(lines)


class SweepPredictor:
    """One trace, many devices: a per-hardware family of predictor backends
    sharing one ``FeatureCache`` (task- and feature-level memoization) and
    one grouping pass per trace.

    ``hws`` is an iterable of hardware names or specs (default: the whole
    registry). ``backend`` + ``**backend_kw`` are forwarded to
    ``get_predictor`` per hardware — e.g. ``estimator=pw`` for "synperf"
    (the estimator is hw-independent and shared). A ``predictors`` mapping
    of pre-built backends overrides construction entirely (they should
    share a cache to benefit from the sweep).

    Conventions (shared with ``docs/predict.md``):

      * every returned latency is **seconds for the whole priced trace**;
        per-step views come from :meth:`predict_steps`;
      * traces are call sequences — flat ``KernelCall``/``CommCall`` lists
        or nested ``(label, repetitions, sub_sequence)`` groups. Workload
        shapes are the *launched* shapes (padded batch) with the longest
        **attended** KV span per step — the decomposer's convention, which
        ``TraceRecorder`` follows, so recorded traces, synthetic
        ``request_calls`` and the hwsim oracle are mutually comparable;
      * the sweep is exact: per-hw results equal independent
        ``get_predictor(backend, hw).predict(trace)`` calls
        (``tests/test_sweep.py`` pins this at 1e-9 relative) — sharing
        only removes redundant work, never approximates."""

    def __init__(
        self,
        hws: Optional[Iterable] = None,
        backend: str = "synperf",
        *,
        cache: Optional[FeatureCache] = None,
        predictors: Optional[dict] = None,
        **backend_kw: Any,
    ) -> None:
        from repro.predict.backends import get_predictor

        self.cache = cache if cache is not None else FeatureCache()
        if predictors is None:
            self.hws = _resolve_hws(hws)
            predictors = {
                hw.name: get_predictor(backend, hw, cache=self.cache, **backend_kw)
                for hw in self.hws
            }
        else:
            # pre-built backends carry their own spec; fall back to the
            # registry for adapters constructed without one. Keys must be
            # the hardware names — predict()/compare() index by them.
            hws = []
            for name, p in predictors.items():
                spec = p.hw if p.hw is not None else get_hw(name)
                if name != spec.name:
                    raise ValueError(
                        f"predictors key {name!r} != its backend's hardware "
                        f"name {spec.name!r}; key the mapping by hw name"
                    )
                hws.append(spec)
            self.hws = hws
        self.predictors = predictors

    @property
    def hw_names(self) -> list:
        return [hw.name for hw in self.hws]

    def predict(self, calls: CallSeq) -> SweepResult:
        """Group once, estimate per hardware."""
        families, comms = group_calls(calls)
        return SweepResult(
            {
                hw.name: self.predictors[hw.name].predict_grouped(families, comms)
                for hw in self.hws
            }
        )

    def predict_steps(self, calls: CallSeq) -> dict:
        """Per-step estimates across the sweep: ``{hw name: [(label,
        Estimate), ...]}`` with one entry per *top-level* group of
        ``calls`` (a ``TraceRecorder`` trace has one group per executed
        engine step; bare calls between groups are folded into an
        anonymous ``"calls"`` step).

        This is the per-step view the placement layer builds on (e.g.
        pricing prefill-class vs decode-class steps separately), and it is
        cheap by construction: every step shares this sweep's
        ``FeatureCache``, so the decompose/schedule/demand levels are
        warmed once per unique shape no matter how many steps repeat it —
        only the per-step grouping pass and the (memoized) feature lookups
        fan out. Estimates are per *single execution* of each step times
        its group repetition count, in trace order."""
        steps: list = []
        loose: list = []
        for item in calls:
            if isinstance(item, (KernelCall, CommCall)):
                loose.append(item)
            else:
                if loose:
                    steps.append(("calls", 1.0, loose))
                    loose = []
                steps.append(item)
        if loose:
            steps.append(("calls", 1.0, loose))
        out: dict = {hw.name: [] for hw in self.hws}
        for label, reps, seq in steps:
            families, comms = group_calls([(label, reps, seq)])
            for hw in self.hws:
                est = self.predictors[hw.name].predict_grouped(families, comms)
                out[hw.name].append((label, est))
        return out

    def compare(self, calls: CallSeq, *, reference: str = "oracle") -> SweepComparison:
        """Measured (``reference`` backend, default the hwsim oracle) vs
        predicted, per hardware and per kernel family, over one grouping
        pass. This is the paper's seen/unseen evaluation protocol."""
        from repro.predict.backends import get_predictor

        families, comms = group_calls(calls)
        by_family: dict = {}
        totals: dict = {}
        for hw in self.hws:
            ref = get_predictor(reference, hw, cache=self.cache)
            measured = ref.predict_grouped(families, comms)
            predicted = self.predictors[hw.name].predict_grouped(families, comms)
            by_family[hw.name] = {
                fam: (measured.by_family[fam], predicted.by_family[fam])
                for fam in measured.by_family
            }
            totals[hw.name] = (measured.total_s, predicted.total_s)
        return SweepComparison(by_family=by_family, totals=totals)
