"""Multi-hardware sweep prediction (the paper's generalization protocol).

SynPerf's headline claim is one estimator generalizing *across hardware*:
the same kernel trace priced on every registry entry, errors reported per
kernel family over the seen/unseen split. ``SweepPredictor`` runs that
protocol as one pass:

    sweep = SweepPredictor(REGISTRY, estimator=pw)
    res = sweep.predict(trace)          # {hw name: Estimate}
    cmp = sweep.compare(trace)          # measured (oracle) vs predicted

Cost model — why a sweep is cheaper than N independent predicts:

  1. the trace is flattened and grouped by (kind, canonical shape) once
     (``group_calls`` dominates single-hw predict on long traces);
  2. decompose+schedule run once per (kind, shape, task-signature) — most
     hardware shares a signature (``batching.task_sig``), so task
     construction does not fan out per device;
  3. only ``analyze`` + the feature vector + one vectorized MLP forward
     per (family, hw) are per-device.

``benchmarks/bench_sweep.py`` asserts the resulting wall-clock: a sweep
over 6 hardware on the 12k-call decode trace stays under 3x a single-hw
predict (vs ~6x for independent passes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from repro.core.hardware import REGISTRY, TPUSpec, get_hw
from repro.predict.api import Estimate
from repro.predict.batching import FeatureCache, group_calls


def _resolve_hws(hws) -> list[TPUSpec]:
    if hws is None:
        return list(REGISTRY.values())
    out = []
    for h in hws:
        out.append(get_hw(h) if isinstance(h, str) else h)
    if not out:
        raise ValueError("SweepPredictor needs at least one hardware")
    names = [h.name for h in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate hardware in sweep: {names}")
    return out


def _split(name: str) -> str:
    spec = REGISTRY.get(name)
    return "?" if spec is None else ("seen" if spec.seen else "unseen")


@dataclasses.dataclass
class SweepResult:
    """Per-hardware estimates for one trace. Mapping-ish: iterate items(),
    index by hw name."""

    estimates: dict  # hw name -> Estimate, sweep order

    def __getitem__(self, hw_name: str) -> Estimate:
        return self.estimates[hw_name]

    def __iter__(self):
        return iter(self.estimates)

    def __len__(self):
        return len(self.estimates)

    def items(self):
        return self.estimates.items()

    def totals(self) -> dict:
        return {name: est.total_s for name, est in self.estimates.items()}

    def scaled(self, k: float) -> "SweepResult":
        return SweepResult({n: e.scaled(k) for n, e in self.estimates.items()})

    def table(self) -> str:
        """Per-hw latency table, seen/unseen tagged, fastest first."""
        rows = sorted(self.estimates.items(), key=lambda kv: kv[1].total_s)
        lines = [f"{'hardware':<14} {'split':<7} {'total':>10} {'kernel':>10} "
                 f"{'comm':>10} {'ceiling':>10}"]
        for name, est in rows:
            ceil = "-" if est.theoretical_s is None else f"{est.theoretical_s*1e3:.2f}ms"
            lines.append(
                f"{name:<14} {_split(name):<7} {est.total_s*1e3:>8.2f}ms "
                f"{est.kernel_s*1e3:>8.2f}ms {est.comm_s*1e3:>8.2f}ms {ceil:>10}"
            )
        return "\n".join(lines)


@dataclasses.dataclass
class SweepComparison:
    """Measured-vs-predicted over a sweep: one row per (hw, family) plus
    per-request totals — the data behind the paper's Table IX layout."""

    #: hw name -> family -> (measured_s, predicted_s)
    by_family: dict
    #: hw name -> (measured_total_s, predicted_total_s)
    totals: dict

    def err_pct(self, hw_name: str) -> float:
        m, p = self.totals[hw_name]
        return abs(p - m) / max(m, 1e-12) * 100.0

    def split_mape(self) -> dict:
        """Mean absolute total-latency error (%) over the seen vs unseen
        hardware split — the generalization headline numbers."""
        out = {"seen": [], "unseen": []}
        for name in self.totals:
            split = _split(name)
            if split != "?":
                out[split].append(self.err_pct(name))
        return {k: float(np.mean(v)) if v else float("nan") for k, v in out.items()}

    def family_mape(self) -> dict:
        """family -> mean |err|% across all swept hardware (kernel-level
        error per family, the Table VIII analogue)."""
        errs: dict = {}
        for fams in self.by_family.values():
            for fam, (m, p) in fams.items():
                errs.setdefault(fam, []).append(abs(p - m) / max(m, 1e-12) * 100.0)
        return {f: float(np.mean(v)) for f, v in errs.items()}

    def table(self) -> str:
        lines = [f"{'hardware':<14} {'split':<7} {'measured':>10} {'predicted':>10} {'err':>7}"]
        for name, (m, p) in sorted(self.totals.items(), key=lambda kv: kv[1][0]):
            lines.append(
                f"{name:<14} {_split(name):<7} {m*1e3:>8.2f}ms {p*1e3:>8.2f}ms "
                f"{self.err_pct(name):>6.1f}%"
            )
        sm = self.split_mape()
        for split in ("seen", "unseen"):
            if not np.isnan(sm[split]):
                lines.append(f"{'mean':<14} {split:<7} {'':>10} {'':>10} {sm[split]:>6.1f}%")
        return "\n".join(lines)


class SweepPredictor:
    """One trace, many devices: a per-hardware family of predictor backends
    sharing one ``FeatureCache`` (task- and feature-level memoization) and
    one grouping pass per trace.

    ``hws`` is an iterable of hardware names or specs (default: the whole
    registry). ``backend`` + ``**backend_kw`` are forwarded to
    ``get_predictor`` per hardware — e.g. ``estimator=pw`` for "synperf"
    (the estimator is hw-independent and shared). A ``predictors`` mapping
    of pre-built backends overrides construction entirely (they should
    share a cache to benefit from the sweep)."""

    def __init__(
        self,
        hws: Optional[Iterable] = None,
        backend: str = "synperf",
        *,
        cache: Optional[FeatureCache] = None,
        predictors: Optional[dict] = None,
        **backend_kw,
    ):
        from repro.predict.backends import get_predictor

        self.cache = cache if cache is not None else FeatureCache()
        if predictors is None:
            self.hws = _resolve_hws(hws)
            predictors = {
                hw.name: get_predictor(backend, hw, cache=self.cache, **backend_kw)
                for hw in self.hws
            }
        else:
            # pre-built backends carry their own spec; fall back to the
            # registry for adapters constructed without one. Keys must be
            # the hardware names — predict()/compare() index by them.
            hws = []
            for name, p in predictors.items():
                spec = p.hw if p.hw is not None else get_hw(name)
                if name != spec.name:
                    raise ValueError(
                        f"predictors key {name!r} != its backend's hardware "
                        f"name {spec.name!r}; key the mapping by hw name"
                    )
                hws.append(spec)
            self.hws = hws
        self.predictors = predictors

    @property
    def hw_names(self) -> list:
        return [hw.name for hw in self.hws]

    def predict(self, calls) -> SweepResult:
        """Group once, estimate per hardware."""
        families, comms = group_calls(calls)
        return SweepResult(
            {
                hw.name: self.predictors[hw.name].predict_grouped(families, comms)
                for hw in self.hws
            }
        )

    def compare(self, calls, *, reference: str = "oracle") -> SweepComparison:
        """Measured (``reference`` backend, default the hwsim oracle) vs
        predicted, per hardware and per kernel family, over one grouping
        pass. This is the paper's seen/unseen evaluation protocol."""
        from repro.predict.backends import get_predictor

        families, comms = group_calls(calls)
        by_family: dict = {}
        totals: dict = {}
        for hw in self.hws:
            ref = get_predictor(reference, hw, cache=self.cache)
            measured = ref.predict_grouped(families, comms)
            predicted = self.predictors[hw.name].predict_grouped(families, comms)
            by_family[hw.name] = {
                fam: (measured.by_family[fam], predicted.by_family[fam])
                for fam in measured.by_family
            }
            totals[hw.name] = (measured.total_s, predicted.total_s)
        return SweepComparison(by_family=by_family, totals=totals)
