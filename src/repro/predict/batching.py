"""Batched-estimation plumbing: canonical workload keys, a memoized
featurize cache, and call grouping.

A fine-grained E2E assembly re-featurizes the same shapes constantly — a
decode sweep issues the *identical* GEMM/rmsnorm/silu workloads at every
cache length (only attention varies with kvlen), and ``model_calls``
repeats one layer ``n_layers`` times. Grouping by (kind, canonical X) and
memoizing ``featurize`` turns thousands of per-call analytical passes
into one pass per unique shape, and lets backends run one vectorized MLP
forward per kernel family instead of per-call batch-1 inference.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dataset import featurize
from repro.core.hardware import TPUSpec
from repro.predict.api import CommCall, KernelCall, flatten_calls


def canonical_x(X: dict) -> tuple:
    """Order-independent hashable key for a workload dict."""
    return tuple(sorted(X.items()))


class FeatureCache:
    """Memoizes ``featurize`` (and the derived feature vector) per
    (kind, canonical workload, hardware). Bounded: on overflow the cache
    resets rather than evicting — repeated sweeps re-warm in one pass."""

    def __init__(self, maxsize: int = 100_000):
        self.maxsize = maxsize
        self._fs: dict = {}
        self._vec: dict = {}
        self.hits = 0
        self.misses = 0

    def featureset(self, kind: str, X: dict, hw: TPUSpec):
        key = (kind, hw.name, canonical_x(X))
        fs = self._fs.get(key)
        if fs is None:
            self.misses += 1
            fs = featurize(kind, X, hw)
            if len(self._fs) >= self.maxsize:
                self._fs.clear()
                self._vec.clear()
            self._fs[key] = fs
        else:
            self.hits += 1
        return fs

    def vector(self, kind: str, X: dict, hw: TPUSpec) -> np.ndarray:
        key = (kind, hw.name, canonical_x(X))
        v = self._vec.get(key)
        if v is None:
            v = self.featureset(kind, X, hw).vector(hw)
            self._vec[key] = v
        else:
            self.hits += 1
        return v


@dataclasses.dataclass
class FamilyGroup:
    """Unique workloads of one kernel family with accumulated weights."""

    kind: str
    workloads: list  # unique dicts, first-seen order
    weights: list  # parallel floats (sum of call counts x group reps)

    @property
    def weight_array(self) -> np.ndarray:
        return np.asarray(self.weights, dtype=np.float64)


def group_calls(calls) -> tuple[dict, dict]:
    """Flatten ``calls`` and group: kernel calls into per-family
    ``FamilyGroup``s deduplicated by canonical workload, comm calls into
    ``{(op, nbytes, n_units): weight}``."""
    families: dict[str, FamilyGroup] = {}
    index: dict[tuple, int] = {}
    comms: dict[tuple, float] = {}
    for call, w in flatten_calls(calls):
        if w == 0:
            continue
        if isinstance(call, KernelCall):
            key = (call.kind, canonical_x(call.X))
            i = index.get(key)
            if i is None:
                grp = families.setdefault(call.kind, FamilyGroup(call.kind, [], []))
                index[key] = len(grp.workloads)
                grp.workloads.append(call.X)
                grp.weights.append(w)
            else:
                families[call.kind].weights[i] += w
        elif isinstance(call, CommCall):
            key = (call.op, call.nbytes, call.n_units)
            comms[key] = comms.get(key, 0.0) + w
        else:
            raise TypeError(f"not a KernelCall/CommCall: {call!r}")
    return families, comms
