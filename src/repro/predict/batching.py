"""Batched-estimation plumbing: canonical workload keys, a memoized
featurize cache, and call grouping.

A fine-grained E2E assembly re-featurizes the same shapes constantly — a
decode sweep issues the *identical* GEMM/rmsnorm/silu workloads at every
cache length (only attention varies with kvlen), and ``model_calls``
repeats one layer ``n_layers`` times. Grouping by (kind, canonical X) and
memoizing ``featurize`` turns thousands of per-call analytical passes
into one pass per unique shape, and lets backends run one vectorized MLP
forward per kernel family instead of per-call batch-1 inference.

Multi-hardware sweeps add further sharing levels: ``featurize`` is
decompose -> schedule -> analyze, and only the *cycle-conversion* half of
``analyze`` (plus the feature vector) reads the full hardware spec.
Decompose reads at most (vmem_mb, num_chips) — the GEMM tile heuristic —
the static scheduler only (n_tasks, num_chips), and the per-pipe demand
summary is hw-independent given the schedule, so each stage is memoized
under exactly the hw fields it reads (:func:`decompose_sig`,
:func:`task_sig`). Across a sweep only pure float math (cycle conversion,
feature vector, MLP forward) fans out per device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.decomposer import SCHED_POLICY, decompose
from repro.core.features import FeatureSet, analyze_summary, demand_summary
from repro.core.hardware import TPUSpec
from repro.core.scheduler import schedule
from repro.predict.api import CallSeq, CommCall, KernelCall, flatten_calls


def canonical_x(X: dict) -> tuple:
    """Order-independent hashable key for a workload dict."""
    return tuple(sorted(X.items()))


def decompose_sig(kind: str, hw: TPUSpec) -> tuple:
    """The subset of ``hw`` that ``decompose`` reads for ``kind`` — only
    the GEMM tile heuristic looks at the spec at all."""
    if kind in ("gemm", "scaled_mm"):
        return (hw.vmem_mb, hw.num_chips)  # gemm_tile_heuristic
    return ()  # attention/rmsnorm/silu_mul/fused_moe ignore hw


def task_sig(kind: str, hw: TPUSpec) -> tuple:
    """The subset of ``hw`` that decompose+schedule actually read for
    ``kind`` — hardware with equal signatures provably produces identical
    (tasks, chip_of), so those stages (and the derived demand summary) are
    shared across a sweep.
    ``tests/test_sweep.py::test_task_sig_matches_direct_featurize`` pins
    this to the decomposer/scheduler implementation for every family and
    every registry entry."""
    if SCHED_POLICY.get(kind) == "workqueue":
        # earliest-finish-first weighs tasks by per-pipe throughput
        sched: tuple = (
            hw.num_chips,
            hw.mxu_flops_per_cycle,
            hw.vpu_ops_per_cycle,
            hw.xu_ops_per_cycle,
            hw.hbm_bytes_per_cycle,
        )
    else:
        sched = (hw.num_chips,)
    return decompose_sig(kind, hw) + sched


class FeatureCache:
    """Memoizes the analytical pipeline per (kind, canonical workload,
    hardware), in levels matching what each stage actually reads:

      * decompose level — ``TaskArray`` keyed by :func:`decompose_sig`
        (for most families: shared across *all* hardware);
      * schedule level — static-policy ``chip_of`` keyed by
        (n_tasks, num_chips), shared across kinds and shapes; workqueue
        schedules are throughput-dependent and keyed by :func:`task_sig`;
      * demand level — the hw-independent half of ``analyze``
        (``demand_summary``) keyed by :func:`task_sig`;
      * feature level — ``FeatureSet`` / feature vector keyed by hw.name
        (the only truly per-device stage: cycle conversion + vector).

    Bounded: on overflow the caches reset rather than evicting — repeated
    sweeps re-warm in one pass."""

    def __init__(self, maxsize: int = 100_000) -> None:
        self.maxsize = maxsize
        self._dec: dict = {}
        self._sched: dict = {}
        self._summ: dict = {}
        self._fs: dict = {}
        self._vec: dict = {}
        self.hits = 0
        self.misses = 0
        #: demand-summary level accounting: ``task_misses`` counts full
        #: decompose+schedule+summary builds, ``task_hits`` cross-hw reuse
        self.task_hits = 0
        self.task_misses = 0

    def _bound(self, d: dict) -> None:
        if len(d) >= self.maxsize:
            d.clear()

    def tasks(self, kind: str, X: dict, hw: TPUSpec) -> tuple:
        """(tasks, chip_of) for one workload, shared across hw with equal
        :func:`decompose_sig` / schedule inputs."""
        cx = canonical_x(X)
        dkey = (kind, decompose_sig(kind, hw), cx)
        t = self._dec.get(dkey)
        if t is None:
            t = decompose(kind, X, hw)
            self._bound(self._dec)
            self._dec[dkey] = t
        if SCHED_POLICY.get(kind) == "workqueue":
            skey = (kind, task_sig(kind, hw), cx)
        else:
            # static partition depends only on the grid size and chip count
            skey = ("static", len(t), hw.num_chips)
        chip_of = self._sched.get(skey)
        if chip_of is None:
            chip_of = schedule(SCHED_POLICY[kind], t, hw)
            self._bound(self._sched)
            self._sched[skey] = chip_of
        return t, chip_of

    def summary(self, kind: str, X: dict, hw: TPUSpec) -> tuple:
        """Hw-independent demand summary, shared across hw with equal
        :func:`task_sig`."""
        key = (kind, task_sig(kind, hw), canonical_x(X))
        summ = self._summ.get(key)
        if summ is None:
            self.task_misses += 1
            tasks, chip_of = self.tasks(kind, X, hw)
            summ = demand_summary(tasks, chip_of, hw.num_chips)
            self._bound(self._summ)
            self._summ[key] = summ
        else:
            self.task_hits += 1
        return summ

    def featureset(self, kind: str, X: dict, hw: TPUSpec) -> "FeatureSet":
        key = (kind, hw.name, canonical_x(X))
        fs = self._fs.get(key)
        if fs is None:
            self.misses += 1
            fs = analyze_summary(self.summary(kind, X, hw), hw)
            self._bound(self._fs)
            if len(self._vec) >= self.maxsize:
                self._vec.clear()
            self._fs[key] = fs
        else:
            self.hits += 1
        return fs

    def vector(self, kind: str, X: dict, hw: TPUSpec) -> np.ndarray:
        key = (kind, hw.name, canonical_x(X))
        v = self._vec.get(key)
        if v is None:
            v = self.featureset(kind, X, hw).vector(hw)
            self._vec[key] = v
        else:
            self.hits += 1
        return v


@dataclasses.dataclass
class FamilyGroup:
    """Unique workloads of one kernel family with accumulated weights."""

    kind: str
    workloads: list  # unique dicts, first-seen order
    weights: list  # parallel floats (sum of call counts x group reps)

    @property
    def weight_array(self) -> np.ndarray:
        return np.asarray(self.weights, dtype=np.float64)


def group_calls(calls: CallSeq) -> tuple[dict, dict]:
    """Flatten ``calls`` and group: kernel calls into per-family
    ``FamilyGroup``s deduplicated by canonical workload, comm calls into
    ``{(op, nbytes, n_units, skew): weight}``."""
    families: dict[str, FamilyGroup] = {}
    index: dict[tuple, int] = {}
    comms: dict[tuple, float] = {}
    for call, w in flatten_calls(calls):
        if w == 0:
            continue
        if isinstance(call, KernelCall):
            key = (call.kind, canonical_x(call.X))
            i = index.get(key)
            if i is None:
                grp = families.setdefault(call.kind, FamilyGroup(call.kind, [], []))
                index[key] = len(grp.workloads)
                grp.workloads.append(call.X)
                grp.weights.append(w)
            else:
                families[call.kind].weights[i] += w
        elif isinstance(call, CommCall):
            key = (call.op, call.nbytes, call.n_units, call.skew)
            comms[key] = comms.get(key, 0.0) + w
        else:
            raise TypeError(f"not a KernelCall/CommCall: {call!r}")
    return families, comms
