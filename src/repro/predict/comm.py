"""Communication-latency regressor — the comm half of every predictor
backend (paper §V-D: RF on a profiled-collective database; here a
relative-error-weighted alpha-beta regression per (op, participants)
bucket fitted on profiled ``hwsim.simulate_comm`` samples).

Moved here from ``repro.core.e2e`` so backends can depend on it without
pulling in the workload generator; ``repro.core.e2e`` re-exports it for
backward compatibility.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import hwsim
from repro.core.hardware import TPUSpec


class CommRegressor:
    """Per (op, participant-count) bucket, fit latency = alpha + beta*bytes
    on profiled samples — the standard alpha-beta structure.

    ``OPS`` is the fitted collective vocabulary; it includes the
    expert-parallel ``all_to_all`` (MoE dispatch/combine, ISSUE 5).
    Regressors fitted before that op existed raise an actionable
    RuntimeError naming their fitted ops when asked for it — the error
    ``FleetRouter`` surfaces as a per-hardware skip warning."""

    #: collectives ``fit`` profiles (must cover every op the workload
    #: generator emits — see ``core.e2e.layer_calls``/``request_calls``)
    OPS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all", "p2p")

    def __init__(self) -> None:
        self.theta: dict = {}

    _NS = (2, 4, 8, 16)

    def fitted_ops(self) -> list:
        """Sorted op names this regressor has coefficients for."""
        return sorted({op for op, _ in self.theta})

    def fit(self, hw: TPUSpec, seed: int = 0) -> "CommRegressor":
        rng = np.random.default_rng(seed)
        for op in self.OPS:
            for n in self._NS:
                rows, ys = [], []
                for _ in range(60):
                    nbytes = float(np.exp(rng.uniform(np.log(1e3), np.log(1e9))))
                    t = hwsim.simulate_comm(op, nbytes, n, hw)
                    rows.append([1.0, nbytes])
                    ys.append(t)
                A = np.asarray(rows)
                y = np.asarray(ys)
                # weight by 1/t: minimize *relative* error so the alpha
                # (latency) regime isn't drowned out by GB-sized samples
                Aw = A / y[:, None]
                self.theta[(op, n)], *_ = np.linalg.lstsq(Aw, np.ones_like(y), rcond=None)
        return self

    def predict(self, op: str, nbytes: float, n: int) -> float:
        if not self.theta:
            raise RuntimeError(
                "CommRegressor has no fitted coefficients (fitted ops: "
                "none) — call fit(hw) first"
            )
        if n <= 1 or nbytes <= 0:
            return 0.0
        nb = min(self._NS, key=lambda x: abs(math.log(x) - math.log(max(n, 2))))
        if (op, nb) not in self.theta:
            raise RuntimeError(
                f"CommRegressor has no coefficients for comm op {op!r} "
                f"(fitted ops: {self.fitted_ops()}) — call fit(hw) to "
                f"refit; regressors fitted before an op joined "
                f"CommRegressor.OPS (e.g. the EP 'all_to_all') must be "
                f"refitted to price it"
            )
        a, b = self.theta[(op, nb)]
        return float(max(a + b * nbytes, 1e-7))
