"""Architecture & shape configuration registry.

Every assigned architecture is expressed as an :class:`ArchConfig`. The full
configs are exercised only through the dry-run (``ShapeDtypeStruct`` lowering,
no allocation); ``smoke()`` derives a reduced same-family config for CPU
tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A full model architecture description.

    The zoo covers six families: dense decoder LMs, MoE LMs, pure SSM
    (Mamba-2/SSD), hybrid attention+SSM (Hymba), encoder-decoder audio
    (Whisper backbone; conv frontend stubbed) and VLM (Llama-3.2-Vision text
    backbone with gated cross-attention; ViT stubbed).
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention flavour ---
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # stablelm: 0.25 partial rotary
    qk_norm: bool = False  # qwen3
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    window: Optional[int] = None  # sliding-window size for local layers
    layer_pattern: str = "global"  # global | alt_local_global | hymba
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | geglu | gelu
    post_norms: bool = False  # gemma2 sandwich norms
    embed_scale: bool = False  # gemma2 scales embeddings by sqrt(d_model)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0  # per-expert hidden (defaults to d_ff)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    moe_group: int = 512  # dispatch group size (tokens)

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 1500

    # --- vlm ---
    n_img_tokens: int = 0
    cross_every: int = 0  # one cross-attn layer after every N self layers

    # --- hymba ---
    meta_tokens: int = 0

    # --- numerics / runtime ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    use_pallas: bool = False  # dispatch hot ops to Pallas kernels (TPU path)
    remat: str = "layer"  # none | layer | dots
    q_block: int = 512  # chunked-attention query block

    # --- perf knobs (EXPERIMENTS.md §Perf; False reproduces the paper-
    # faithful baseline numbers) ---
    flash_remat: bool = True  # recompute per-q-block attention in backward
    # constrain q/k/v sharding inside attention: True | False | "train"
    attn_shard_hint: object = True
    # block-sparse triangular causal schedule: only lower-triangle
    # (q-block, kv-block) pairs are computed — halves causal attention
    # FLOPs and score traffic (§Perf beyond-paper). Values: True | False |
    # "prefill". Default "prefill": in training the scan's saved per-pair
    # probabilities cost more memory than the flash-remat dense path
    # (measured It-9); extending to training needs a custom-vjp backward.
    causal_sparse: object = "prefill"
    moe_bf16_combine: bool = True  # bf16 dispatch/combine einsums

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.n_heads == 0:  # attention-free (pure SSM)
            return self.head_dim
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so the 'model' axis always divides it."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def moe_hidden(self) -> int:
        return self.moe_dff or self.d_ff

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """long_500k requires sub-quadratic attention (SSM / hybrid /
        sliding-window); skipped for pure full-attention archs (DESIGN.md
        §Arch-applicability)."""
        if shape.name == "long_500k":
            return self.family in ("ssm", "hybrid") or self.layer_pattern == "alt_local_global"
        return True

    def n_params(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, dff, V = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        n_ff_mats = 3 if self.act in ("silu", "geglu") else 2
        ffn = n_ff_mats * d * dff
        per_layer = 0
        if self.family in ("dense", "audio", "vlm"):
            per_layer = attn + ffn
        elif self.family == "moe":
            moe = self.n_experts * n_ff_mats * d * self.moe_hidden + d * self.n_experts
            per_layer = attn + moe + (ffn if self.dense_residual else 0)
        elif self.family == "ssm":
            di, G, N, H = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * G * N + H) + di * d
        elif self.family == "hybrid":
            di, G, N = self.d_inner, self.ssm_groups, self.ssm_state
            ssm = d * (2 * di + 2 * G * N + self.ssm_heads) + di * d
            per_layer = attn + ffn + ssm
        total = self.n_layers * per_layer + 2 * V * d
        if self.family == "audio":
            total += self.n_enc_layers * (attn + ffn)
            total += self.enc_frames * d  # learned encoder positions
            total += 32768 * d  # learned decoder positions (MAX_DEC_POS)
        if self.family == "vlm" and self.cross_every:
            n_cross = self.n_layers // self.cross_every
            total += n_cross * (attn + ffn)
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        n_ff_mats = 3
        dead = (self.n_experts - self.top_k) * n_ff_mats * self.d_model * self.moe_hidden
        return self.n_params() - self.n_layers * dead

    # ------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """A reduced same-family config that runs a CPU forward/train step."""
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            moe_dff=96 if self.n_experts else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16,
            window=16 if self.window else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=24 if self.n_enc_layers else 1500,
            n_img_tokens=8 if self.n_img_tokens else 0,
            cross_every=2 if self.cross_every else 0,
            meta_tokens=8 if self.meta_tokens else 0,
            ssd_chunk=16,
            q_block=16,
            moe_group=32,
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    import os

    if os.environ.get("REPRO_PERF_BASELINE"):
        # paper-faithful baseline: every §Perf optimization disabled
        # (EXPERIMENTS.md compares this against the tuned defaults)
        cfg = dataclasses.replace(
            cfg,
            flash_remat=False,
            attn_shard_hint=False,
            moe_bf16_combine=False,
            causal_sparse=False,
        )
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import repro.configs.all  # noqa: F401  (populate registry)
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs.all  # noqa: F401

    return sorted(_REGISTRY)


def all_cells() -> list[Tuple[str, str]]:
    """All runnable (arch, shape) dry-run cells, honouring documented skips."""
    cells = []
    for a in list_archs():
        cfg = get_arch(a)
        for s in SHAPES.values():
            if cfg.supports_shape(s):
                cells.append((a, s.name))
    return cells
