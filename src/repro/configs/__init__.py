from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeConfig,
    all_cells,
    get_arch,
    list_archs,
    register,
)
