"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=1024, d_ff=0 (no FFN; Mamba-2 blocks only), vocab=50280,
ssm_state=128.
"""
from repro.configs.base import ArchConfig, register

MAMBA2_370M = register(
    ArchConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_groups=1,
        conv_width=4,
        ssd_chunk=256,
    )
)
