"""arctic-480b — 128-expert top-2 MoE with parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
"""
from repro.configs.base import ArchConfig, register

ARCTIC_480B = register(
    ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        n_experts=128,
        top_k=2,
        moe_dff=4864,
        dense_residual=True,
        act="silu",
        # 56 q-heads / 8 kv-heads don't divide the 16-way model axis, so the
        # prefill shard hint degenerates to batch-only pinning and regressed
        # (+11% memory, measured); training keeps it (bf16-combine + hint
        # cut the collective term 57%). The triangular schedule also
        # measured net-negative here (attention is a small share next to the
        # MoE dispatch; the pair-scan carry costs more than it saves).
        attn_shard_hint="train",
        causal_sparse=False,
    )
)
