"""gemma2-2b — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
window=4096 on local (even) layers, attn softcap 50, final softcap 30,
GeGLU, sandwich norms, scaled embeddings.
"""
from repro.configs.base import ArchConfig, register

GEMMA2_2B = register(
    ArchConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        rope_theta=10_000.0,
        attn_softcap=50.0,
        final_softcap=30.0,
        window=4096,
        layer_pattern="alt_local_global",
        act="geglu",
        post_norms=True,
        embed_scale=True,
        # §Perf iterations 2b/2c/7: q/k/v and k/v-only shard pinning REGRESSED
        # for prefill (8 q-heads don't divide the 16-way model axis); the
        # train-only variant measured +4.2% collective but -3.2% on the
        # overall bound -> keep GSPMD default propagation entirely. The
        # triangular schedule also measured net-negative at this small width.
        attn_shard_hint=False,
        causal_sparse=False,
        # flash-remat recompute also measured net-negative at this scale
        flash_remat=False,
    )
)
