"""llama-3.2-vision-11b — text backbone with gated cross-attention image
layers [hf:meta-llama/Llama-3.2-11B-Vision].

40L total: 32 self-attn + 8 gated cross-attn layers (one after every 4 self
layers). d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. The ViT
frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, n_img_tokens, d_model).
"""
from repro.configs.base import ArchConfig, register

LLAMA32_VISION_11B = register(
    ArchConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=32,  # self-attn layers; +8 cross layers via cross_every
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        act="silu",
        n_img_tokens=1601,
        cross_every=4,
    )
)
