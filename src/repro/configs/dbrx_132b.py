"""dbrx-132b — fine-grained 16-expert top-4 MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.configs.base import ArchConfig, register

DBRX_132B = register(
    ArchConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        n_experts=16,
        top_k=4,
        moe_dff=10752,
        dense_residual=False,
        act="silu",
    )
)
