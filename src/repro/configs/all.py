"""Import every architecture config so the registry is populated."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    dbrx_132b,
    deepseek_67b,
    gemma2_2b,
    hymba_1_5b,
    llama32_vision_11b,
    mamba2_370m,
    qwen3_0_6b,
    stablelm_3b,
    whisper_base,
)
