"""stablelm-3b — dense decoder, MHA (kv=heads), partial rotary, LayerNorm
[hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ArchConfig, register

STABLELM_3B = register(
    ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        rope_theta=10_000.0,
        rope_pct=0.25,
        norm="layernorm",
        act="silu",
    )
)
