"""hymba-1.5b — parallel attention + Mamba heads in every layer
[arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16,
128 learned meta tokens, sliding window 1024 everywhere except 3 global
layers (first / middle / last).
"""
from repro.configs.base import ArchConfig, register

HYMBA_1_5B = register(
    ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_headdim=64,
        ssm_expand=1,  # SSM branch operates at d_model width
        window=1024,
        layer_pattern="hymba",
        meta_tokens=128,
        act="silu",
        # 128 meta tokens shift sequence lengths to S+128; pick blocking that
        # divides 4096+128, 32768+128 and 524288+128 (= 2^7 * odd).
        # §Perf It-8 tried ssd_chunk=64 (hypothesis: intra-chunk segsum
        # tensors dominate memory) — measured +-0.1% on every term ->
        # REFUTED; SSD tensors are not the prefill memory driver. Kept 128.
        ssd_chunk=128,
        q_block=128,
    )
)
