"""qwen3-0.6b — dense decoder with qk-norm and GQA [hf:Qwen/Qwen3-8B family].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, head_dim=128.
"""
from repro.configs.base import ArchConfig, register

QWEN3_0_6B = register(
    ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        rope_theta=1_000_000.0,
        qk_norm=True,
        act="silu",
    )
)
