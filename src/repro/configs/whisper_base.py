"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

6L (decoder) + 6L (encoder) d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
Conv frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, enc_frames, d_model). LayerNorm + GELU, learned positions (encoded as
absolute-positional; no RoPE).
"""
from repro.configs.base import ArchConfig, register

WHISPER_BASE = register(
    ArchConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,
        n_enc_layers=6,
        enc_frames=1500,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        norm="layernorm",
        act="gelu",
        rope_pct=0.0,  # learned absolute positions instead of RoPE
        # tiny model: the triangular pair-scan's carry overhead exceeds the
        # causal savings (measured +70% on a 0.4s memory term) — keep dense.
        # 8 heads don't divide the 16-way model axis either, so shard
        # pinning degenerates to batch-only replication (collectives x12,
        # measured) — keep default propagation.
        causal_sparse=False,
        attn_shard_hint=False,
        flash_remat=False,  # measured net-negative at 6L/512d scale
    )
)
