"""Training substrate: pjit train step and the fault-tolerant Trainer loop."""
