"""Training loop with production concerns:

  * checkpoint/restart: periodic atomic checkpoints, auto-resume from the
    latest one (preemption-safe — see tests/test_fault_tolerance.py for the
    kill/restart bitwise-continuation check);
  * data-iterator state is implicit (deterministic batch_at(step)), so resume
    needs only the step number;
  * straggler watchdog: logs steps slower than ``watchdog_factor`` x the
    running median (on real multi-host deployments this hooks the
    per-host heartbeat instead);
  * elastic restart: checkpoints hold full arrays; ``Trainer.restore`` puts
    them onto whatever mesh/shardings the new incarnation uses.
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import statistics
import time
from typing import Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.train.step import TrainConfig, init_train_state, make_optimizer, make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = False
    watchdog_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        data_cfg: DataConfig,
        tc: TrainConfig,
        trainer_cfg: TrainerConfig,
        mesh=None,
        state_shardings=None,
        batch_shardings=None,
    ):
        self.cfg = cfg
        self.api = build_model(cfg)
        self.tc = tc
        self.tcfg = trainer_cfg
        self.data = SyntheticLM(cfg, data_cfg)
        self.optimizer = make_optimizer(tc)
        self.ckpt = CheckpointManager(
            trainer_cfg.ckpt_dir, keep=trainer_cfg.keep, async_save=trainer_cfg.async_save
        )
        step_fn = make_train_step(self.api, self.optimizer, tc)
        if mesh is not None:
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(state_shardings, batch_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            )
        else:
            self.train_step = jax.jit(step_fn, donate_argnums=(0,))
        self.state_shardings = state_shardings
        self._preempted = False
        self.step_times: list[float] = []
        self.metrics_history: list[dict] = []

    # ------------------------------------------------------------------
    def init_or_restore(self, seed: int = 0):
        state = init_train_state(
            self.api, self.optimizer, jax.random.PRNGKey(seed),
            compress_grads=self.tc.compress_grads,
        )
        restored = self.ckpt.restore_latest(state, self.state_shardings)
        if restored is not None:
            step, state, extra = restored
            log.info("resumed from checkpoint step %d", step)
            return int(step), state
        return 0, state

    def request_preemption(self, *_args):
        self._preempted = True

    # ------------------------------------------------------------------
    def run(self, seed: int = 0, preempt_after: Optional[int] = None):
        """Returns (final_step, state, losses). ``preempt_after`` simulates a
        preemption notice after N steps (tests/fault-tolerance drills)."""
        start, state = self.init_or_restore(seed)
        signal.signal(signal.SIGUSR1, self.request_preemption)
        losses = []
        for step in range(start, self.tcfg.total_steps):
            batch = jax.tree.map(jax.numpy.asarray, self.data.batch_at(step))
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            losses.append(loss)
            self.metrics_history.append({k: float(v) for k, v in metrics.items()})
            if (step + 1) % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step + 1, loss, dt)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.total_steps:
                self.ckpt.save(step + 1, state, extra={"loss": loss})
            if preempt_after is not None and step + 1 - start >= preempt_after:
                self._preempted = True
            if self._preempted:
                self.ckpt.save(step + 1, state, extra={"loss": loss, "preempted": True})
                self.ckpt.wait()
                log.warning("preempted at step %d; checkpoint saved", step + 1)
                return step + 1, state, losses
        self.ckpt.wait()
        return self.tcfg.total_steps, state, losses

    # ------------------------------------------------------------------
    def _watchdog(self, step: int, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = statistics.median(self.step_times[-50:])
            if dt > self.tcfg.watchdog_factor * med:
                log.warning(
                    "straggler: step %d took %.2fs (median %.2fs) — "
                    "on a real cluster this triggers host health checks",
                    step,
                    dt,
                    med,
                )
