"""The pjit-able training step: loss -> grads -> (optional compression) ->
optimizer update. Gradient accumulation runs as a scan over microbatches so
per-layer FSDP all-gathers can overlap the next microbatch's compute (XLA
latency hiding)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.collectives import (
    DEFAULT_BUCKET_BYTES,
    ef_compress_grads,
    ef_compress_grads_bucketed,
)
from repro.models.registry import ModelApi
from repro.optim.adamw import AdamW, AdamWState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1  # gradient accumulation
    compress_grads: bool = False  # int8 error-feedback compression
    # overlapped transport: bucket the EF all-reduces in reverse leaf
    # order (backward availability) so each bucket launches as soon as
    # its grads exist — numerically bit-identical to the synchronous
    # path (tests/test_dist.py); only the launch schedule changes
    overlap_grads: bool = False
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def make_optimizer(tc: TrainConfig) -> AdamW:
    from repro.optim.adamw import warmup_cosine

    return AdamW(
        lr=warmup_cosine(tc.lr, tc.warmup, tc.total_steps),
        weight_decay=tc.weight_decay,
        clip_norm=tc.clip_norm,
    )


def init_train_state(
    api: ModelApi, optimizer: AdamW, key, compress_grads: bool = False
) -> dict:
    params = api.init(key)
    # the error-feedback buffer is allocated eagerly when compressing so the
    # state pytree structure is stable across steps — a lazily-appearing err
    # subtree changes the donated-buffer aliasing of the jitted step
    err = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if compress_grads
        else None
    )
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
        "err": err,
    }


def train_state_pspecs(state_shapes: dict, mesh) -> dict:
    """PartitionSpecs for a full train-state tree (params, optimizer moments,
    error-feedback buffer). The single source of truth for launchers and the
    dry-run — the err subtree mirrors the params whenever it exists."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import param_pspecs

    return {
        "params": param_pspecs(state_shapes["params"], mesh),
        "opt": AdamWState(
            step=P(),
            mu=param_pspecs(state_shapes["opt"].mu, mesh),
            nu=param_pspecs(state_shapes["opt"].nu, mesh),
        ),
        "step": P(),
        "err": (
            param_pspecs(state_shapes["err"], mesh)
            if state_shapes["err"] is not None
            else None
        ),
    }


def make_train_step(api: ModelApi, optimizer: AdamW, tc: TrainConfig):
    cfg = api.cfg

    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            B = x.shape[0]
            assert B % tc.microbatches == 0
            return x.reshape(tc.microbatches, B // tc.microbatches, *x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            return (acc_g, acc_l + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / tc.microbatches, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        err = state.get("err")
        if tc.compress_grads:
            if tc.overlap_grads:
                grads, err, _ = ef_compress_grads_bucketed(
                    grads, err, bucket_bytes=tc.bucket_bytes
                )
            else:
                grads, err = ef_compress_grads(grads, err)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state["opt"], state["params"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "err": err,
        }
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
