"""Distribution substrate: sharding rules, compression collectives, pipeline.

Split by concern:
  * :mod:`repro.dist.sharding` — role-based PartitionSpec resolution and the
    ambient-mesh ``constrain`` used throughout the model code;
  * :mod:`repro.dist.collectives` — int8 error-feedback gradient compression;
  * :mod:`repro.dist.pipeline` — GPipe pipeline parallelism via shard_map.
"""
from repro.dist.collectives import ef_compress_grads
from repro.dist.pipeline import pipeline_bubble_fraction, pipeline_forward
from repro.dist.sharding import (
    active_mesh,
    batch_pspecs,
    cache_pspecs,
    constrain,
    param_pspecs,
    resolve_pspec,
    to_named,
    use_mesh,
)

__all__ = [
    "active_mesh",
    "batch_pspecs",
    "cache_pspecs",
    "constrain",
    "ef_compress_grads",
    "param_pspecs",
    "pipeline_bubble_fraction",
    "pipeline_forward",
    "resolve_pspec",
    "to_named",
    "use_mesh",
]
