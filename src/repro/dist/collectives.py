"""Communication-compression collectives.

int8 error-feedback (EF) gradient compression: each step quantizes
``grad + carried_error`` to int8 with a per-leaf absmax scale, and carries
the quantization residual into the next step. The residual feedback makes
the scheme unbiased in the limit — the accumulated compressed updates
converge to the true gradient sum (1-bit Adam / EF-SGD lineage), which is
what licenses shipping 4x fewer bytes through data-parallel all-reduces.

On a real multi-host deployment the int8 payload (``q``, ``scale``) is what
crosses the network; here compress -> dequantize runs inside the jitted step
so the numerics (and the bytes accounted by the dry-run HLO pass) are
faithful while the transport stays XLA's own all-reduce.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_compress_grads", "int8_quantize", "int8_dequantize"]

_LEVELS = 127.0  # symmetric int8: q in [-127, 127]


def int8_quantize(x) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric absmax quantization. Returns (q_int8, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / _LEVELS
    q = jnp.clip(jnp.round(x / jnp.where(scale > 0, scale, 1.0)), -_LEVELS, _LEVELS)
    return q.astype(jnp.int8), scale


def int8_dequantize(q, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, err: Optional[Any]) -> Tuple[Any, Any]:
    """Error-feedback int8 compression over a gradient pytree.

    ``err`` is the carried residual tree (None on the first step — allocated
    as zeros here, which is why the train state stores ``err: None`` until
    compression actually runs). Returns ``(dequantized_grads, new_err)``
    with both trees matching the structure of ``grads``.

    Error-feedback invariants (what makes the scheme sound, and what the
    unit tests pin):

    * **per-leaf conservation** — for every leaf, exactly
      ``dequantized + new_err == grads + err`` in float32: quantization
      error is never dropped, only deferred to the next step's input;
    * **telescoping** — summed over steps the carried residuals cancel,
      so the accumulated compressed updates equal the true gradient sum
      up to the single final residual (bounded by one quantization step:
      ``absmax / 127``). This is the EF-SGD/1-bit-Adam argument that
      licenses shipping 4x fewer bytes through the all-reduce;
    * **residual boundedness** — ``|new_err| <= scale/2`` elementwise for
      a non-degenerate scale, so the carried state cannot grow without
      bound while gradients stay bounded;
    * **structure stability** — ``new_err`` always has the structure and
      dtypes of ``grads`` (float32 leaves), regardless of whether ``err``
      was None, so donated-buffer aliasing under ``jit`` sees a fixed
      state layout after the first step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if err is None:
        err_leaves = [jnp.zeros(g.shape, jnp.float32) for g in leaves]
    else:
        err_leaves = treedef.flatten_up_to(err)

    deq_leaves, new_err_leaves = [], []
    for g, e in zip(leaves, err_leaves):
        target = g.astype(jnp.float32) + e
        q, scale = int8_quantize(target)
        deq = int8_dequantize(q, scale)
        deq_leaves.append(deq)
        new_err_leaves.append(target - deq)
    return (
        jax.tree_util.tree_unflatten(treedef, deq_leaves),
        jax.tree_util.tree_unflatten(treedef, new_err_leaves),
    )
