"""Communication-compression collectives.

int8 error-feedback (EF) gradient compression: each step quantizes
``grad + carried_error`` to int8 with a per-leaf absmax scale, and carries
the quantization residual into the next step. The residual feedback makes
the scheme unbiased in the limit — the accumulated compressed updates
converge to the true gradient sum (1-bit Adam / EF-SGD lineage), which is
what licenses shipping 4x fewer bytes through data-parallel all-reduces.

On a real multi-host deployment the int8 payload (``q``, ``scale``) is what
crosses the network; here compress -> dequantize runs inside the jitted step
so the numerics (and the bytes accounted by the dry-run HLO pass) are
faithful while the transport stays XLA's own all-reduce.

:func:`ef_compress_grads_bucketed` is the overlap-ready variant (ISSUE
10): leaves are partitioned into launch buckets in reverse tree order —
the order backward produces gradients — so each bucket's reduce can
launch as soon as its grads exist and hide under the remaining backward
compute. Compression is per-leaf and reduction elementwise, so bucketing
is bit-identical to the synchronous path by construction; the returned
:class:`GradBucket` ledger is what the predict layer's overlap model
prices (``Estimate.overlapped``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ef_compress_grads",
    "ef_compress_grads_bucketed",
    "bucket_leaves",
    "GradBucket",
    "int8_quantize",
    "int8_dequantize",
]

_LEVELS = 127.0  # symmetric int8: q in [-127, 127]

#: default bucket payload cap for the overlapped path (int8 wire bytes)
DEFAULT_BUCKET_BYTES = 4 << 20


def int8_quantize(x) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric absmax quantization. Returns (q_int8, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / _LEVELS
    q = jnp.clip(jnp.round(x / jnp.where(scale > 0, scale, 1.0)), -_LEVELS, _LEVELS)
    return q.astype(jnp.int8), scale


def int8_dequantize(q, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, err: Optional[Any]) -> Tuple[Any, Any]:
    """Error-feedback int8 compression over a gradient pytree.

    ``err`` is the carried residual tree (None on the first step — allocated
    as zeros here, which is why the train state stores ``err: None`` until
    compression actually runs). Returns ``(dequantized_grads, new_err)``
    with both trees matching the structure of ``grads``.

    Error-feedback invariants (what makes the scheme sound, and what the
    unit tests pin):

    * **per-leaf conservation** — for every leaf, exactly
      ``dequantized + new_err == grads + err`` in float32: quantization
      error is never dropped, only deferred to the next step's input;
    * **telescoping** — summed over steps the carried residuals cancel,
      so the accumulated compressed updates equal the true gradient sum
      up to the single final residual (bounded by one quantization step:
      ``absmax / 127``). This is the EF-SGD/1-bit-Adam argument that
      licenses shipping 4x fewer bytes through the all-reduce;
    * **residual boundedness** — ``|new_err| <= scale/2`` elementwise for
      a non-degenerate scale, so the carried state cannot grow without
      bound while gradients stay bounded;
    * **structure stability** — ``new_err`` always has the structure and
      dtypes of ``grads`` (float32 leaves), regardless of whether ``err``
      was None, so donated-buffer aliasing under ``jit`` sees a fixed
      state layout after the first step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if err is None:
        err_leaves = [jnp.zeros(g.shape, jnp.float32) for g in leaves]
    else:
        err_leaves = treedef.flatten_up_to(err)

    deq_leaves, new_err_leaves = [], []
    for g, e in zip(leaves, err_leaves):
        target = g.astype(jnp.float32) + e
        q, scale = int8_quantize(target)
        deq = int8_dequantize(q, scale)
        deq_leaves.append(deq)
        new_err_leaves.append(target - deq)
    return (
        jax.tree_util.tree_unflatten(treedef, deq_leaves),
        jax.tree_util.tree_unflatten(treedef, new_err_leaves),
    )


# ----------------------------------------------------------------------
# bucketed, overlapped error-feedback all-reduces (ISSUE 10)
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradBucket:
    """One overlapped all-reduce launch in the bucket ledger: which leaf
    indices it carries (into the flattened grad tree, *reverse* leaf
    order — the order backward produces gradients), and its int8 wire
    payload (1 byte per element plus one f32 scale per leaf)."""

    leaf_indices: Tuple[int, ...]
    nbytes: int


def bucket_leaves(leaves: List[Any], bucket_bytes: int) -> List[GradBucket]:
    """Partition flattened grad leaves into launch buckets of at most
    ``bucket_bytes`` int8 wire payload each (a leaf larger than the cap
    gets its own bucket).

    Leaves are walked in **reverse** tree order — the last layers'
    gradients exist first during backward, so the reversed order is the
    order each bucket's reduce can actually launch while earlier layers
    are still computing. The returned ledger is what the overlap model in
    ``core.e2e``/``repro.predict`` prices: one ``all_reduce`` CommCall
    per bucket, launched as soon as the bucket fills, hideable under the
    remaining backward compute.
    """
    if bucket_bytes < 1:
        raise ValueError(f"bucket_bytes must be >= 1, got {bucket_bytes}")
    buckets: List[GradBucket] = []
    cur: List[int] = []
    cur_bytes = 0
    for i in reversed(range(len(leaves))):
        nbytes = int(leaves[i].size) + 4  # int8 payload + f32 scale
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(GradBucket(tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nbytes
    if cur:
        buckets.append(GradBucket(tuple(cur), cur_bytes))
    return buckets


def ef_compress_grads_bucketed(
    grads: Any,
    err: Optional[Any],
    *,
    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
    all_reduce: Optional[Callable] = None,
) -> Tuple[Any, Any, List[GradBucket]]:
    """Bucketed, overlap-ready variant of :func:`ef_compress_grads`.

    Compression is per-leaf (absmax scale per tensor) and the reduction
    is elementwise, so partitioning the leaves into launch buckets
    changes *which collective carries which leaf* but not a single
    arithmetic operation — the result is **bit-identical** to the
    synchronous path, per construction (pinned by ``tests/test_dist.py``
    on the 8-forced-host-device CI leg). Every EF invariant of
    :func:`ef_compress_grads` (conservation, telescoping, residual
    bound, structure stability) therefore holds bucket by bucket.

    ``all_reduce`` optionally applies the transport per bucket (e.g.
    ``lambda ls: [lax.pmean(x, "data") for x in ls]`` inside a
    ``shard_map``) — launched bucket-by-bucket in reverse leaf order, the
    order backward makes gradients available, so XLA can hide each
    bucket's reduce under the remaining backward compute. ``None`` keeps
    the transport outside (the synchronous-train-step default, where
    XLA's own all-reduce stays the wire).

    Returns ``(dequantized_grads, new_err, ledger)`` — the ledger is the
    per-bucket launch schedule the predict layer turns into overlapped
    ``CommCall``s.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if err is None:
        err_leaves = [jnp.zeros(g.shape, jnp.float32) for g in leaves]
    else:
        err_leaves = treedef.flatten_up_to(err)

    ledger = bucket_leaves(leaves, bucket_bytes)
    deq_leaves: List[Any] = [None] * len(leaves)
    new_err_leaves: List[Any] = [None] * len(leaves)
    for bucket in ledger:
        bucket_deq = []
        for i in bucket.leaf_indices:
            target = leaves[i].astype(jnp.float32) + err_leaves[i]
            q, scale = int8_quantize(target)
            deq = int8_dequantize(q, scale)
            bucket_deq.append(deq)
            new_err_leaves[i] = target - deq
        if all_reduce is not None:
            bucket_deq = all_reduce(bucket_deq)
        for i, deq in zip(bucket.leaf_indices, bucket_deq):
            deq_leaves[i] = deq
    return (
        jax.tree_util.tree_unflatten(treedef, deq_leaves),
        jax.tree_util.tree_unflatten(treedef, new_err_leaves),
        ledger,
    )
