"""Sharding rule engine: axis *roles* -> mesh axes -> PartitionSpecs.

Model code never names mesh axes directly. It annotates tensors with logical
roles (``"batch"``, ``"tp"``, ``"fsdp"``, ``"experts"``) and this module maps
roles onto whatever mesh is active, with a greedy divisibility fallback:

  * a role whose candidate mesh axes are absent from the mesh replicates;
  * a dim that a candidate axis does not divide evenly replicates (odd head
    counts like hymba's 25 on a 16-way model axis, batch=1, etc.);
  * ``"batch"`` may span several axes jointly — on the multi-pod production
    mesh it greedily takes the longest prefix of ``("pod", "data")`` whose
    product still divides the batch dim;
  * a mesh axis is consumed at most once per spec (an expert-parallel dim
    claiming ``"model"`` blocks a later ``"tp"`` dim from reusing it).

The same engine resolves parameter trees (:func:`param_pspecs`), input
batches (:func:`batch_pspecs`) and KV/SSM cache trees (:func:`cache_pspecs`),
so the training step, the serving engine and the dry-run lowering all agree
on one source of truth for the distribution strategy.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "resolve_pspec",
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "to_named",
    "use_mesh",
    "active_mesh",
    "constrain",
    "mesh_degrees",
]


# ----------------------------------------------------------------------
# role -> mesh-axis candidates
# ----------------------------------------------------------------------

# Order matters for multi-axis roles: "batch" takes the longest divisible
# prefix, so pods are the outermost data-parallel dimension.
_ROLE_AXES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "experts": ("model",),
    "pipe": ("pipe",),
}


def _mesh_sizes(mesh) -> dict[str, int]:
    # Mesh and AbstractMesh both expose .shape as an axis-name -> size mapping.
    return dict(mesh.shape)


def mesh_degrees(mesh) -> tuple[int, int]:
    """``(tp, pp)`` of a mesh under the role table: the sizes of the axes
    the ``"tp"``/``"experts"`` and ``"pipe"`` roles resolve onto
    (``"model"`` and ``"pipe"``). ``(1, 1)`` for ``mesh=None`` — the
    degrees a single-process run executes at. This is the single source of
    truth the serving engines use to report the mesh they actually live on
    (trace recording, predicted admission)."""
    if mesh is None:
        return (1, 1)
    sizes = _mesh_sizes(mesh)
    return int(sizes.get("model", 1)), int(sizes.get("pipe", 1))


def resolve_pspec(shape: Sequence[int], axis_roles: Sequence[Optional[str]], mesh) -> P:
    """Resolve one tensor's axis roles into a PartitionSpec on ``mesh``.

    ``axis_roles`` has one entry per dim: a role name or None (replicate).
    Role semantics (the ``_ROLE_AXES`` table):

    * ``"batch"`` — data-parallel dim; may span several mesh axes jointly,
      greedily taking the longest prefix of ``("pod", "data")`` whose
      product divides the dim (pods are the outermost data dimension);
    * ``"fsdp"`` — parameter-shard dim of fully-sharded data parallelism;
      maps to ``"data"`` only (never pods: FSDP gathers stay intra-pod);
    * ``"tp"`` — tensor-parallel (Megatron row/column) dim on ``"model"``;
    * ``"experts"`` — expert-parallel dim, also on ``"model"``: EP and TP
      share the axis, and the at-most-once consumption rule below is what
      forces an expert-sharded weight's hidden dims to replicate. The
      dispatch/combine all-to-alls this sharding implies are modeled
      byte-exactly by ``core.decomposer.ep_alltoall_bytes``;
    * ``"pipe"`` — pipeline-stage dim on the ``"pipe"`` axis (present on
      the pipeline production mesh, ``launch.mesh``); the stacked layer
      dim ``dist.pipeline.pipeline_forward`` shards its chunks over.

    Guarantees: the returned spec is always valid to shard ``shape`` with —
    a role whose axes are absent replicates, a dim a candidate axis does
    not divide evenly replicates (greedy prefix: the first non-dividing
    axis stops a multi-axis role), and a mesh axis is consumed at most
    once per spec (first dim wins; later dims claiming the same axis
    replicate).
    """
    if len(shape) != len(axis_roles):
        raise ValueError(f"shape {tuple(shape)} vs roles {tuple(axis_roles)}")
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries: list[Any] = []
    for dim, role in zip(shape, axis_roles):
        if role is None or role not in _ROLE_AXES:
            entries.append(None)
            continue
        picked: list[str] = []
        prod = 1
        for ax in _ROLE_AXES[role]:
            if ax not in sizes or ax in used:
                continue
            if dim % (prod * sizes[ax]) != 0:
                break  # greedy prefix: stop at the first non-dividing axis
            picked.append(ax)
            prod *= sizes[ax]
        if not picked:
            entries.append(None)
        else:
            used.update(picked)
            entries.append(picked[0] if len(picked) == 1 else tuple(picked))
    return P(*entries)


# ----------------------------------------------------------------------
# active-mesh context
# ----------------------------------------------------------------------

_local = threading.local()


def active_mesh():
    """The innermost mesh entered via :func:`use_mesh`, or None."""
    stack = getattr(_local, "mesh_stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Make ``mesh`` the ambient mesh for :func:`constrain` inside traces."""
    stack = getattr(_local, "mesh_stack", None)
    if stack is None:
        stack = _local.mesh_stack = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


def constrain(x, axis_roles: Sequence[Optional[str]]):
    """``with_sharding_constraint`` against the active mesh; no-op without one.

    Safe to call unconditionally from model code: on a single device (or when
    no ``use_mesh`` context is active at trace time) it returns ``x``.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = resolve_pspec(x.shape, axis_roles, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------
# tree mappers
# ----------------------------------------------------------------------

# Trailing-dim roles per parameter leaf name. Leaves carry a variable number
# of leading stack dims (lax.scan layer stacking; vlm groups stack twice) —
# rules describe only the logical trailing dims and pad left with None.
_PARAM_RULES: dict[str, tuple] = {
    # embeddings / positional tables
    "tok": ("tp", "fsdp"),
    "head": ("fsdp", "tp"),
    "meta": (None, "fsdp"),
    "enc_pos": (None, "fsdp"),
    "dec_pos": (None, "fsdp"),
    # attention projections (column-parallel in, row-parallel out)
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # FFN (SwiGLU)
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "router": ("fsdp", None),
    # SSM mixers
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "conv_w": ("fsdp", None),
    "conv_b": ("fsdp",),
    # SSM per-head vectors follow the cache's head sharding
    # (cache_pspecs shards the H dim of (B, H, hd, N) states on tp)
    "A_log": ("tp",),
    "D": ("tp",),
    "dt_bias": ("tp",),
    # norm scales/biases and residual gates are elementwise over activation
    # dims that stay unsharded — replicate (sharding them under the generic
    # matrix fallback would split the layer-stack dim, audited ISSUE 3)
    "w": (None,),
    "b": (None,),
    "q_norm": (None,),
    "k_norm": (None,),
    "norm_attn": (None,),
    "norm_ssm": (None,),
    "gate_norm": (None,),
    "gate_attn": (),
    "gate_ffn": (),
}

#: every parameter leaf name that has been explicitly audited against the
#: production mesh; ``test_param_rules_cover_all_archs`` fails when a model
#: introduces a leaf name outside this set, forcing a deliberate rule
#: instead of a silent generic fallback
AUDITED_PARAM_LEAVES = frozenset(_PARAM_RULES)

# Expert-parallel variants: the stacked (E, d, f) weights shard experts on
# the model axis; the hidden dim must then stay unsharded (axis reuse).
_MOE_PARAM_RULES: dict[str, tuple] = {
    "w_gate": ("experts", "fsdp", None),
    "w_up": ("experts", "fsdp", None),
    "w_down": ("experts", None, "fsdp"),
}


def _path_names(path) -> list[str]:
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(str(entry.key))
        elif hasattr(entry, "name"):
            out.append(str(entry.name))
    return out


def _pad_roles(roles: tuple, ndim: int) -> Optional[tuple]:
    if ndim < len(roles):
        return None
    return (None,) * (ndim - len(roles)) + tuple(roles)


def _param_roles(path, ndim: int) -> tuple:
    names = _path_names(path)
    name = names[-1] if names else ""
    in_moe = "moe" in names[:-1] and "dense" not in names[:-1]
    if in_moe and name in _MOE_PARAM_RULES:
        roles = _pad_roles(_MOE_PARAM_RULES[name], ndim)
        if roles is not None:
            return roles
    if name in _PARAM_RULES:
        roles = _pad_roles(_PARAM_RULES[name], ndim)
        if roles is not None:
            return roles
    # generic fallback: matrices get megatron-ish (fsdp, tp) on the trailing
    # two dims; vectors/scalars (norm scales, gates, A_log, ...) replicate
    if ndim >= 2:
        return (None,) * (ndim - 2) + ("fsdp", "tp")
    return (None,) * ndim


def param_pspecs(params, mesh):
    """Map every parameter leaf (arrays or ShapeDtypeStructs) to a
    PartitionSpec. Structure-preserving, so the result plugs straight into
    ``jax.jit`` in/out shardings and ``device_put``."""

    def one(path, leaf):
        return resolve_pspec(leaf.shape, _param_roles(path, len(leaf.shape)), mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_pspecs(batch, mesh):
    """Input batches shard their leading (batch) dim; everything else
    replicates. Works for token batches and modality frontends alike."""

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return resolve_pspec(leaf.shape, ("batch",) + (None,) * (nd - 1), mesh)

    return jax.tree.map(one, batch)


# Cache leaves are stacked along a leading layer dim; roles are anchored on
# the trailing dims by leaf name.
_CACHE_RULES: dict[str, tuple] = {
    # (..., B, S, H_kv, hd): batch + head sharding, never the seq dim
    "k": ("batch", None, "tp", None),
    "v": ("batch", None, "tp", None),
    "ck": ("batch", None, "tp", None),
    "cv": ("batch", None, "tp", None),
    # (..., B, conv_dim, W)
    "conv": ("batch", None, None),
    # (..., B, H, hd, N)
    "ssm": ("batch", "tp", None, None),
}


def cache_pspecs(caches, mesh):
    """PartitionSpecs for prefill/decode cache trees (KV + SSM states)."""

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        roles = _pad_roles(_CACHE_RULES.get(name, ()), len(leaf.shape)) if name in _CACHE_RULES else None
        if roles is None:
            roles = (None,) * len(leaf.shape)
        return resolve_pspec(leaf.shape, roles, mesh)

    return jax.tree_util.tree_map_with_path(one, caches)


def to_named(specs, mesh):
    """Replace every PartitionSpec leaf with a NamedSharding on ``mesh``.

    Non-spec leaves (None placeholders like the lazy error-feedback buffer)
    pass through untouched.
    """
    if isinstance(specs, P):
        return NamedSharding(mesh, specs)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
