"""Pipeline parallelism via ``shard_map`` + collective permutes: GPipe,
interleaved-1F1B and zero-bubble ZB-H1 schedules, plus the analytical
bubble models the predictor uses (``core.e2e.pp_bubble``).

All schedules stream microbatches around a ring of ``S`` pipeline stages
(one device per stage along the pipeline mesh axis). The layer stack
(leaves stacked along a leading layer dim, the layout ``Segment.init``
produces) is split into contiguous chunks in layer order; at every tick a
device applies one chunk to the activation it holds, then ``ppermute``
shifts activations one stage down the ring. The schedules differ only
in how many chunks each device owns and how long a microbatch occupies
its ring slot:

``schedule="gpipe"``
    One chunk per device (``n_layers / S`` layers). A microbatch makes
    ``S`` hops; with ``M`` microbatches the schedule runs ``M + S - 1``
    ticks — bubble fraction ``(S - 1) / (M + S - 1)`` (fill + drain).

``schedule="1f1b"``
    The interleaved schedule: each device owns ``V = interleave`` chunks
    (``n_layers / (S * V)`` layers each), placed round-robin so global
    chunk ``g`` lives on device ``g mod S`` — a microbatch makes ``V * S``
    hops through the same ring, visiting every device ``V`` times. Each
    tick now moves ``1/V`` of a GPipe stage, so fill/drain cost shrinks by
    ``V`` relative to the work: for ``S | M`` the schedule runs
    ``V*M + S - 1`` ticks of ``1/V`` stage-time each — bubble fraction
    ``(S - 1) / (V*M + S - 1)``, strictly below GPipe's whenever ``S > 1``.
    (This is the forward pass of Megatron's interleaved 1F1B; the name is
    kept because the *schedule geometry* — virtual stages on a ring — is
    what sets the bubble, for forward-only serving exactly as for
    training.)

``schedule="zb-h1"``
    The zero-bubble three-phase schedule (ZB-H1 lineage): backward is
    split into B (input-grad) and W (weight-grad) ticks, so each
    microbatch's ring lifecycle is ``3*V*S`` chunk-ticks — ``V*S``
    F ticks that apply the layer chunks in order, ``V*S`` B occupancy
    ticks (the input-grad wave re-crossing every chunk boundary in the
    same ring direction), and ``V*S`` W ticks whose weight-grad work is
    what fills the warmup/cooldown slots that 1F1B leaves idle. All
    three phases are useful per-device work, so with three times the
    work amortizing the *same* straggler drain the bubble shrinks:
    ``1 - 3*V*M / ticks`` with
    ``ticks = 3*V*S*ceil(M/S) + (M-1) mod S`` — for ``S | M`` and
    ``V = 1`` that is ``3M + S - 1`` ticks, the canonical ZB-H1
    makespan. The executed forward applies chunks only during the F
    phase and carries the finished activation through the B/W occupancy
    ticks, so numerics still equal the sequential scan exactly.

    Ordering theorem (pinned by ``tests/test_zero_bubble.py``): with
    ``r = (M-1) mod S``, ``bubble(zb-h1) <= bubble(1f1b)`` iff
    ``3 * ticks_1f1b >= ticks_zb`` iff ``2r >= 0`` — always true, and
    *strict* exactly when ``r != 0`` (at ``M ≡ 1 (mod S)`` the lone
    straggler drains identically under both and they tie, the same tie
    region as 1F1B-vs-GPipe).

Every analytical quantity here is *exact*, not asymptotic:
:func:`schedule_ticks` is the precise number of ring ticks the shard_map
implementation scans, :func:`simulate_schedule` re-derives it by stepping
the ring event by event (the property tests pin closed form == simulation
== executed scan length for both schedules), and :func:`bubble_fraction`
is ``1 - ideal_work / ticks`` in consistent tick units.

Numerics match a sequential ``lax.scan`` over the full stack exactly for
all schedules: each microbatch sees the same layer order and the same
per-microbatch operand shapes, only interleaved in time across devices.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = [
    "pipeline_forward",
    "pipeline_bubble_fraction",
    "schedule_ticks",
    "bubble_fraction",
    "simulate_schedule",
    "SCHEDULES",
]

#: schedules pipeline_forward / schedule_ticks / bubble_fraction understand
SCHEDULES = ("gpipe", "1f1b", "zb-h1")

#: lifecycle phases per ring slot: 1F1B runs forward only (F); ZB-H1 adds
#: the B (input-grad) and W (weight-grad) occupancy phases — 3x the
#: per-microbatch chunk-ticks on the same slot machine
_PHASES = {"gpipe": 1, "1f1b": 1, "zb-h1": 3}


def _check_schedule(schedule: str) -> None:
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, got {schedule!r}")


def schedule_ticks(
    n_stages: int, n_micro: int, schedule: str = "gpipe", interleave: int = 2
) -> int:
    """Exact ring-tick count of the executed :func:`pipeline_forward`
    schedule (the length of its ``lax.scan``).

    GPipe: ``M + S - 1``. The ring schedules hold at most ``S`` in-flight
    microbatches (one slot per device); a microbatch occupies its slot
    for its full lifecycle ``L`` and a new one can enter stage 0 only
    when the incoming slot is free — giving

        ``L * ceil(M/S) + (M-1) mod S``

    with ``L = V*S`` for interleaved 1F1B (``V*M + S - 1`` when ``S``
    divides ``M``, the Megatron interleaved form) and ``L = 3*V*S`` for
    ZB-H1 (the F/B/W three-phase lifecycle; ``3M + S - 1`` at ``V = 1``
    and ``S | M``, the canonical ZB-H1 makespan). With ``interleave=1``
    the 1F1B count degenerates to GPipe's ``M + S - 1`` — the ring is
    the same machine. Note a ring tick is ``1/V`` of a GPipe tick (a
    chunk is ``1/V`` of a stage); :func:`bubble_fraction` normalizes for
    that.
    """
    _check_schedule(schedule)
    S, M = int(n_stages), int(n_micro)
    if S < 1 or M < 1:
        raise ValueError(f"need n_stages >= 1 and n_micro >= 1, got {S}, {M}")
    if schedule == "gpipe":
        return M + S - 1
    V = int(interleave)
    if V < 1:
        raise ValueError(f"interleave must be >= 1, got {V}")
    return _PHASES[schedule] * V * S * math.ceil(M / S) + (M - 1) % S


def bubble_fraction(
    n_stages: int, n_micro: int, schedule: str = "gpipe", interleave: int = 2
) -> float:
    """Idle fraction of the schedule: ``1 - ideal_work / ticks``.

    Per-device ideal work is ``M`` stage-ticks for GPipe, ``V*M``
    chunk-ticks for 1F1B and ``3*V*M`` for ZB-H1 (F + B + W are all
    useful per-device compute; a chunk-tick is ``1/V`` of a stage-tick),
    so the fractions are directly comparable across schedules. For all
    ``(S, M >= 1)``: the 1F1B fraction is <= GPipe's, strictly smaller
    whenever ``S > 1``, ``interleave >= 2`` and ``M mod S != 1`` (at
    ``M ≡ 1 (mod S)`` the straggler microbatch drains alone under both
    schedules and they tie); and the ZB-H1 fraction is <= 1F1B's at the
    same ``V``, strictly smaller exactly when ``(M - 1) mod S != 0`` —
    pinned by the property tests in ``tests/test_parallelism.py`` and
    ``tests/test_zero_bubble.py``.
    """
    ticks = schedule_ticks(n_stages, n_micro, schedule, interleave)
    V = 1 if schedule == "gpipe" else int(interleave)
    work = n_micro * V * _PHASES[schedule]
    return (ticks - work) / ticks


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule (fill + drain). Kept for
    backward compatibility; equals ``bubble_fraction(S, M, "gpipe")``."""
    return bubble_fraction(n_stages, n_micro, "gpipe")


def simulate_schedule(
    n_stages: int, n_micro: int, schedule: str = "gpipe", interleave: int = 2
) -> int:
    """Event-driven reference simulation of the activation ring.

    Steps the exact machine :func:`pipeline_forward` implements — one
    in-flight slot per device, stage-0 injection only into a free slot,
    one lifecycle tick per ring tick, then a ring shift — and returns the
    tick at which the **last** microbatch completes. For ZB-H1 a slot's
    lifecycle spans the three phases (``g // (V*S)`` is 0 during F, 1
    during B, 2 during W); occupancy and completion are what set the tick
    count, so the same machine covers all ring schedules. This is an
    independent derivation of :func:`schedule_ticks` (no shared
    arithmetic); the property tests assert simulation == closed form for
    every schedule across the whole ``(S, M, V)`` grid, which is what
    licenses using the closed form as the analytical bubble model in
    ``core.e2e``.
    """
    _check_schedule(schedule)
    S, M = int(n_stages), int(n_micro)
    V = int(interleave) if schedule != "gpipe" else 1
    total_stages = _PHASES[schedule] * V * S
    slots: list = [None] * S  # per-device in-flight (microbatch, next stage)
    next_m = done = ticks = 0
    while done < M:
        if slots[0] is None and next_m < M:
            slots[0] = (next_m, 0)  # stage-0 injection into the free slot
            next_m += 1
        shifted: list = [None] * S
        for d in range(S):
            if slots[d] is None:
                continue
            m, g = slots[d]
            assert g % S == d, "chunk placement invariant: stage g lives on g mod S"
            g += 1
            if g == total_stages:
                done += 1  # finished on device S-1; slot recycles via the ring
            else:
                shifted[(d + 1) % S] = (m, g)
        slots = shifted
        ticks += 1
    return ticks


# ----------------------------------------------------------------------
# executed schedules (shard_map + ppermute)
# ----------------------------------------------------------------------


def pipeline_forward(
    layer_fn: Callable,
    params: Any,
    x,
    mesh,
    axis: Optional[str] = None,
    *,
    schedule: str = "gpipe",
    interleave: int = 2,
    ticks: Optional[int] = None,
):
    """Run a stacked layer pytree as a pipeline over ``mesh``.

    Schedule contract:

    * ``schedule="gpipe"`` (default): one contiguous stage per device;
      ``n_layers`` must divide by the pipeline axis size ``S``. Runs
      exactly ``schedule_ticks(S, M, "gpipe")`` ticks.
    * ``schedule="1f1b"``: interleaved virtual stages; ``n_layers`` must
      divide by ``S * interleave``. Runs exactly
      ``schedule_ticks(S, M, "1f1b", interleave)`` ticks. Any ``M >= 1``
      is supported (non-divisible microbatch counts pay the straggler
      drain the analytical model prices).
    * ``schedule="zb-h1"``: the zero-bubble three-phase ring; same layer
      divisibility as 1F1B. Chunks are applied during the F phase
      (lifecycle ticks ``< V*S``); the B/W phases carry the finished
      activation as occupancy ticks, so the output still equals the
      sequential scan. Runs exactly
      ``schedule_ticks(S, M, "zb-h1", interleave)`` ticks.

    Args:
      layer_fn: ``(layer_params, h) -> h`` for a single layer; applied to
        per-microbatch activations, so ``h`` has shape ``x.shape[1:]``.
      params: pytree whose leaves are stacked ``(n_layers, ...)``.
      x: ``(n_micro, *per_microbatch_shape)`` microbatched inputs.
      mesh: mesh containing the pipeline axis (defaults to its first axis).
      ticks: test/debug override of the scan length. The default (None)
        uses the analytical :func:`schedule_ticks`; the exactness tests
        run with ``ticks - 1`` to prove the analytical count is minimal,
        not merely sufficient.

    Returns ``(n_micro, *per_microbatch_shape)`` outputs, replicated across
    the pipeline axis — equal to scanning every layer over each microbatch
    (both schedules preserve layer order exactly).
    """
    _check_schedule(schedule)
    axis = axis or mesh.axis_names[0]
    if schedule in ("1f1b", "zb-h1"):
        return _forward_ring(
            layer_fn, params, x, mesh, axis, interleave, ticks, schedule
        )
    return _forward_gpipe(layer_fn, params, x, mesh, axis, ticks)


def _forward_gpipe(layer_fn, params, x, mesh, axis, ticks=None):
    n_stages = mesh.shape[axis]
    n_layers = jax.tree.leaves(params)[0].shape[0]
    if n_layers % n_stages != 0:
        raise ValueError(f"{n_layers} layers not divisible into {n_stages} stages")
    n_micro = x.shape[0]
    n_ticks = schedule_ticks(n_stages, n_micro, "gpipe") if ticks is None else ticks
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_fn(stage_params, x_all):
        stage = lax.axis_index(axis)

        def apply_stage(h):
            def body(c, lp):
                return layer_fn(lp, c), None

            h, _ = lax.scan(body, h, stage_params)
            return h

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t while the schedule is filling
            inp = lax.dynamic_index_in_dim(x_all, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            h = jnp.where(jnp.logical_and(stage == 0, t < n_micro), inp, state)
            y = apply_stage(h)
            # the last stage finishes microbatch t - (S - 1) at tick t
            idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            cur = lax.dynamic_index_in_dim(outputs, idx, keepdims=False)
            take = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            outputs = lax.dynamic_update_index_in_dim(
                outputs, jnp.where(take, y, cur), idx, 0
            )
            state = lax.ppermute(y, axis, ring)
            return (state, outputs), None

        init = (jnp.zeros_like(x_all[0]), jnp.zeros_like(x_all))
        (_, outputs), _ = lax.scan(tick, init, jnp.arange(n_ticks))
        # only the last stage holds real outputs; psum broadcasts them so the
        # result is replicated (out_specs P() below)
        return lax.psum(jnp.where(stage == n_stages - 1, outputs, 0.0), axis)

    pspecs = jax.tree.map(lambda _: P(axis), params)
    return shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_rep=False,  # ppermute-carried state is intentionally unreplicated
    )(params, x)


def _forward_ring(layer_fn, params, x, mesh, axis, interleave, ticks=None,
                  schedule="1f1b"):
    n_stages = mesh.shape[axis]
    V = int(interleave)
    if V < 1:
        raise ValueError(f"interleave must be >= 1, got {V}")
    n_layers = jax.tree.leaves(params)[0].shape[0]
    if n_layers % (n_stages * V) != 0:
        raise ValueError(
            f"{n_layers} layers not divisible into {n_stages} stages x "
            f"{V} interleaved chunks"
        )
    per_chunk = n_layers // (n_stages * V)
    n_micro = x.shape[0]
    # forward chunk-stages apply layers; ZB-H1 extends the slot lifecycle
    # with the B/W occupancy phases (chunks applied only while g < V*S)
    forward_stages = V * n_stages
    total_stages = _PHASES[schedule] * forward_stages
    n_ticks = (
        schedule_ticks(n_stages, n_micro, schedule, V) if ticks is None else ticks
    )
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    # round-robin chunk placement: global chunk g = j * S + d lives on
    # device d, local slot j — reshape (L, ...) -> (V, S, per_chunk, ...)
    # and shard dim 1 so each device holds its V interleaved chunks
    chunked = jax.tree.map(
        lambda p: p.reshape(V, n_stages, per_chunk, *p.shape[1:]), params
    )

    def stage_fn(chunk_params, x_all):
        stage = lax.axis_index(axis)
        local = jax.tree.map(lambda p: p[:, 0], chunk_params)  # (V, per_chunk, ...)

        def apply_chunk(j, h):
            def run(jj):
                def f(hh):
                    def body(c, lp):
                        return layer_fn(lp, c), None

                    out, _ = lax.scan(body, hh, jax.tree.map(lambda p: p[jj], local))
                    return out

                return f

            return lax.switch(j, [run(jj) for jj in range(V)], h)

        def tick(carry, _t):
            h, g, m, live, next_m, outputs = carry
            # stage-0 injection: only into a free (non-live) incoming slot
            inject = jnp.logical_and(
                jnp.logical_and(stage == 0, live == 0), next_m < n_micro
            )
            inp = lax.dynamic_index_in_dim(
                x_all, jnp.clip(next_m, 0, n_micro - 1), keepdims=False
            )
            h = jnp.where(inject, inp, h)
            g = jnp.where(inject, 0, g)
            m = jnp.where(inject, next_m, m)
            live = jnp.where(inject, 1, live)
            next_m = next_m + inject.astype(jnp.int32)
            # process the local chunk this slot's next stage maps to; B/W
            # occupancy ticks (zb-h1, g >= V*S) carry h through unchanged
            j = jnp.clip(g // n_stages, 0, V - 1)
            y = apply_chunk(j, h)
            h = jnp.where(
                jnp.logical_and(live == 1, g < forward_stages), y, h
            )
            g = g + 1
            # the final lifecycle tick (g == phases*V*S) lands on device S-1
            fin = jnp.logical_and(live == 1, g >= total_stages)
            idx = jnp.clip(m, 0, n_micro - 1)
            cur = lax.dynamic_index_in_dim(outputs, idx, keepdims=False)
            outputs = lax.dynamic_update_index_in_dim(
                outputs, jnp.where(fin, h, cur), idx, 0
            )
            live = jnp.where(fin, 0, live)
            h = lax.ppermute(h, axis, ring)
            g = lax.ppermute(g, axis, ring)
            m = lax.ppermute(m, axis, ring)
            live = lax.ppermute(live, axis, ring)
            return (h, g, m, live, next_m, outputs), None

        zero = jnp.zeros((), jnp.int32)
        init = (
            jnp.zeros_like(x_all[0]),
            zero,  # g: next global chunk-stage of the held slot
            zero,  # m: microbatch index of the held slot
            zero,  # live: slot occupancy flag (int32 so ppermute is uniform)
            zero,  # next_m: injection counter (meaningful on stage 0 only)
            jnp.zeros_like(x_all),
        )
        (_, _, _, _, _, outputs), _ = lax.scan(tick, init, jnp.arange(n_ticks))
        return lax.psum(jnp.where(stage == n_stages - 1, outputs, 0.0), axis)

    pspecs = jax.tree.map(lambda _: P(None, axis), chunked)
    return shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_rep=False,  # ppermute-carried state is intentionally unreplicated
    )(chunked, x)
