"""GPipe-style pipeline parallelism via ``shard_map`` + collective permutes.

The layer stack (leaves stacked along a leading layer dim, the same layout
``Segment.init`` produces) is split into ``n_stages`` contiguous stages, one
per device along the pipeline mesh axis. Microbatches stream through the
stages: at every tick each stage applies its local layers to the microbatch
it holds, then ``ppermute`` shifts activations one stage down the ring.
Stage 0 ingests a fresh microbatch per tick; the last stage emits a finished
one. With M microbatches and S stages the schedule runs M + S - 1 ticks, a
bubble fraction of (S - 1) / (M + S - 1) — the quantity the analytical
decomposer models for cross-pipeline workloads.

Numerics match a sequential ``lax.scan`` over the full stack exactly: each
microbatch sees the same layer order and the same per-microbatch operand
shapes, only interleaved in time across devices.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward", "pipeline_bubble_fraction"]


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule (fill + drain)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_forward(layer_fn: Callable, params: Any, x, mesh, axis: Optional[str] = None):
    """Run a stacked layer pytree as a GPipe pipeline over ``mesh``.

    Args:
      layer_fn: ``(layer_params, h) -> h`` for a single layer; applied to
        per-microbatch activations, so ``h`` has shape ``x.shape[1:]``.
      params: pytree whose leaves are stacked ``(n_layers, ...)``; n_layers
        must be divisible by the pipeline axis size.
      x: ``(n_micro, *per_microbatch_shape)`` microbatched inputs.
      mesh: mesh containing the pipeline axis (defaults to its first axis).

    Returns ``(n_micro, *per_microbatch_shape)`` outputs, replicated across
    the pipeline axis — equal to scanning every layer over each microbatch.
    """
    axis = axis or mesh.axis_names[0]
    n_stages = mesh.shape[axis]
    n_layers = jax.tree.leaves(params)[0].shape[0]
    if n_layers % n_stages != 0:
        raise ValueError(f"{n_layers} layers not divisible into {n_stages} stages")
    n_micro = x.shape[0]
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_fn(stage_params, x_all):
        stage = lax.axis_index(axis)

        def apply_stage(h):
            def body(c, lp):
                return layer_fn(lp, c), None

            h, _ = lax.scan(body, h, stage_params)
            return h

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t while the schedule is filling
            inp = lax.dynamic_index_in_dim(x_all, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            h = jnp.where(jnp.logical_and(stage == 0, t < n_micro), inp, state)
            y = apply_stage(h)
            # the last stage finishes microbatch t - (S - 1) at tick t
            idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            cur = lax.dynamic_index_in_dim(outputs, idx, keepdims=False)
            take = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            outputs = lax.dynamic_update_index_in_dim(
                outputs, jnp.where(take, y, cur), idx, 0
            )
            state = lax.ppermute(y, axis, ring)
            return (state, outputs), None

        init = (jnp.zeros_like(x_all[0]), jnp.zeros_like(x_all))
        (_, outputs), _ = lax.scan(tick, init, jnp.arange(n_micro + n_stages - 1))
        # only the last stage holds real outputs; psum broadcasts them so the
        # result is replicated (out_specs P() below)
        return lax.psum(jnp.where(stage == n_stages - 1, outputs, 0.0), axis)

    pspecs = jax.tree.map(lambda _: P(axis), params)
    return shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_rep=False,  # ppermute-carried state is intentionally unreplicated
    )(params, x)
