"""Emit the EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

  PYTHONPATH=src python -m repro.roofline.report --dir results/dryrun \
      [--baseline results/dryrun_baseline] > tables.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.roofline.analysis import (
    ICI_BW,
    HBM_BW,
    PEAK_FLOPS,
    load_rows,
    markdown_table,
    pick_hillclimb_cells,
)


def dryrun_table(rows) -> str:
    hdr = (
        "| arch | shape | mesh | HLO TFLOP/dev | HBM GB/dev | coll GB/dev | "
        "collective mix | compile s |\n|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for d in rows:
        mix = d["collectives"]
        parts = [
            f"{k.split('-')[1][:3] if '-' in k else k}:{v['bytes']/1e9:.1f}G"
            for k, v in mix.items()
            if isinstance(v, dict) and v.get("bytes", 0) > 1e8
        ]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{d['flops']/1e12:.2f} | {d['hbm_bytes']/1e9:.1f} | "
            f"{mix['_total_bytes']/1e9:.2f} | {' '.join(parts) or '-'} | "
            f"{d['compile_s']} |"
        )
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--section", default="all", choices=("all", "dryrun", "roofline", "compare"))
    args = ap.parse_args()

    raw = [json.load(open(p)) for p in sorted(glob.glob(os.path.join(args.dir, "*.json")))]
    rows = load_rows(args.dir)

    if args.section in ("all", "dryrun"):
        print("### §Dry-run — compiled artifacts (per-device, SPMD-partitioned)\n")
        print(dryrun_table(raw))
        print()
    if args.section in ("all", "roofline"):
        print("### §Roofline — three-term analysis\n")
        print(f"Constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16/chip, "
              f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s/link ICI.\n")
        print(markdown_table(rows))
        print()
        picks = pick_hillclimb_cells(rows)
        print("Hillclimb picks:")
        for why, r in picks.items():
            print(f"- **{why}**: {r.arch}/{r.shape}/{r.mesh} "
                  f"(dominant={r.dominant}, bound={r.bound_s:.2f}s)")
        print()
    if args.baseline and args.section in ("all", "compare"):
        base_rows = {(r.arch, r.shape, r.mesh): r for r in load_rows(args.baseline)}
        print("### §Perf — baseline vs optimized (paper-faithful -> beyond-paper)\n")
        print("| cell | term | baseline (s) | optimized (s) | delta |\n|---|---|---|---|---|")
        for r in rows:
            b = base_rows.get((r.arch, r.shape, r.mesh))
            if b is None:
                continue
            for term in ("compute", "memory", "collective"):
                bv = getattr(b, f"{term}_s" if term != "compute" else "compute_s")
                ov = getattr(r, f"{term}_s" if term != "compute" else "compute_s")
                if max(bv, ov) < 1e-4:
                    continue
                delta = (bv - ov) / max(bv, 1e-30) * 100
                mark = "**" if abs(delta) > 5 else ""
                print(f"| {r.arch}/{r.shape}/{r.mesh} | {term} | {bv:.3e} | "
                      f"{ov:.3e} | {mark}{delta:+.1f}%{mark} |")


if __name__ == "__main__":
    main()
