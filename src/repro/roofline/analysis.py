"""Three-term roofline analysis over the compiled dry-run artifacts.

Per (arch x shape x mesh) cell, from results/dryrun/*.json (produced by
repro.launch.dryrun with the loop-aware HLO walker):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / ICI_link_bw

(the per-device numbers come from the SPMD-partitioned module, so dividing
by per-chip peaks is the same as the global/(chips*peak) formulation).

Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from repro.configs import SHAPES, get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_dev: float
    hlo_flops_dev: float
    hbm_bytes_dev: float
    coll_bytes_dev: float

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_dev / max(self.hlo_flops_dev, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / bound: what fraction of the step's lower-bound time
        is spent at the compute roof (1.0 = perfectly compute-bound)."""
        return self.compute_s / max(self.bound_s, 1e-30)

    def advice(self) -> str:
        if self.dominant == "memory":
            return (
                "memory-bound: cut HBM traffic (fuse/keep attention scores & "
                "SSD intra-chunk tensors in VMEM via Pallas kernels; fewer "
                "fusion-boundary materializations)"
            )
        if self.dominant == "collective":
            return (
                "collective-bound: reshard to reduce all-gather/reduce volume "
                "(fsdp gather granularity, TP axis choice) or overlap with "
                "compute"
            )
        if self.useful_ratio < 0.45:
            return (
                "compute-bound but low useful ratio: reduce recompute (remat "
                "policy) and masked-out causal work (block-sparse schedule)"
            )
        return "compute-bound: near the MXU roof; remaining headroom is remat policy"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    N = cfg.active_params()
    if shape.kind == "train":
        total = 6.0 * N * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * N * shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        total = 2.0 * N * shape.global_batch
    return total / n_devices


def load_rows(dryrun_dir: str = "results/dryrun") -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(path))
        n = d["n_devices"]
        rows.append(
            RooflineRow(
                arch=d["arch"],
                shape=d["shape"],
                mesh=d["mesh"],
                n_devices=n,
                compute_s=d["flops"] / PEAK_FLOPS,
                memory_s=d["hbm_bytes"] / HBM_BW,
                collective_s=d["collectives"]["_total_bytes"] / ICI_BW,
                model_flops_dev=model_flops_per_device(d["arch"], d["shape"], n),
                hlo_flops_dev=d["flops"],
                hbm_bytes_dev=d["hbm_bytes"],
                coll_bytes_dev=d["collectives"]["_total_bytes"],
            )
        )
    return rows


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO flops | roofline frac | what would move it |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.2f} | {r.advice()} |"
        )
    return hdr + "\n".join(lines)


def pick_hillclimb_cells(rows: list[RooflineRow]) -> dict:
    """worst roofline fraction, most collective-bound, most representative of
    the paper's technique (the biggest fused-MoE training cell). Trivial
    cells (bound < 10 ms, launch-overhead territory) are excluded from the
    'worst fraction' pick."""
    single = [r for r in rows if r.mesh == "16x16"]
    heavy = [r for r in single if r.bound_s >= 0.01] or single
    worst = min(heavy, key=lambda r: r.roofline_fraction)
    coll = max(single, key=lambda r: r.collective_s / max(r.bound_s, 1e-30))
    moe = [r for r in single if get_arch(r.arch).n_experts and r.shape == "train_4k"]
    rep = max(moe, key=lambda r: r.hlo_flops_dev) if moe else single[0]
    return {"worst_fraction": worst, "most_collective": coll, "paper_representative": rep}
