"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
which undercounts scanned layer stacks by ~n_layers (validated in
tests/test_hlo_cost.py). This walker parses the optimized HLO module,
extracts ``known_trip_count`` from each while's backend_config, and
aggregates, weighted by execution count:

  * dot FLOPs           = 2 * numel(out) * prod(contracting dims)
  * vector (VPU) ops    = elementwise op output elements
  * transcendental ops  = exp/tanh/log/rsqrt/... output elements
  * HBM bytes           = operand+output bytes of top-level instructions
                          (fusion boundaries = the memory schedule; fused
                          interiors stay on-chip)
  * collective bytes    = per-kind operand bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute

All numbers are PER DEVICE (the module is already SPMD-partitioned).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "and", "or", "xor", "not", "select", "clamp", "compare", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "convert",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "is-finite", "atan2",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "tan", "logistic",
    "erf", "expm1", "log1p",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# top-level ops considered free of HBM traffic
_FREE = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "while", "call", "conditional", "partition-id", "replica-id",
    "after-all", "iota", "rng-bit-generator", "custom-call",
    "opt-barrier", "domain",
}

# split on commas outside [], () and {} — operand annotations can carry
# explicit layouts (f32[2,512,32]{2,1,0}) whose inner commas must not split
_TUPLE_SPLIT = re.compile(r",\s*(?![^\[\({]*[\]\)}])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)|(?:[a-z0-9]+\[\]))\s*"
    r"([a-z0-9\-]+)\((.*?)\)(.*)$"
)


def _type_numel_bytes(t: str) -> tuple[int, int]:
    """(numel, bytes) of a type string (tuples summed)."""
    numel = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


def _shape_dims(t: str) -> list[int]:
    m = _SHAPE_RE.search(t)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type: str
    op: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict  # name -> type
    instrs: list


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                params = {}
                for part in _TUPLE_SPLIT.split(m.group(3)):
                    part = part.strip()
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(m.group(2), params, [])
                if m.group(1):
                    entry_name = m.group(2)
                continue
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                name, typ, op, ops_str, attrs = m.groups()
                operands = [o.strip() for o in _TUPLE_SPLIT.split(ops_str)] if ops_str.strip() else []
                cur.instrs.append(Instr(name, typ, op, operands, attrs))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_type(opnd: str, comp: Computation, symtab: dict) -> Optional[str]:
    """Resolve an operand's type: inline annotation, local def or param."""
    opnd = opnd.strip()
    m = re.match(r"^((?:\([^=]*?\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+%?([\w.\-]+)$", opnd)
    if m:
        return m.group(1)
    name = opnd.lstrip("%")
    if name in symtab:
        return symtab[name]
    if name in comp.params:
        return comp.params[name]
    return None


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _trip_count(instr: Instr, comps) -> int:
    m = _TRIP_RE.search(instr.attrs)
    if m:
        return int(m.group(1))
    # fallback: look for compare against a constant in the condition
    cm = _COND_RE.search(instr.attrs)
    if cm and cm.group(1) in comps:
        for i in comps[cm.group(1)].instrs:
            if i.op == "constant":
                d = re.search(r"constant\((\d+)\)", i.attrs or "")
        # give up
    return 1


@dataclasses.dataclass
class CostSummary:
    dot_flops: float = 0.0
    vector_ops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: {"bytes": 0.0, "count": 0.0} for k in _COLLECTIVES}
    )
    n_while: int = 0
    unknown_ops: dict = dataclasses.field(default_factory=dict)

    @property
    def flops(self) -> float:
        return self.dot_flops + self.vector_ops

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "vector_ops": self.vector_ops,
            "flops": self.flops,
            "transcendentals": self.transcendentals,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "n_while": self.n_while,
            "unknown_ops": dict(sorted(self.unknown_ops.items(), key=lambda kv: -kv[1])[:10]),
        }


def analyze_hlo(text: str) -> CostSummary:
    comps = parse_module(text)
    summary = CostSummary()
    if "__entry__" not in comps:
        return summary
    _walk(comps["__entry__"], 1.0, comps, summary, top_level=True, seen=set())
    return summary


# ----------------------------------------------------------------------
# HBM traffic model with TPU-style fusion grouping
# ----------------------------------------------------------------------
#
# The CPU backend emits much finer fusions than the TPU backend would, so
# "every top-level instruction's operands+outputs hit HBM" wildly overcounts
# traffic for the TPU target. We re-fuse conservatively: any producer whose
# op is fusible and whose value has exactly one consumer is merged into that
# consumer's group (XLA's producer-consumer fusion rule of thumb). Traffic is
# then the deduplicated group-boundary I/O, with slice-like ops counting
# their *output* size (a dynamic-slice reads a tile, not the whole buffer)
# and dynamic-update-slice counting 2x the update (in-place cache writes).

_ALIAS = {"get-tuple-element", "bitcast", "tuple", "reshape"}
_SLICE_LIKE = {"slice", "dynamic-slice", "gather"}
_FUSIBLE = (
    _ALIAS
    | _SLICE_LIKE
    | _ELEMENTWISE
    | _TRANSCENDENTAL
    | {"fusion", "broadcast", "reduce", "pad", "iota", "reduce-window", "map",
       "reverse", "concatenate"}
)
_SINKS = _FUSIBLE | {"dot"}
_ZERO_TRAFFIC = {
    "parameter", "constant", "while", "call", "conditional", "after-all",
    "partition-id", "replica-id", "tuple", "get-tuple-element", "bitcast",
    "opt-barrier", "domain", "add-dependency",
}


def _operand_names(instr: Instr) -> list:
    out = []
    for o in instr.operands:
        m = re.search(r"%?([\w.\-]+)\s*$", o.strip())
        if m:
            out.append(m.group(1))
    return out


def _fusion_read_sizes(instr: Instr, comps) -> dict[int, int]:
    """Effective read bytes per operand index of a fusion: when a fusion
    parameter is consumed ONLY by slice-like ops inside the fused
    computation (a fused dynamic-slice over, e.g., stacked scan residuals),
    the hardware reads the slice, not the whole buffer."""
    out: dict[int, int] = {}
    if comps is None:
        return out
    cm = _CALLS_RE.search(instr.attrs)
    if not cm or cm.group(1) not in comps:
        return out
    fused = comps[cm.group(1)]
    pnames = list(fused.params.keys())
    for idx, pname in enumerate(pnames):
        uses = [i for i in fused.instrs if pname in [n for n in _operand_names(i)]]
        if uses and all(u.op in _SLICE_LIKE for u in uses):
            out[idx] = sum(_type_numel_bytes(u.type)[1] for u in uses)
    return out


def computation_traffic(
    comp: Computation, comps: dict | None = None, _debug: list | None = None
) -> float:
    """Per-execution HBM bytes of one top-level computation.

    _debug: optional list collecting (group_bytes, root_op, root_name,
    n_members) tuples for introspection."""
    defs: dict[str, Instr] = {i.name: i for i in comp.instrs}
    symtab = {i.name: i.type for i in comp.instrs}
    symtab.update(comp.params)

    consumers: dict[str, list] = {}
    for i in comp.instrs:
        for on in _operand_names(i):
            consumers.setdefault(on, []).append(i.name)

    # union-find
    parent: dict[str, str] = {i.name: i.name for i in comp.instrs}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    root_name = comp.instrs[-1].name if comp.instrs else None
    for i in comp.instrs:
        if i.op not in _FUSIBLE:
            continue
        cons = consumers.get(i.name, [])
        ext_used = i.name == root_name
        if len(cons) == 1 and not ext_used:
            c = defs.get(cons[0])
            if c is not None and c.op in _SINKS:
                union(i.name, c.name)

    def nbytes(name):
        t = symtab.get(name)
        return _type_numel_bytes(t)[1] if t else 0

    groups: dict[str, list] = {}
    for i in comp.instrs:
        groups.setdefault(find(i.name), []).append(i)

    total = 0.0
    for gid, members in groups.items():
        gtotal = 0.0
        names = {m.name for m in members}
        if all(m.op in _ZERO_TRAFFIC for m in members):
            continue
        if len(members) == 1 and members[0].op == "dynamic-update-slice":
            ops = _operand_names(members[0])
            upd = nbytes(ops[1]) if len(ops) > 1 else 0
            total += 2.0 * upd
            continue
        seen_in = set()
        for m in members:
            # pure views (gte/bitcast/reshape/tuple) never touch HBM — real
            # consumers count the view-sized read themselves via symtab
            if m.op in _ZERO_TRAFFIC or m.op in _ALIAS:
                continue
            fusion_reads = _fusion_read_sizes(m, comps) if m.op == "fusion" else {}
            for oi, on in enumerate(_operand_names(m)):
                if on in names or on in seen_in:
                    continue
                seen_in.add(on)
                t = symtab.get(on)
                if t is None or t.lstrip().startswith("("):
                    # tuple-typed values are aliases (loop-carried state);
                    # real reads happen element-wise via gte consumers
                    continue
                b = _type_numel_bytes(t)[1]
                if m.op in _SLICE_LIKE or m.op in _ALIAS:
                    b = min(b, _type_numel_bytes(m.type)[1] or b)
                if oi in fusion_reads:
                    b = min(b, fusion_reads[oi])
                gtotal += b
        for m in members:
            if m.op in _ZERO_TRAFFIC:
                continue
            used_outside = m.name == root_name or any(
                c not in names for c in consumers.get(m.name, [])
            )
            if used_outside:
                if m.op == "dynamic-update-slice":
                    ops = _operand_names(m)
                    gtotal += 2.0 * (nbytes(ops[1]) if len(ops) > 1 else 0)
                else:
                    gtotal += _type_numel_bytes(m.type)[1]
        total += gtotal
        if _debug is not None:
            _debug.append((gtotal, members[-1].op, members[-1].name, len(members)))
    return total


_TRAFFIC_CACHE_KEY = "__traffic__"


def _walk(comp: Computation, weight: float, comps, s: CostSummary, *, top_level: bool, seen):
    if top_level:
        cache = getattr(s, "_traffic_cache", None)
        if cache is None:
            cache = {}
            s._traffic_cache = cache
        if comp.name not in cache:
            cache[comp.name] = computation_traffic(comp, comps)
        s.hbm_bytes += weight * cache[comp.name]
    symtab = {i.name: i.type for i in comp.instrs}
    for instr in comp.instrs:
        op = instr.op
        out_numel, out_bytes = _type_numel_bytes(instr.type)

        # ---- control flow ------------------------------------------------
        if op == "while":
            trips = _trip_count(instr, comps)
            s.n_while += 1
            body = _BODY_RE.search(instr.attrs)
            if body and body.group(1) in comps:
                _walk(comps[body.group(1)], weight * trips, comps, s, top_level=top_level, seen=seen)
            continue
        if op in ("call", "async-start"):
            cm = _TOAPPLY_RE.search(instr.attrs) or _CALLS_RE.search(instr.attrs)
            if cm and cm.group(1) in comps:
                _walk(comps[cm.group(1)], weight, comps, s, top_level=top_level, seen=seen)
            continue
        if op == "conditional":
            for branch in re.findall(r"(?:true_computation|false_computation|branch_computations=\{[^}]*\}|computation)=%?([\w.\-]+)", instr.attrs):
                if branch in comps:
                    _walk(comps[branch], weight, comps, s, top_level=top_level, seen=seen)
            continue

        # ---- collectives --------------------------------------------------
        matched_coll = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-start"):
                matched_coll = k
                break
        if matched_coll and not op.endswith("-done"):
            b = 0
            for o in instr.operands:
                t = _operand_type(o, comp, symtab)
                if t:
                    b += _type_numel_bytes(t)[1]
            s.collectives[matched_coll]["bytes"] += weight * b
            s.collectives[matched_coll]["count"] += weight
            continue

        # ---- fusion: recurse for compute (bytes handled by the traffic
        # model at the computation level) ------------------------------------
        if op == "fusion":
            cm = _CALLS_RE.search(instr.attrs)
            if cm and cm.group(1) in comps:
                _walk(comps[cm.group(1)], weight, comps, s, top_level=False, seen=seen)
            continue

        # ---- dot ----------------------------------------------------------
        if op == "dot":
            lhs_t = _operand_type(instr.operands[0], comp, symtab) if instr.operands else None
            k = 1
            cm = _CONTRACT_RE.search(instr.attrs)
            if cm and lhs_t:
                dims = _shape_dims(lhs_t)
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
            s.dot_flops += weight * 2.0 * out_numel * k
            continue

        # ---- elementwise / transcendental ---------------------------------
        if op in _TRANSCENDENTAL:
            s.transcendentals += weight * out_numel
            s.vector_ops += weight * out_numel
        elif op in _ELEMENTWISE:
            s.vector_ops += weight * out_numel
        elif op in ("reduce", "reduce-window"):
            in_numel = 0
            for o in instr.operands[: max(1, len(instr.operands) // 2)]:
                t = _operand_type(o, comp, symtab)
                if t:
                    in_numel += _type_numel_bytes(t)[0]
            s.vector_ops += weight * in_numel
        elif op in _FREE or op.endswith("-done"):
            pass
        elif op in ("dynamic-slice", "dynamic-update-slice", "slice", "copy",
                    "transpose", "reshape", "broadcast", "concatenate", "pad",
                    "gather", "scatter", "reverse", "sort", "dynamic-reshape",
                    "cholesky", "triangular-solve", "rng", "map", "select-and-scatter"):
            pass  # data movement: bytes handled by computation_traffic
        else:
            s.unknown_ops[op] = s.unknown_ops.get(op, 0) + 1


if __name__ == "__main__":
    import sys

    text = open(sys.argv[1]).read()
    print(json.dumps(analyze_hlo(text).as_dict(), indent=2))
