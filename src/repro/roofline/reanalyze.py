"""Re-run the loop-aware HLO cost walk over cached dry-run HLO artifacts and
refresh the dryrun JSONs in place — iterating on the traffic/cost model
without recompiling 66 cells.

  PYTHONPATH=src python -m repro.roofline.reanalyze [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from repro.roofline.hlo_cost import analyze_hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    for jpath in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        tag = os.path.basename(jpath)[: -len(".json")]
        hpath = os.path.join(args.dir, "hlo", tag + ".hlo.zst")
        if not os.path.exists(hpath):
            print(f"[skip] no HLO for {tag}")
            continue
        text = zstandard.decompress(open(hpath, "rb").read()).decode()
        walk = analyze_hlo(text)
        d = json.load(open(jpath))
        d["flops"] = walk.flops
        d["dot_flops"] = walk.dot_flops
        d["vector_ops"] = walk.vector_ops
        d["transcendentals"] = walk.transcendentals
        d["hbm_bytes"] = walk.hbm_bytes
        d["collectives"] = {**walk.collectives, "_total_bytes": walk.collective_bytes}
        d["unknown_ops"] = walk.unknown_ops
        with open(jpath, "w") as f:
            json.dump(d, f, indent=2, default=str)
        print(f"[ok] {tag}: flops={walk.flops:.3e} hbm={walk.hbm_bytes:.3e} "
              f"coll={walk.collective_bytes:.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
