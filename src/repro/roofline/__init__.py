"""Roofline analysis: HLO cost walking and performance reports."""
