"""Serving: continuous batching over prefill/decode steps, trace capture
(``serve.trace``) feeding the predict layer, and prediction-guided fleet
placement (``serve.placement``)."""
