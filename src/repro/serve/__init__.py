"""Serving engine: continuous batching over prefill/decode steps."""
