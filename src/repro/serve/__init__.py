"""Serving: continuous batching over prefill/decode steps (mesh-native via
``engine.mesh=``), trace capture (``serve.trace``) feeding the predict
layer, prediction-guided fleet placement (``serve.placement``), and
fleet-scale queueing simulation on top (``serve.fleet``)."""
