"""Serving engine: continuous batching over prefill/decode steps, plus
trace capture (``serve.trace``) feeding the predict layer."""
