"""Serving: continuous batching over prefill/decode steps (mesh-native via
``engine.mesh=``), trace capture (``serve.trace``) feeding the predict
layer, prediction-guided fleet placement (``serve.placement``),
fleet-scale queueing simulation on top (``serve.fleet``), and the drift
control loop (``serve.monitor``): measured-vs-predicted residual
monitoring that re-routes the fleet mid-replay when predictions go stale.
"""
