"""Serve-trace capture: record the kernel-call sequence a serving run
actually executes, in the exact format the predict layer consumes.

The serving engines execute jitted model steps; the decomposer models the
same steps as ``KernelCall``/``CommCall`` sequences (``core.e2e``). A
``TraceRecorder`` attached to an engine bridges the two: every executed
prefill/decode step appends one ``(label, 1.0, model_calls(...))`` group
with the *actual* shapes served (batch, query length, attended KV length),
so after a run

    rec = TraceRecorder()
    eng = ServeEngine(cfg, recorder=rec)
    ... serve ...
    SweepPredictor(hws, estimator=pw).compare(rec.calls())

prices the real workload on every hardware — the measured-vs-predicted
protocol of the paper, driven by a live serving trace instead of a
synthetic request shape.

Recording contract (see docs/predict.md):

  * one group per executed engine step, in execution order;
  * ``B`` is the *launched* batch (the full lock-step slot pool for the
    continuous engine, not just active slots) — kernels are priced at the
    shapes the hardware actually runs;
  * ``kvlen`` is the longest *attended* KV span in the step — the
    decomposer's convention (``request_calls`` prices its Simpson decode
    samples the same way, and causal ``kv_eff`` in ``decompose_attention``
    assumes it), so recorded traces are directly comparable to synthetic
    request estimates and to the hwsim oracle. Note this is the logical
    span: the reference engines' masked decode kernel physically sweeps
    the full padded cache, so comparisons against *this process's*
    wall-clock (rather than the oracle) would need padded-cache pricing;
  * labels are informational only (``prefill[...]``, ``decode@pos``,
    ``admit#rid``, ``tick[...]``); group weights are always 1.0 — a
    recorded step happened exactly once.

The recorder is deliberately cheap: it builds the nested call groups
(plain dataclasses) and never touches device memory.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.core.e2e import model_calls


@dataclasses.dataclass
class TraceRecorder:
    """Accumulates one nested call group per executed engine step."""

    steps: list = dataclasses.field(default_factory=list)

    def record_step(
        self,
        label: str,
        cfg: ArchConfig,
        B: int,
        qlen: int,
        kvlen: int,
        tp: int = 1,
    ) -> None:
        """Record one executed step as the decomposer's call sequence for
        its shapes (all layers + LM head, the ``model_calls`` lowering)."""
        self.steps.append((label, 1.0, model_calls(cfg, B, qlen, kvlen, tp)))

    def record(self, label: str, calls: list) -> None:
        """Record a pre-lowered call group (escape hatch for custom steps,
        e.g. PP boundary traffic an engine adds itself)."""
        self.steps.append((label, 1.0, calls))

    def calls(self) -> list:
        """The recorded trace as one nested call sequence — feed directly
        to ``Predictor.predict`` / ``SweepPredictor.predict``."""
        return list(self.steps)

    def labels(self) -> list:
        return [label for label, _, _ in self.steps]

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def clear(self) -> None:
        self.steps.clear()
