"""Serve-trace capture: record the kernel-call sequence a serving run
actually executes, in the exact format the predict layer consumes.

The serving engines execute jitted model steps; the decomposer models the
same steps as ``KernelCall``/``CommCall`` sequences (``core.e2e``). A
``TraceRecorder`` attached to an engine bridges the two: every executed
prefill/decode step appends one ``(label, 1.0, model_calls(...))`` group
with the *actual* shapes served (batch, query length, attended KV length),
so after a run

    rec = TraceRecorder()
    eng = ServeEngine(cfg, recorder=rec)
    ... serve ...
    SweepPredictor(hws, estimator=pw).compare(rec.calls())

prices the real workload on every hardware — the measured-vs-predicted
protocol of the paper, driven by a live serving trace instead of a
synthetic request shape.

Recording contract (see docs/serving.md):

  * one group per executed engine step, in execution order;
  * ``B`` is the *launched* batch (the full lock-step slot pool for the
    continuous engine, not just active slots) — kernels are priced at the
    shapes the hardware actually runs;
  * ``kvlen`` is the longest *attended* KV span in the step — the
    decomposer's convention (``request_calls`` prices its Simpson decode
    samples the same way, and causal ``kv_eff`` in ``decompose_attention``
    assumes it), so recorded traces are directly comparable to synthetic
    request estimates and to the hwsim oracle. Note this is the logical
    span: the reference engines' masked decode kernel physically sweeps
    the full padded cache, so comparisons against *this process's*
    wall-clock (rather than the oracle) would need padded-cache pricing;
  * labels are informational only (``prefill[...]``, ``decode@pos``,
    ``admit#rid``, ``tick[...]``); group weights are always 1.0 — a
    recorded step happened exactly once;
  * every step additionally carries a :class:`StepMeta` (shape + phase +
    active-sequence count) so downstream consumers — the placement
    layer's split-fleet routing, per-token cost objectives — can classify
    steps without parsing labels.

The recorder is deliberately cheap: it builds the nested call groups
(plain dataclasses) and never touches device memory.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core.e2e import model_calls

#: step phases the placement layer understands; ``"other"`` is the
#: catch-all for pre-lowered escape-hatch steps with no declared phase
PHASES = ("prefill", "decode", "other")


def step_calls(
    cfg: ArchConfig,
    B: int,
    qlen: int,
    kvlen: int,
    tp: int = 1,
    pp: int = 1,
    *,
    pp_schedule: str = "gpipe",
    pp_interleave: int = 2,
    tuned: Optional[dict] = None,
) -> list:
    """Lower one engine step's shapes into the call sequence the recorder
    would record for them: the full ``model_calls`` lowering plus, at
    ``pp > 1``, the schedule's stage-boundary activation traffic.

    This is the single lowering both :meth:`TraceRecorder.record_step` and
    the residual monitor's re-lowering path
    (``repro.serve.monitor.step_predicted_s``) use, which is what makes
    the round-trip exact: re-lowering a recorded :class:`StepMeta`'s
    shapes yields the same calls — hence the same prediction — as the
    group recorded live."""
    calls = model_calls(cfg, B, qlen, kvlen, tp, tuned)
    if pp > 1:
        from repro.core.e2e import pp_boundary_hops
        from repro.predict.api import CommCall

        boundary = pp_boundary_hops(pp, pp_schedule, pp_interleave) * (
            B * cfg.d_model * 2.0
        )
        calls.append(("pp_boundary", 1, [CommCall("p2p", boundary * qlen, 2)]))
    return calls


@dataclasses.dataclass(frozen=True)
class StepMeta:
    """Shape + scheduling metadata of one recorded engine step.

    ``B``/``qlen``/``kvlen`` are the *launched* shapes (padded batch,
    attended KV span — the recording contract above); ``active`` is how
    many of the ``B`` rows belong to live requests (== ``B`` for the
    simple batch engine, the in-flight count for the continuous engine's
    lock-step ticks). A decode step therefore generated ``active`` tokens.
    ``tp``/``pp`` are the parallel degrees the step was *recorded at*
    (the recorder's declared mesh — see :class:`TraceRecorder`).
    """

    label: str
    phase: str  # one of PHASES
    B: int
    qlen: int
    kvlen: int
    active: int
    #: resolved at record time: the engine's mesh degrees when the
    #: recorder is bound to a mesh-native engine, else the declared ones
    tp: int = 1
    pp: int = 1
    #: wall-clock seconds the step actually took, stamped by the engine
    #: via :meth:`TraceRecorder.mark_measured` (0.0 = not measured).
    #: Measured steps are the residual monitor's observations
    #: (``repro.serve.monitor.trace_residuals``).
    measured_s: float = 0.0


@dataclasses.dataclass
class TraceRecorder:
    """Accumulates one nested call group per executed engine step, plus a
    parallel :class:`StepMeta` per step (``meta``).

    The parallel degrees a trace is *priced at* come from the engine it is
    attached to: an engine constructed with ``mesh=`` calls
    :meth:`bind_mesh` with its mesh's "model"/"pipe" axis sizes, and every
    recorded step lowers at those degrees — the trace then carries the TP
    all-reduces/all-gathers, the MoE expert-parallel dispatch/combine
    all-to-alls (byte-exact — ``core.e2e.layer_calls``) and the PP
    stage-boundary activations of the mesh the engine actually runs on.
    Recorded traces therefore price collective costs through
    ``SweepPredictor``/``FleetRouter`` exactly like synthetic
    ``request_calls`` do.

    Caller-declared degrees (``TraceRecorder(tp=4, pp=2)``) are kept as a
    *deprecation shim* for pricing a single-process run at a hypothetical
    mesh; they apply only when no engine mesh is bound. When a declared
    degree conflicts with a bound mesh, the mesh wins and a
    ``DeprecationWarning`` is raised — the engine's reality is
    authoritative. A per-step ``tp=`` argument to :meth:`record_step`
    overrides both."""

    steps: list = dataclasses.field(default_factory=list)
    meta: list = dataclasses.field(default_factory=list)
    #: declared degrees (deprecation shim); ``None`` = inherit from the
    #: engine's mesh (1 when the engine has none)
    tp: Optional[int] = None
    pp: Optional[int] = None
    #: pipeline schedule the PP boundary traffic is recorded for
    pp_schedule: str = "gpipe"
    pp_interleave: int = 2
    #: autotuned kernel block table (``repro.tune.TunedConfigs.for_hw(hw)``:
    #: kernel family -> block kwargs); recorded steps lower with these
    #: blocks merged into matching kernel calls, so the trace prices the
    #: tuned engine, not the default one
    tuned: Optional[dict] = None
    _mesh_tp: Optional[int] = dataclasses.field(default=None, init=False, repr=False)
    _mesh_pp: Optional[int] = dataclasses.field(default=None, init=False, repr=False)

    def bind_mesh(self, tp: int, pp: int = 1) -> None:
        """Bind the recorder to an engine's actual mesh degrees. Called by
        engines constructed with ``mesh=``; callers never need to. Bound
        degrees are authoritative: a conflicting declared ``tp=``/``pp=``
        raises a ``DeprecationWarning`` and loses."""
        if (self.tp not in (None, tp)) or (self.pp not in (None, pp)):
            warnings.warn(
                f"TraceRecorder declared tp={self.tp}/pp={self.pp} but the "
                f"engine's mesh runs tp={tp}/pp={pp}; the mesh wins. "
                "Declared degrees are deprecated for mesh-native engines — "
                "drop them and let the recorder inherit from the engine.",
                DeprecationWarning,
                stacklevel=3,
            )
        self._mesh_tp, self._mesh_pp = int(tp), int(pp)

    @property
    def resolved_tp(self) -> int:
        """The TP degree steps record at: engine-mesh bound > declared > 1."""
        if self._mesh_tp is not None:
            return self._mesh_tp
        return 1 if self.tp is None else self.tp

    @property
    def resolved_pp(self) -> int:
        if self._mesh_pp is not None:
            return self._mesh_pp
        return 1 if self.pp is None else self.pp

    def record_step(
        self,
        label: str,
        cfg: ArchConfig,
        B: int,
        qlen: int,
        kvlen: int,
        tp: Optional[int] = None,
        *,
        phase: Optional[str] = None,
        active: Optional[int] = None,
    ) -> None:
        """Record one executed step as the decomposer's call sequence for
        its shapes (all layers + LM head, the ``model_calls`` lowering),
        at the recorder's resolved parallel degrees (``tp`` overrides).

        ``phase`` defaults to the shape heuristic ``qlen > 1 -> prefill``;
        engines should pass it explicitly (a 1-token-prompt admission is
        still a prefill). ``active`` defaults to ``B``. When the resolved
        ``pp > 1`` the step additionally carries its stage-boundary
        activation traffic (``qlen`` tokens across the schedule's boundary
        hops — the same convention as ``request_calls``)."""
        if phase is None:
            phase = "prefill" if qlen > 1 else "decode"
        if phase not in PHASES:
            raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
        tp = self.resolved_tp if tp is None else tp
        pp = self.resolved_pp
        calls = step_calls(cfg, B, qlen, kvlen, tp, pp,
                           pp_schedule=self.pp_schedule,
                           pp_interleave=self.pp_interleave, tuned=self.tuned)
        self.steps.append((label, 1.0, calls))
        self.meta.append(
            StepMeta(label, phase, B, qlen, kvlen,
                     B if active is None else active, tp, pp)
        )

    def record(self, label: str, calls: list, *, phase: str = "other") -> None:
        """Record a pre-lowered call group (escape hatch for custom steps,
        e.g. PP boundary traffic an engine adds itself). Shapes are
        unknown, so the meta row carries zeros and phase ``"other"``
        unless declared."""
        if phase not in PHASES:
            raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
        self.steps.append((label, 1.0, calls))
        self.meta.append(StepMeta(label, phase, 0, 0, 0, 0))

    def mark_measured(self, seconds: float) -> None:
        """Stamp the most recently recorded step with its measured
        wall-clock (engines call this right after timing the step; the
        pairing of measured seconds with the step's predicted calls is
        what the residual monitor consumes). No-op refinements are
        rejected: there must be a step to stamp."""
        if not self.meta:
            raise RuntimeError("mark_measured with no recorded step")
        if not seconds >= 0:
            raise ValueError(f"measured seconds must be >= 0, got {seconds}")
        self.meta[-1] = dataclasses.replace(self.meta[-1], measured_s=float(seconds))

    def calls(self) -> list:
        """The recorded trace as one nested call sequence — feed directly
        to ``Predictor.predict`` / ``SweepPredictor.predict``."""
        return list(self.steps)

    def labels(self) -> list:
        return [label for label, _, _ in self.steps]

    def phases(self) -> list:
        """Per-step phase tags, parallel to ``labels()``."""
        return [m.phase for m in self.meta]

    def split_calls(self) -> dict:
        """The trace partitioned by phase: ``{"prefill": [...steps...],
        "decode": [...]}`` (phases with no steps are omitted). Each value
        is a valid call sequence — this is the input shape
        ``FleetRouter.route_split`` consumes to place workload classes on
        different hardware."""
        out: dict = {}
        for step, m in zip(self.steps, self.meta):
            out.setdefault(m.phase, []).append(step)
        return out

    @property
    def decode_tokens(self) -> int:
        """Tokens generated by the recorded *decode* steps only (sum of
        active rows per decode tick). Each prefill also samples one token
        per active row, so the total output is :attr:`generated_tokens`."""
        return sum(m.active for m in self.meta if m.phase == "decode")

    @property
    def prefill_tokens(self) -> int:
        """First tokens sampled from recorded prefill steps (one per
        active row of each prefill/admission)."""
        return sum(m.active for m in self.meta if m.phase == "prefill")

    @property
    def generated_tokens(self) -> int:
        """Every token the recorded run produced: prefill-sampled first
        tokens plus decode-tick tokens. For a full request of ``lout``
        output tokens this matches the synthetic ``B * lout`` convention
        of ``place_request`` — the ``n_tokens`` per-token cost objectives
        should use."""
        return self.prefill_tokens + self.decode_tokens

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def clear(self) -> None:
        self.steps.clear()
        self.meta.clear()
