"""Fleet-scale queueing simulation: replay large request streams through a
``FleetRouter`` placement with real queueing delay.

The placement layer prices a workload in isolation — one request, empty
fleet. Real serving latency is dominated by *waiting*: requests arrive in
bursts, replicas are busy, queues build. :class:`FleetSimulator` closes
that gap with a discrete-event simulation on top of the predict stack:

  * each :class:`WorkloadClass` (a named request shape: cfg, B, lin, lout,
    parallel degrees, mix weight) is lowered to its ``request_calls``
    sequence and routed through a shared :class:`FleetRouter` pass
    (``route_many`` — one warmed ``FeatureCache`` across classes). The
    class's *service time* on its assigned hardware is the placement row's
    ``total_s`` (PP bubble surcharge included) — the ``SweepPredictor``
    path end to end;
  * :meth:`FleetSimulator.replay` then streams arrivals (Poisson via
    :func:`poisson_arrivals`, or recorded timestamps) through per-hardware
    FIFO replica pools (:func:`simulate_queue`) and reports queue-aware
    fleet metrics: p50/p95/p99/mean latency, waiting time and utilization
    per hardware (:class:`FleetReport`);
  * an optional :class:`AutoscalePolicy` adjusts each pool's replica count
    at fixed arrival-rate windows — the predicted-autoscaling hook:
    desired replicas = arrival rate x predicted service time / target
    utilization.

Exactness anchors (gated in ``benchmarks/bench_fleet.py --smoke``): a
request entering an empty fleet waits zero, so its simulated latency *is*
the isolated placement estimate (bit-for-bit — the simulator adds queueing
on top of the predict path, it never re-derives service times); and p95
latency is monotone in arrival rate under common random numbers (same
seed, arrival times scaled by 1/rate).

The simulator is pure host-side Python/NumPy over predicted seconds — it
never touches device memory, so replaying 1e5–1e6 requests takes seconds.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.e2e import pp_bubble, request_calls
from repro.predict.sweep import check_prebuilt_exclusive
from repro.serve.monitor import drift_factor, resolve_drift
from repro.serve.placement import FleetRouter, Placement


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One request shape in the traffic mix: the synthetic-request
    convention of ``place_request`` (``B`` sequences of ``lin`` prompt +
    ``lout`` output tokens at the given parallel degrees), plus a mix
    ``weight`` — the relative share of arrivals drawn from this class."""

    name: str
    cfg: ArchConfig
    B: int = 1
    lin: int = 128
    lout: int = 16
    tp: int = 1
    pp: int = 1
    pp_schedule: str = "gpipe"
    pp_microbatches: Optional[int] = None
    pp_interleave: int = 2
    weight: float = 1.0

    def calls(self) -> list:
        return request_calls(
            self.cfg, self.B, self.lin, self.lout, tp=self.tp, pp=self.pp,
            pp_schedule=self.pp_schedule, pp_interleave=self.pp_interleave,
        )

    def bubble(self) -> float:
        return pp_bubble(self.pp, self.pp_microbatches, self.pp_schedule,
                         self.pp_interleave)

    @property
    def n_tokens(self) -> int:
        return self.B * self.lout


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Predicted autoscaling: at every ``window_s`` boundary, size the
    replica pool to the window's observed arrival rate —

        desired = ceil(rate x mean predicted service / target_utilization)

    clipped to ``[min_replicas, max_replicas]``. Service times are the
    predict path's, so the policy scales on *predicted* load, before
    queues actually build (the fleet analogue of predicted admission)."""

    window_s: float
    target_utilization: float = 0.7
    min_replicas: int = 1
    max_replicas: int = 64


def poisson_arrivals(rate_rps: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` Poisson arrival times (seconds, sorted) at ``rate_rps``.

    Uses one exponential draw per gap under a fixed seed, so two streams
    at different rates with the same seed are *scaled copies* of each
    other — the common-random-numbers construction that makes simulated
    latency percentiles monotone in arrival rate."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def simulate_queue(
    arrivals: np.ndarray,
    service_s: np.ndarray,
    replicas: int = 1,
    autoscale: Optional[AutoscalePolicy] = None,
):
    """FIFO multi-replica queue: each request starts on the
    earliest-free replica, no earlier than its arrival.

    Returns ``(starts, trajectory, capacity_s)``: per-request service
    start times, the replica-count trajectory ``[(t, n), ...]`` (constant
    ``[(0, replicas)]`` without autoscaling), and the integrated capacity
    ``sum(n x dt)`` up to the last completion — the denominator of
    utilization. O(n log replicas) via a heap of replica-free times.

    With ``autoscale``, the pool is resized at every ``window_s`` boundary
    from the previous window's arrival rate and mean service time;
    shrinking retires the earliest-free replicas first.
    """
    arrivals = np.asarray(arrivals, float)
    service_s = np.asarray(service_s, float)
    n = len(arrivals)
    starts = np.empty(n, float)
    free = [0.0] * int(replicas)  # next-free time per replica
    heapq.heapify(free)
    traj = [(0.0, len(free))]

    boundary = autoscale.window_s if autoscale is not None else math.inf
    win_count, win_service = 0, 0.0
    for i in range(n):
        a = arrivals[i]
        while a >= boundary:  # autoscale only; inf never triggers
            rate = win_count / autoscale.window_s
            mean_svc = win_service / win_count if win_count else 0.0
            desired = max(
                autoscale.min_replicas,
                min(
                    autoscale.max_replicas,
                    math.ceil(rate * mean_svc / autoscale.target_utilization)
                    if win_count
                    else autoscale.min_replicas,
                ),
            )
            while len(free) < desired:
                heapq.heappush(free, boundary)
            while len(free) > desired:
                heapq.heappop(free)
            traj.append((boundary, len(free)))
            win_count, win_service = 0, 0.0
            boundary += autoscale.window_s
        win_count += 1
        win_service += service_s[i]
        t = heapq.heappop(free)
        start = a if a >= t else t
        starts[i] = start
        heapq.heappush(free, start + service_s[i])

    horizon = max(free) if n else 0.0  # last completion across replicas
    capacity = 0.0
    for (t0, c), (t1, _) in zip(traj, traj[1:] + [(horizon, 0)]):
        capacity += c * max(min(t1, horizon) - t0, 0.0)
    return starts, traj, capacity


@dataclasses.dataclass
class HardwareLoad:
    """Queue-aware serving metrics of one hardware pool in the fleet."""

    hw: str
    classes: list  # workload-class names routed here
    n_requests: int
    replicas: int  # initial pool size
    final_replicas: int
    latency_p50_s: float
    latency_p95_s: float
    latency_p99_s: float
    latency_mean_s: float
    wait_mean_s: float
    utilization: float  # busy seconds / integrated capacity
    busy_s: float
    replica_traj: list  # [(t, n), ...]


@dataclasses.dataclass(frozen=True)
class RerouteEvent:
    """One mid-replay re-route of the drift control loop: the monitor
    tripped on request ``index`` (completion time ``t``), the tripping
    ``(cls, hw)`` key's EWMA residual deviated by ``deviation``, and the
    fleet was re-routed under the per-hw ``corrections`` (cumulative
    residual factors) — ``old_assignment`` -> ``new_assignment``."""

    index: int  # arrival-order index of the tripping request
    t: float  # completion time of the tripping request (sim seconds)
    cls: str  # workload class whose residual tripped
    hw: str  # hardware the tripping residual was measured on
    deviation: float  # |ewma residual - 1| at trip time
    corrections: dict  # hw -> correction factor applied at this re-route
    old_assignment: dict  # class -> hw before
    new_assignment: dict  # class -> hw after

    @property
    def changed(self) -> bool:
        """True when the re-route actually moved at least one class."""
        return self.old_assignment != self.new_assignment


@dataclasses.dataclass
class FleetReport:
    """One replayed stream's fleet metrics. ``latencies`` is the raw
    per-request latency array (arrival to completion, predicted seconds on
    the assigned hardware) for downstream analysis. ``reroutes`` is the
    drift control loop's re-route log (empty without ``monitor=``, and for
    any replay where no sustained drift tripped); ``assignment`` is the
    assignment in effect at the *end* of the replay — it differs from the
    simulator's frozen one exactly when a logged re-route changed it."""

    assignment: dict  # class name -> hw name
    per_hw: dict  # hw name -> HardwareLoad
    n_requests: int
    horizon_s: float  # last completion
    latency_p50_s: float
    latency_p95_s: float
    latency_p99_s: float
    latency_mean_s: float
    latencies: np.ndarray = dataclasses.field(repr=False, default=None)
    #: RerouteEvent log, in trip order (drift control loop)
    reroutes: list = dataclasses.field(default_factory=list)

    def table(self) -> str:
        lines = [
            f"{'hardware':<14} {'classes':<18} {'reqs':>8} {'repl':>5} "
            f"{'util':>6} {'p50':>10} {'p95':>10} {'p99':>10}"
        ]
        for hw, load in sorted(self.per_hw.items()):
            repl = (
                str(load.replicas)
                if load.final_replicas == load.replicas
                else f"{load.replicas}->{load.final_replicas}"
            )
            lines.append(
                f"{hw:<14} {','.join(load.classes):<18} {load.n_requests:>8} "
                f"{repl:>5} {load.utilization:>6.1%} "
                f"{load.latency_p50_s*1e3:>8.2f}ms {load.latency_p95_s*1e3:>8.2f}ms "
                f"{load.latency_p99_s*1e3:>8.2f}ms"
            )
        return "\n".join(lines)


class FleetSimulator:
    """Replay request streams through a routed fleet with queueing delay.

    Construction routes every workload class (``route_many`` on one shared
    router/cache) and freezes the assignment + per-class service times;
    :meth:`replay` is then pure host-side simulation — price once, replay
    many streams. ``replicas`` is an int (every pool) or a ``{hw: int}``
    mapping; ``autoscale`` (an :class:`AutoscalePolicy`) applies to every
    pool and can be overridden per replay."""

    def __init__(
        self,
        classes,
        *,
        router: Optional[FleetRouter] = None,
        hws=None,
        backend: str = "synperf",
        objective="latency",
        replicas=1,
        autoscale: Optional[AutoscalePolicy] = None,
        **backend_kw,
    ):
        if isinstance(classes, WorkloadClass):
            classes = [classes]
        if not classes:
            raise ValueError("FleetSimulator needs at least one WorkloadClass")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate workload-class names: {names}")
        self.classes = list(classes)
        check_prebuilt_exclusive("router", router, hws, backend, backend_kw)
        self.router = router if router is not None else FleetRouter(hws, backend, **backend_kw)
        # routing inputs are kept so the drift control loop can re-run
        # route_many mid-replay under residual-corrected service times
        self._objective = objective
        self._named_calls = {c.name: c.calls() for c in self.classes}
        self._n_tokens = {c.name: c.n_tokens for c in self.classes}
        self._scales = {c.name: c.bubble() for c in self.classes}
        #: class name -> Placement (full fleet ranking per class)
        self.placements: dict = self.router.route_many(
            self._named_calls,
            objective=objective,
            n_tokens=self._n_tokens,
            scales=self._scales,
        )
        #: class name -> assigned hardware (the placement's best entry)
        self.assignment = {name: p.best for name, p in self.placements.items()}
        pools = sorted(set(self.assignment.values()))
        # pools a re-route newly sends traffic to get this default size
        self._default_replicas = 1 if isinstance(replicas, dict) else int(replicas)
        self.replicas = (
            dict(replicas) if isinstance(replicas, dict)
            else {hw: int(replicas) for hw in pools}
        )
        self.autoscale = autoscale

    def pool_size(self, hw: str) -> int:
        """Replica count of one hardware pool (hardware the frozen
        assignment never used falls back to the scalar ``replicas=``
        default — a re-route can move traffic onto it)."""
        return self.replicas.get(hw, self._default_replicas)

    def service_s(self, cls_name: str, hw: Optional[str] = None) -> float:
        """Predicted isolated service time of one class on ``hw`` (its
        assigned hardware by default) — the placement row's ``total_s``."""
        return self.placements[cls_name][hw or self.assignment[cls_name]].total_s

    def saturation_rate_rps(self) -> float:
        """The total arrival rate at which the busiest pool reaches
        utilization 1 under the class mix — rates for an experiment are
        naturally expressed as fractions of this."""
        total_w = sum(c.weight for c in self.classes)
        load_per_rate: dict = {}
        for c in self.classes:
            hw = self.assignment[c.name]
            load_per_rate[hw] = load_per_rate.get(hw, 0.0) + (
                c.weight / total_w
            ) * self.service_s(c.name)
        return min(
            self.replicas[hw] / load for hw, load in load_per_rate.items()
        )

    def replay(
        self,
        arrivals=None,
        *,
        rate_rps: Optional[float] = None,
        n_requests: Optional[int] = None,
        seed: int = 0,
        class_ids=None,
        autoscale: Optional[AutoscalePolicy] = None,
        drift=None,
        monitor=None,
    ) -> FleetReport:
        """Replay one request stream and report queue-aware fleet metrics.

        Either pass recorded ``arrivals`` (seconds, any order — sorted
        internally) or ``rate_rps`` + ``n_requests`` for a Poisson stream.
        ``class_ids`` optionally pins each request's workload class (index
        into ``self.classes``); by default classes are drawn by weight
        under ``seed``.

        Drift control loop: ``drift=`` injects measured-vs-predicted drift
        (a ``serve.monitor.DriftSpec``, a list of them, or a ``{hw:
        factor}`` step shorthand) by multiplying the *true* service times
        on the drifted hardware while predictions stay frozen; ``monitor=``
        (a ``serve.monitor.ResidualMonitor``) observes every completion's
        measured-vs-predicted residual and, on a sustained trip, re-runs
        ``route_many`` under residual-corrected service times mid-replay —
        the fleet re-balances and the report's ``reroutes`` log says when
        and how. Either argument switches to the event-by-event control
        path; autoscaling composes with it (each pool resizes at its
        window boundaries from the window's *measured* rate and service
        times, the same rule as :func:`simulate_queue` — which prices
        drifted hardware at its drifted load, not the frozen prediction).
        With both ``None`` the vectorized frozen-assignment path is
        bit-identical to before."""
        if arrivals is None:
            if rate_rps is None or n_requests is None:
                raise ValueError(
                    "replay needs arrivals= (recorded) or rate_rps= + "
                    "n_requests= (Poisson)"
                )
            arrivals = poisson_arrivals(rate_rps, n_requests, seed)
        arrivals = np.sort(np.asarray(arrivals, float))
        n = len(arrivals)
        if class_ids is None:
            w = np.asarray([c.weight for c in self.classes], float)
            class_ids = np.random.default_rng(seed + 1).choice(
                len(self.classes), size=n, p=w / w.sum()
            )
        class_ids = np.asarray(class_ids)
        policy = self.autoscale if autoscale is None else autoscale
        if drift is not None or monitor is not None:
            return self._replay_controlled(
                arrivals, class_ids, drift, monitor, policy
            )
        svc_by_class = np.asarray(
            [self.service_s(c.name) for c in self.classes], float
        )
        svc = svc_by_class[class_ids]

        latencies = np.empty(n, float)
        per_hw: dict = {}
        horizon = 0.0
        hw_of_class = [self.assignment[c.name] for c in self.classes]
        for hw in sorted(set(hw_of_class)):
            cls_idx = [i for i, h in enumerate(hw_of_class) if h == hw]
            mask = np.isin(class_ids, cls_idx)
            if not mask.any():
                continue
            a, s = arrivals[mask], svc[mask]
            starts, traj, capacity = simulate_queue(
                a, s, self.replicas[hw], policy
            )
            lat = starts + s - a
            latencies[mask] = lat
            horizon = max(horizon, float((starts + s).max()))
            per_hw[hw] = HardwareLoad(
                hw=hw,
                classes=[self.classes[i].name for i in cls_idx],
                n_requests=int(mask.sum()),
                replicas=self.replicas[hw],
                final_replicas=traj[-1][1],
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)),
                latency_p99_s=float(np.percentile(lat, 99)),
                latency_mean_s=float(lat.mean()),
                wait_mean_s=float((starts - a).mean()),
                utilization=float(s.sum() / capacity) if capacity > 0 else 0.0,
                busy_s=float(s.sum()),
                replica_traj=traj,
            )
        return FleetReport(
            assignment=dict(self.assignment),
            per_hw=per_hw,
            n_requests=n,
            horizon_s=horizon,
            latency_p50_s=float(np.percentile(latencies, 50)),
            latency_p95_s=float(np.percentile(latencies, 95)),
            latency_p99_s=float(np.percentile(latencies, 99)),
            latency_mean_s=float(latencies.mean()),
            latencies=latencies,
        )

    # ------------------------------------------------------------------
    # drift control loop

    def _replay_controlled(
        self, arrivals, class_ids, drift, monitor, autoscale=None
    ) -> FleetReport:
        """Event-by-event replay with drift injection and/or residual
        monitoring (the production control loop, simulated).

        Per completion: the *measured* service time is the placement row's
        ``total_s`` times the injected drift factor at arrival time; the
        *predicted* one is the row's ``total_s`` times the cumulative
        correction already applied to that hardware (1.0 until a trip).
        The monitor observes that pair; when it trips, the fleet is
        re-routed under ``ResidualCorrectedObjective`` with the cumulative
        per-hw corrections, the event is logged, and the monitor resets —
        its history measured the *old* baseline. Without drift and with a
        quiet monitor this path reproduces the vectorized frozen replay
        exactly (same per-hw FIFO heaps, same arithmetic).

        ``autoscale`` (an :class:`AutoscalePolicy`) composes with the
        control loop: each pool tracks its own window boundaries on the
        absolute clock and resizes from the previous window's arrival
        rate and mean *measured* service time — the same resize rule as
        :func:`simulate_queue` (where measured == predicted, since that
        path has no drift), so drifted hardware is scaled for the load it
        actually serves."""
        from repro.predict.objective import (
            ResidualCorrectedObjective,
            get_objective,
        )

        specs = resolve_drift(drift)
        for hw in specs:
            known = {r.hw for p in self.placements.values() for r in p.rows}
            if hw not in known:
                raise ValueError(
                    f"drift names hardware {hw!r} that no placement prices; "
                    f"priceable: {sorted(known)}"
                )
        base_obj = get_objective(self._objective)
        assignment = dict(self.assignment)
        cum_corr: dict = {}  # hw -> cumulative correction factor applied
        reroutes: list = []
        n = len(arrivals)
        latencies = np.empty(n, float)
        pools: dict = {}  # hw -> heap of replica next-free times
        # per-hw accumulators for the report
        acc: dict = {}  # hw -> dict(lat=[], wait=[], busy=0.0, classes=set)
        # per-hw autoscale state: next window boundary, window arrival
        # count / measured-service sum, replica trajectory
        boundary: dict = {}  # hw -> next resize time
        win_count: dict = {}
        win_service: dict = {}
        traj: dict = {}  # hw -> [(t, n), ...]

        for i in range(n):
            a = float(arrivals[i])
            c = self.classes[int(class_ids[i])]
            hw = assignment[c.name]
            pool = pools.get(hw)
            if pool is None:
                pool = [0.0] * self.pool_size(hw)
                heapq.heapify(pool)
                pools[hw] = pool
                traj[hw] = [(0.0, len(pool))]
                if autoscale is not None:
                    boundary[hw] = autoscale.window_s
                    win_count[hw], win_service[hw] = 0, 0.0
            while autoscale is not None and a >= boundary[hw]:
                b = boundary[hw]
                rate = win_count[hw] / autoscale.window_s
                mean_svc = (
                    win_service[hw] / win_count[hw] if win_count[hw] else 0.0
                )
                desired = max(
                    autoscale.min_replicas,
                    min(
                        autoscale.max_replicas,
                        math.ceil(
                            rate * mean_svc / autoscale.target_utilization
                        )
                        if win_count[hw]
                        else autoscale.min_replicas,
                    ),
                )
                while len(pool) < desired:
                    heapq.heappush(pool, b)
                while len(pool) > desired:
                    heapq.heappop(pool)
                traj[hw].append((b, len(pool)))
                win_count[hw], win_service[hw] = 0, 0.0
                boundary[hw] = b + autoscale.window_s
            base = self.placements[c.name][hw].total_s
            measured = base * drift_factor(specs, hw, a)
            predicted = base * cum_corr.get(hw, 1.0)
            if autoscale is not None:
                win_count[hw] += 1
                win_service[hw] += measured
            t_free = heapq.heappop(pool)
            start = a if a >= t_free else t_free
            done = start + measured
            heapq.heappush(pool, done)
            latencies[i] = done - a
            st = acc.get(hw)
            if st is None:
                st = acc[hw] = {"lat": [], "wait": [], "busy": 0.0,
                                "classes": set()}
            st["lat"].append(done - a)
            st["wait"].append(start - a)
            st["busy"] += measured
            st["classes"].add(c.name)
            if monitor is None:
                continue
            event = monitor.observe(c.name, hw, measured, predicted, t=done)
            if event is None:
                continue
            # sustained drift: fold the monitor's per-hw corrections into
            # the cumulative ones (they are residuals *of the corrected
            # predictions*, so composition is multiplicative), re-route,
            # and reset the monitor against the new baseline
            step_corr = monitor.corrections()
            for h, f in step_corr.items():
                cum_corr[h] = cum_corr.get(h, 1.0) * f
            corrected = self.router.route_many(
                self._named_calls,
                objective=ResidualCorrectedObjective(base_obj, dict(cum_corr)),
                n_tokens=self._n_tokens,
                scales=self._scales,
            )
            new_assignment = {name: p.best for name, p in corrected.items()}
            reroutes.append(
                RerouteEvent(
                    index=i, t=done, cls=event.cls, hw=event.hw,
                    deviation=event.deviation, corrections=dict(step_corr),
                    old_assignment=dict(assignment),
                    new_assignment=dict(new_assignment),
                )
            )
            assignment = new_assignment
            monitor.reset()

        per_hw: dict = {}
        horizon = 0.0
        for hw, st in acc.items():
            lat = np.asarray(st["lat"], float)
            wait = np.asarray(st["wait"], float)
            size = self.pool_size(hw)
            hw_last = float(max(pools[hw]))  # last completion on this pool
            horizon = max(horizon, hw_last)
            # integrated capacity over the replica trajectory (constant
            # [(0, size)] without autoscaling -> size * hw_last, as before)
            hw_traj = traj[hw]
            capacity = 0.0
            for (t0, cnt), (t1, _) in zip(hw_traj, hw_traj[1:] + [(hw_last, 0)]):
                capacity += cnt * max(min(t1, hw_last) - t0, 0.0)
            per_hw[hw] = HardwareLoad(
                hw=hw,
                classes=sorted(st["classes"]),
                n_requests=len(lat),
                replicas=size,
                final_replicas=len(pools[hw]),
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)),
                latency_p99_s=float(np.percentile(lat, 99)),
                latency_mean_s=float(lat.mean()),
                wait_mean_s=float(wait.mean()),
                utilization=float(st["busy"] / capacity) if capacity > 0 else 0.0,
                busy_s=float(st["busy"]),
                replica_traj=hw_traj,
            )
        return FleetReport(
            assignment=assignment,
            per_hw=per_hw,
            n_requests=n,
            horizon_s=horizon,
            latency_p50_s=float(np.percentile(latencies, 50)),
            latency_p95_s=float(np.percentile(latencies, 95)),
            latency_p99_s=float(np.percentile(latencies, 99)),
            latency_mean_s=float(latencies.mean()),
            latencies=latencies,
            reroutes=reroutes,
        )
