"""Residual monitoring: detect sustained measured-vs-predicted drift and
drive fleet re-routing (the ROADMAP's "drift-driven re-routing" control
loop; Zhang et al.'s fine-grained distributed-LLM model, arXiv 2509.22832,
is the reference for which multi-node terms dominate at fleet scale, and
PipeWeave's frozen-at-fit-time accuracy is the baseline this loop beats).

The predict stack prices a workload once; a live fleet then drifts —
thermals, contention, a quietly degraded link — and placements made on the
stale numbers stop being optimal. A :class:`ResidualMonitor` closes that
gap:

  * every completed unit of work contributes one *residual* observation,
    the ratio ``measured_s / predicted_s`` for its ``(workload class,
    hardware)`` key — from the fleet simulator's completions, from a
    :class:`~repro.serve.trace.TraceRecorder`'s per-step wall-clock
    (``StepMeta.measured_s``), or from engine ``Result.latency_s``;
  * per key, the monitor keeps an EWMA of the residual ratio over a
    sliding window (``window`` is the EWMA span: ``alpha = 2/(window+1)``,
    seeded with the first sample so an all-identical stream's EWMA is that
    value *exactly*; the last ``window`` raw residuals are kept for
    inspection);
  * a drift trips only when the EWMA's deviation ``|ewma - 1|`` stays
    ``>= threshold`` for ``sustain`` *consecutive* observations (after at
    least ``min_samples`` have been seen) — a single noisy spike moves
    the EWMA by at most ``alpha`` of itself and resets the streak, so
    transient noise never triggers a re-route;
  * on a trip, :meth:`ResidualMonitor.corrections` is the per-hardware
    residual factor to rescale predictions with —
    ``FleetSimulator.replay(monitor=...)`` re-runs ``route_many`` under a
    :class:`~repro.predict.objective.ResidualCorrectedObjective` built
    from it, logs a ``RerouteEvent``, and resets the monitor against the
    corrected baseline (so a step drift re-routes exactly once: after
    correction the residual returns to 1).

Drift *injection* lives here too: a :class:`DriftSpec` multiplies one
hardware's true service times (step or linear ramp), which makes the whole
loop testable end to end — inject a step, watch the monitor trip, check
the re-route log (``benchmarks/bench_fleet.py --smoke`` gates exactly
this; ``tests/test_fleet_properties.py`` holds the property bounds).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

#: default EWMA span (observations) — roughly "how much history matters"
DEFAULT_WINDOW = 64
#: default relative deviation of the EWMA ratio that counts as drift
DEFAULT_THRESHOLD = 0.25
#: default number of consecutive over-threshold observations to trip
DEFAULT_SUSTAIN = 8


# ----------------------------------------------------------------------
# drift injection
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """A multiplier on one hardware's *true* service times.

    ``mode="step"`` jumps from 1.0 to ``factor`` at ``t_start``;
    ``mode="ramp"`` rises linearly from 1.0 at ``t_start`` to ``factor``
    at ``t_end`` and holds. Factors below 1.0 model a *speedup* drift
    (e.g. a contention source going away) and are equally detectable —
    the monitor trips on ``|ewma - 1|``, not on slowdowns only."""

    hw: str
    factor: float
    t_start: float = 0.0
    mode: str = "step"  # "step" | "ramp"
    t_end: Optional[float] = None  # required for mode="ramp"

    def __post_init__(self) -> None:
        if self.factor <= 0 or not math.isfinite(self.factor):
            raise ValueError(f"drift factor must be finite and > 0, got {self.factor}")
        if self.mode not in ("step", "ramp"):
            raise ValueError(f"drift mode must be 'step' or 'ramp', got {self.mode!r}")
        if self.mode == "ramp":
            if self.t_end is None or self.t_end <= self.t_start:
                raise ValueError(
                    f"ramp drift needs t_end > t_start, got t_start={self.t_start} "
                    f"t_end={self.t_end}"
                )

    def factor_at(self, t: float) -> float:
        """The multiplier in effect at simulation time ``t``."""
        if t < self.t_start:
            return 1.0
        if self.mode == "step" or t >= self.t_end:
            return self.factor
        frac = (t - self.t_start) / (self.t_end - self.t_start)
        return 1.0 + (self.factor - 1.0) * frac


def resolve_drift(drift) -> dict:
    """Normalize a replay's ``drift=`` argument to ``{hw: [DriftSpec]}``.

    Accepts ``None``, one :class:`DriftSpec`, an iterable of them, or the
    shorthand ``{hw: factor}`` (a step at t=0 per entry)."""
    if drift is None:
        return {}
    if isinstance(drift, DriftSpec):
        drift = [drift]
    if isinstance(drift, dict):
        drift = [DriftSpec(hw=h, factor=f) for h, f in drift.items()]
    out: dict = {}
    for spec in drift:
        if not isinstance(spec, DriftSpec):
            raise TypeError(
                "drift= takes a DriftSpec, a list of them, or a {hw: factor} "
                f"mapping; got element {spec!r}"
            )
        out.setdefault(spec.hw, []).append(spec)
    return out


def drift_factor(specs_by_hw: dict, hw: str, t: float) -> float:
    """Combined (multiplicative) drift factor on ``hw`` at time ``t``."""
    f = 1.0
    for spec in specs_by_hw.get(hw, ()):
        f *= spec.factor_at(t)
    return f


# ----------------------------------------------------------------------
# residual observations
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Residual:
    """One measured-vs-predicted observation."""

    t: float
    cls: str
    hw: str
    measured_s: float
    predicted_s: float
    label: str = ""

    @property
    def ratio(self) -> float:
        return self.measured_s / self.predicted_s


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """A sustained-drift trip: the EWMA residual of ``(cls, hw)`` stayed
    over threshold for the configured streak. ``ewma`` is the residual
    *ratio* at trip time — the correction factor for this key."""

    t: float
    cls: str
    hw: str
    ewma: float
    deviation: float  # |ewma - 1| at trip time
    n_samples: int  # total observations of the key so far


@dataclasses.dataclass
class _KeyState:
    ewma: float = 0.0
    n: int = 0
    over: int = 0  # consecutive over-threshold observations
    window: deque = None  # last `window` raw ratios


class ResidualMonitor:
    """Sustained measured-vs-predicted drift detector per
    ``(workload class, hardware)`` key.

    Parameters
    ----------
    window:
        EWMA span in observations (``alpha = 2/(window+1)``); also the
        length of the kept raw-residual window. A window longer than the
        observation stream is fine — the EWMA is seeded with the first
        sample and defined from then on.
    threshold:
        relative deviation ``|ewma - 1|`` that counts as over-threshold.
        The comparison is ``>=``: a residual pinned exactly at
        ``1 + threshold`` trips once sustained.
    sustain:
        consecutive over-threshold observations required to trip. One
        under-threshold observation resets the streak — this is the
        transient-noise guard.
    min_samples:
        observations of a key before it may start a streak (defaults to
        ``sustain``); keeps single-sample classes from tripping on their
        first residual.
    """

    def __init__(
        self,
        *,
        window: int = DEFAULT_WINDOW,
        threshold: float = DEFAULT_THRESHOLD,
        sustain: int = DEFAULT_SUSTAIN,
        min_samples: Optional[int] = None,
    ):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not (threshold > 0 and math.isfinite(threshold)):
            raise ValueError(f"threshold must be finite and > 0, got {threshold}")
        if sustain < 1:
            raise ValueError(f"sustain must be >= 1, got {sustain}")
        self.window = int(window)
        self.threshold = float(threshold)
        self.sustain = int(sustain)
        self.min_samples = int(sustain if min_samples is None else min_samples)
        self._alpha = 2.0 / (self.window + 1.0)
        self._state: dict = {}  # (cls, hw) -> _KeyState
        #: every trip ever observed (kept across reset() by default)
        self.events: list = []
        self.n_observed = 0

    # ------------------------------------------------------------------

    def observe(
        self, cls: str, hw: str, measured_s: float, predicted_s: float, t: float = 0.0
    ) -> Optional[DriftEvent]:
        """Feed one residual; returns a :class:`DriftEvent` when this
        observation completes a sustained over-threshold streak (the event
        is also appended to :attr:`events`), else ``None``. After a trip
        the streak restarts — without :meth:`reset` (or corrected
        predictions) the same drift trips again ``sustain`` observations
        later."""
        if not (measured_s > 0 and math.isfinite(measured_s)):
            raise ValueError(f"measured_s must be finite and > 0, got {measured_s}")
        if not (predicted_s > 0 and math.isfinite(predicted_s)):
            raise ValueError(f"predicted_s must be finite and > 0, got {predicted_s}")
        ratio = measured_s / predicted_s
        key = (cls, hw)
        st = self._state.get(key)
        if st is None:
            st = self._state[key] = _KeyState(
                ewma=ratio, window=deque(maxlen=self.window)
            )
        else:
            st.ewma += self._alpha * (ratio - st.ewma)
        st.n += 1
        st.window.append(ratio)
        self.n_observed += 1
        if st.n >= self.min_samples and abs(st.ewma - 1.0) >= self.threshold:
            st.over += 1
        else:
            st.over = 0
        if st.over >= self.sustain:
            st.over = 0
            event = DriftEvent(
                t=t, cls=cls, hw=hw, ewma=st.ewma,
                deviation=abs(st.ewma - 1.0), n_samples=st.n,
            )
            self.events.append(event)
            return event
        return None

    def observe_trace(self, recorder, predictor, *, cls: str = "trace",
                      hw: Optional[str] = None) -> list:
        """Feed every measured step of a ``TraceRecorder`` (steps with
        ``StepMeta.measured_s > 0``); returns the trip events raised.
        ``hw`` defaults to the predictor's hardware name."""
        events = []
        for r in trace_residuals(recorder, predictor, cls=cls, hw=hw):
            ev = self.observe(r.cls, r.hw, r.measured_s, r.predicted_s, t=r.t)
            if ev is not None:
                events.append(ev)
        return events

    def observe_results(self, results, predicted_s: float, *, cls: str, hw: str,
                        t0: float = 0.0) -> list:
        """Feed engine ``Result``s: each result's measured ``latency_s``
        against one per-request ``predicted_s`` (e.g. a ``request_calls``
        estimate on the target hardware). Returns the trip events."""
        events = []
        t = t0
        for r in results:
            t += r.latency_s
            ev = self.observe(cls, hw, r.latency_s, predicted_s, t=t)
            if ev is not None:
                events.append(ev)
        return events

    # ------------------------------------------------------------------

    def keys(self) -> list:
        return sorted(self._state)

    def ewma(self, cls: str, hw: str) -> Optional[float]:
        st = self._state.get((cls, hw))
        return None if st is None else st.ewma

    def deviation(self, cls: str, hw: str) -> Optional[float]:
        st = self._state.get((cls, hw))
        return None if st is None else abs(st.ewma - 1.0)

    def n_samples(self, cls: str, hw: str) -> int:
        st = self._state.get((cls, hw))
        return 0 if st is None else st.n

    def window_samples(self, cls: str, hw: str) -> list:
        """The raw residual ratios currently in the key's sliding window."""
        st = self._state.get((cls, hw))
        return [] if st is None else list(st.window)

    def corrections(self) -> dict:
        """Per-hardware residual correction factors: for each hardware with
        observations, the window-count-weighted mean of its class EWMAs.
        Multiply predicted service times by these to get residual-corrected
        ones (``ResidualCorrectedObjective`` does exactly that). Hardware
        never observed is absent — callers treat that as factor 1.0."""
        num: dict = {}
        den: dict = {}
        for (_, hw), st in self._state.items():
            w = len(st.window)
            num[hw] = num.get(hw, 0.0) + st.ewma * w
            den[hw] = den.get(hw, 0) + w
        return {hw: num[hw] / den[hw] for hw in num if den[hw] > 0}

    def reset(self, *, clear_events: bool = False) -> None:
        """Drop all per-key sample state (the re-route loop calls this
        after applying corrections — the baseline changed, so history
        against the old baseline is no longer evidence). The trip history
        in :attr:`events` is kept unless ``clear_events=True``."""
        self._state.clear()
        self.n_observed = 0
        if clear_events:
            self.events.clear()


# ----------------------------------------------------------------------
# trace round-trip helpers
# ----------------------------------------------------------------------


def step_predicted_s(meta, cfg, predictor, *, pp_schedule: str = "gpipe",
                     pp_interleave: int = 2, tuned: Optional[dict] = None) -> float:
    """Predicted seconds of one recorded step, re-lowered from its
    :class:`~repro.serve.trace.StepMeta` shapes alone (``B``/``qlen``/
    ``kvlen`` at the meta's ``tp``/``pp``). By construction this equals
    predicting the recorded call group directly — the round-trip the
    recorder contract promises (covered in ``tests/test_trace_residuals``)."""
    from repro.serve.trace import step_calls

    return predictor.predict(
        step_calls(cfg, meta.B, meta.qlen, meta.kvlen, tp=meta.tp, pp=meta.pp,
                   pp_schedule=pp_schedule, pp_interleave=pp_interleave,
                   tuned=tuned)
    ).total_s


def trace_residuals(recorder, predictor, *, cls: str = "trace",
                    hw: Optional[str] = None) -> list:
    """Measured-vs-predicted residuals of a recorded serving run: one
    :class:`Residual` per step that carries engine wall-clock
    (``StepMeta.measured_s > 0``), with ``predicted_s`` from pricing the
    recorded call group on ``predictor``. Timestamps are the cumulative
    measured seconds (a per-process clock, good enough for ordering)."""
    if hw is None:
        hw = getattr(getattr(predictor, "hw", None), "name", "") or "?"
    out = []
    t = 0.0
    for (_, _, calls), meta in zip(recorder.steps, recorder.meta):
        if meta.measured_s <= 0:
            continue
        t += meta.measured_s
        out.append(
            Residual(t=t, cls=cls, hw=hw, measured_s=meta.measured_s,
                     predicted_s=predictor.predict(calls).total_s,
                     label=meta.label)
        )
    return out
