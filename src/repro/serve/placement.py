"""Prediction-guided fleet placement: route workloads across the hardware
registry using the predict layer (paper §VII "beyond simulation" — the
predictor as a hardware-selection engine, cf. PipeWeave's deployment
framing and Lee et al.'s predict-then-place fleet workflow).

``FleetRouter`` closes the loop ISSUE 3 opened: a live ``TraceRecorder``
trace (or a synthetic ``request_calls`` sequence) is priced on every
registry entry via one shared ``SweepPredictor`` pass, then ranked under a
pluggable objective (``repro.predict.objective``)::

    router = FleetRouter(objective="cost", estimator=pw, fallback="oracle")
    placement = router.route(rec.calls(), n_tokens=rec.decode_tokens)
    placement.best            # hw name with the lowest score
    print(placement.table())  # ranked table, skipped hw surfaced

Split-fleet assignment prices workload *classes* separately — a
prefill-heavy class is compute-bound and a decode-heavy class is
bandwidth-bound, so they can prefer different devices::

    sp = router.route_split(rec)   # or {"prefill": [...], "decode": [...]}
    sp.assignment                  # {"prefill": "tpu-v7p", "decode": "tpu-v6e"}

Robustness: a registry entry whose backend cannot price the trace — an
unfitted ``CommRegressor``, an untrained kernel family under
``fallback="error"``, unpriced hardware under a cost objective — is
*skipped with a warning* and surfaced in ``Placement.skipped`` and the
table, instead of aborting the whole fleet sweep mid-pass. Routing only
raises when **no** hardware survives.

Units: scores follow the objective (seconds for ``latency``, USD for the
cost family); ``total_s``/``cost_usd`` per row are whole-trace values.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.predict.api import Estimate
from repro.predict.batching import group_calls
from repro.predict.objective import (
    Objective,
    ResidualCorrectedObjective,
    UnpricedHardwareError,
    get_objective,
    trace_cost_usd,
)
from repro.predict.sweep import SweepPredictor, check_prebuilt_exclusive, hw_split


@dataclasses.dataclass
class PlacementRow:
    """One ranked hardware: whole-trace latency/cost plus the objective's
    score (lower = better) and SLO feasibility."""

    hw: str
    split: str  # seen / unseen / ? (off-registry)
    total_s: float
    cost_usd: Optional[float]  # None when the hardware is unpriced
    score: float
    feasible: bool
    estimate: Estimate


@dataclasses.dataclass
class Placement:
    """A ranked routing decision: feasible hardware first (by score), then
    infeasible (still by score), plus every skipped entry with its reason."""

    objective: str
    rows: list  # PlacementRow, ranked
    skipped: dict  # hw name -> reason string
    n_tokens: Optional[float] = None

    @property
    def best(self) -> str:
        """The top-ranked hardware name (feasible when any entry is)."""
        if not self.rows:
            raise RuntimeError(
                f"placement under {self.objective!r} has no rankable hardware"
                + (f"; skipped: {self.skipped}" if self.skipped else "")
            )
        return self.rows[0].hw

    def ranking(self) -> list:
        return [r.hw for r in self.rows]

    def __getitem__(self, hw_name: str) -> PlacementRow:
        for r in self.rows:
            if r.hw == hw_name:
                return r
        raise KeyError(hw_name)

    def __contains__(self, hw_name: str) -> bool:
        return any(r.hw == hw_name for r in self.rows)

    def table(self) -> str:
        """Ranked placement table; skipped hardware is listed last with
        its skip reason so fleet gaps stay visible."""
        lines = [f"{'hardware':<14} {'split':<7} {'total':>10} {'cost':>10} "
                 f"{'score':>12} {'feasible':>8}"]
        for r in self.rows:
            cost = "-" if r.cost_usd is None else f"${r.cost_usd:.3g}"
            lines.append(
                f"{r.hw:<14} {r.split:<7} {r.total_s*1e3:>8.2f}ms {cost:>10} "
                f"{r.score:>12.4g} {'yes' if r.feasible else 'NO':>8}"
            )
        for name, reason in sorted(self.skipped.items()):
            lines.append(f"{name:<14} {'-':<7} {'skipped:':>10} {reason}")
        return "\n".join(lines)


@dataclasses.dataclass
class SplitPlacement:
    """Per-workload-class placements (``route_split``): one ``Placement``
    per class, plus the combined fleet assignment."""

    parts: dict  # class name -> Placement

    @property
    def assignment(self) -> dict:
        """``{class: best hw}`` — the split-fleet routing decision."""
        return {phase: p.best for phase, p in self.parts.items()}

    @property
    def is_split(self) -> bool:
        """True when at least two classes prefer different hardware."""
        return len(set(self.assignment.values())) > 1

    def __getitem__(self, phase: str) -> Placement:
        return self.parts[phase]

    def table(self) -> str:
        out = []
        for phase, p in self.parts.items():
            out.append(f"-- {phase} (objective={p.objective}) --")
            out.append(p.table())
        return "\n".join(out)


class FleetRouter:
    """Rank the hardware fleet for a workload by predicted performance.

    Construction mirrors ``SweepPredictor`` (it owns one internally):
    ``hws`` is an iterable of registry names or ``TPUSpec``s (default: the
    whole registry), ``backend`` + ``**backend_kw`` go to
    ``get_predictor`` per hardware, or pass a prebuilt ``sweep=`` to share
    its warmed ``FeatureCache`` across many routing calls. ``objective``
    is the default criterion (name or ``Objective``); every route call may
    override it.

    ``audit=True`` runs the predictor-coverage lint
    (``repro.analysis.audit_predictor``) over every fleet backend at
    construction and raises :class:`~repro.analysis.AuditError` listing the
    diagnostics — a stale ``CommRegressor`` or an untrained kernel family
    fails *here* instead of surfacing as one skip warning per hardware in
    the middle of a fleet sweep. Pass a callable
    ``audit(predictor, hw_name) -> list[Diagnostic]`` to substitute a
    custom pre-flight lint."""

    def __init__(
        self,
        hws=None,
        backend: str = "synperf",
        *,
        objective="latency",
        sweep: Optional[SweepPredictor] = None,
        audit=None,
        **backend_kw,
    ):
        check_prebuilt_exclusive("sweep", sweep, hws, backend, backend_kw)
        self.sweep = sweep if sweep is not None else SweepPredictor(hws, backend, **backend_kw)
        self.objective = get_objective(objective)
        if audit:
            # deferred import: serve must stay importable without analysis
            from repro.analysis import AuditError, audit_predictor

            hook = audit_predictor if audit is True else audit
            found = []
            for name, predictor in self.sweep.predictors.items():
                found += (
                    hook(predictor, hw_name=name)
                    if hook is audit_predictor
                    else hook(predictor, name)
                )
            errors = [d for d in found if d.severity == "error"]
            if errors:
                raise AuditError(errors)

    @property
    def hw_names(self) -> list:
        return self.sweep.hw_names

    # ------------------------------------------------------------------

    def _rank(
        self, estimates: dict, obj: Objective, n_tokens, skipped: dict
    ) -> Placement:
        rows = []
        for hw in self.sweep.hws:
            if hw.name in skipped:
                continue
            est = estimates[hw.name]
            try:
                score = obj.score(hw, est, n_tokens=n_tokens)
            except UnpricedHardwareError as e:
                # a per-hardware gap (no price) skips the entry; workload-
                # metadata errors (e.g. a missing n_tokens) are hardware-
                # independent and propagate to the caller instead of being
                # laundered into one skip warning per fleet entry
                warnings.warn(f"FleetRouter: skipping {hw.name}: {e}", stacklevel=3)
                skipped[hw.name] = f"{type(e).__name__}: {e}"
                continue
            cost = (
                None
                if hw.usd_per_chip_hour is None
                else trace_cost_usd(hw, est)
            )
            rows.append(
                PlacementRow(
                    hw=hw.name,
                    split=hw_split(hw.name),
                    total_s=est.total_s,
                    cost_usd=cost,
                    score=score,
                    feasible=obj.feasible(hw, est),
                    estimate=est,
                )
            )
        if not rows:
            raise RuntimeError(
                f"FleetRouter: every hardware was skipped under "
                f"{obj.describe()!r}: {skipped}"
            )
        rows.sort(key=lambda r: (not r.feasible, r.score))
        return Placement(
            objective=obj.describe(), rows=rows, skipped=skipped, n_tokens=n_tokens
        )

    def route(
        self,
        calls,
        *,
        objective=None,
        n_tokens: Optional[float] = None,
        scale: float = 1.0,
        overlap: bool = False,
    ) -> Placement:
        """Price ``calls`` on every fleet entry (one grouping pass, shared
        cache) and rank under the objective.

        ``n_tokens`` is the generated-token count (needed by per-token
        objectives); ``scale`` multiplies every estimate (e.g. the PP
        bubble surcharge ``place_request`` applies); ``overlap=True``
        overlap-prices each candidate (``Estimate.overlapped``, applied
        before ``scale``) — each device uses its own exposed-compute
        window, which can re-rank comm-bound fleets. Hardware whose
        backend raises while pricing (unfitted comm regressor, untrained
        family under ``fallback="error"``) is skipped with a warning."""
        obj = self.objective if objective is None else get_objective(objective)
        families, comms = group_calls(calls)
        estimates: dict = {}
        skipped: dict = {}
        for hw in self.sweep.hws:
            try:
                est = self.sweep.predictors[hw.name].predict_grouped(families, comms)
            except RuntimeError as e:  # incl. UntrainedFamilyError
                warnings.warn(
                    f"FleetRouter: skipping {hw.name}: {e}", stacklevel=2
                )
                skipped[hw.name] = f"{type(e).__name__}: {e}"
                continue
            if overlap:
                est = est.overlapped()
            estimates[hw.name] = est if scale == 1.0 else est.scaled(scale)
        return self._rank(estimates, obj, n_tokens, skipped)

    def route_many(
        self,
        named_calls: dict,
        *,
        objective=None,
        n_tokens: Optional[dict] = None,
        scales: Optional[dict] = None,
    ) -> dict:
        """Route several named workloads through the shared sweep cache:
        ``{name: call sequence} -> {name: Placement}``. ``n_tokens`` and
        ``scales`` are optional per-name mappings (generated-token count
        for per-token objectives; estimate scale, e.g. a PP bubble
        surcharge). The names are workload *classes* in the fleet-simulator
        sense (``serve.fleet``) — every class is priced against one warmed
        ``FeatureCache``, so routing a whole traffic mix costs barely more
        than one combined route."""
        n_tokens = n_tokens or {}
        scales = scales or {}
        return {
            name: self.route(
                calls,
                objective=objective,
                n_tokens=n_tokens.get(name),
                scale=scales.get(name, 1.0),
            )
            for name, calls in named_calls.items()
        }

    def route_corrected(
        self,
        named_calls: dict,
        corrections: dict,
        *,
        objective=None,
        n_tokens: Optional[dict] = None,
        scales: Optional[dict] = None,
    ) -> dict:
        """``route_many`` against *residual-corrected* service times: every
        hardware's estimate is rescaled by its measured-vs-predicted
        correction factor (``{hw: factor}``, absent = 1.0 — typically a
        ``repro.serve.monitor.ResidualMonitor``'s ``corrections()``) before
        objective scoring. This is the mid-replay re-route step of the
        drift control loop: the ranking reflects what the fleet measures,
        not what the frozen fit believed."""
        obj = self.objective if objective is None else get_objective(objective)
        return self.route_many(
            named_calls,
            objective=ResidualCorrectedObjective(obj, dict(corrections)),
            n_tokens=n_tokens,
            scales=scales,
        )

    def route_trace(self, recorder, *, objective=None, scale: float = 1.0) -> Placement:
        """Route a live ``TraceRecorder``: the recorded call groups with
        ``n_tokens`` taken from the recorder's generated-token count
        (prefill-sampled first tokens + decode-tick tokens)."""
        return self.route(
            recorder.calls(),
            objective=objective,
            n_tokens=recorder.generated_tokens or None,
            scale=scale,
        )

    def route_split(self, trace, *, objective=None) -> SplitPlacement:
        """Split-fleet assignment: place each workload class on its own
        best hardware.

        ``trace`` is a ``TraceRecorder`` (classes = recorded step phases,
        via ``split_calls()``) or a ``{class: call sequence}`` mapping.
        Every class is priced through the same shared cache, so the split
        pass costs barely more than one combined route."""
        if hasattr(trace, "split_calls"):
            parts = trace.split_calls()
            # per-class token counts so per-token objectives work on
            # either side of the split
            tokens = {
                "prefill": getattr(trace, "prefill_tokens", None) or None,
                "decode": getattr(trace, "decode_tokens", None) or None,
            }
        elif isinstance(trace, dict):
            parts = trace
            tokens = {}
        else:
            raise TypeError(
                "route_split takes a TraceRecorder or a {class: calls} mapping, "
                f"got {type(trace).__name__}"
            )
        if not parts:
            raise ValueError("route_split: empty trace (no workload classes)")
        return SplitPlacement(
            {
                phase: self.route(calls, objective=objective, n_tokens=tokens.get(phase))
                for phase, calls in parts.items()
            }
        )
