"""Serving engine: batched prefill + decode with a KV cache, request queue
and sampler — the inference-side driver (the paper's subject is inference
performance, so the end-to-end example serves batched requests).

Single-process implementation with the same structure a multi-host server
uses: admission by batch, one prefill per admitted batch (right-padded to the
batch max), then lock-step decode with per-sequence stop handling.

Both engines share a :class:`_ModelRunner` that owns params, caches, the
jitted prefill/decode steps and sampling — and optionally a mesh. With
``mesh=`` the engines are *mesh-native*: parameters are placed with
``dist.sharding.param_pspecs``, KV caches with ``cache_pspecs``, and every
step traces under ``use_mesh(mesh)`` so the models' ``constrain``
annotations become real sharding constraints — prefill and decode then
genuinely execute sharded (verify on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). The engine's
parallel degrees (``engine.tp``/``engine.pp``, the mesh's "model"/"pipe"
axis sizes) flow into an attached ``TraceRecorder`` and into predicted
admission, so traces and admission decisions are priced at the mesh the
engine actually runs on rather than a caller-declared one.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.transformer as T
from repro.configs.base import ArchConfig
from repro.dist.sharding import (
    cache_pspecs,
    mesh_degrees,
    param_pspecs,
    to_named,
    use_mesh,
)
from repro.models.registry import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: list
    prefill_s: float
    decode_s: float
    #: scheduler steps the request was resident for (its admission prefill
    #: plus every decode tick it took a token in) — comparable across the
    #: batch and continuous engines, and to fleet-simulator service ticks
    ticks: int = 0
    #: admission-to-retire wall-clock of this process. For the reference
    #: CPU engines this is a functional metric only; the fleet simulator's
    #: queueing latency is the *predicted* analogue on target hardware.
    latency_s: float = 0.0


class _ModelRunner:
    """Shared prefill/decode/sample machinery for the serving engines.

    Owns the model api, parameters, the jitted step functions and the
    engine's base PRNG key. With ``mesh=`` the runner places parameters
    (``param_pspecs``) and caches (``cache_pspecs``) on the mesh and runs
    every jitted step inside ``use_mesh(mesh)``, so the models' activation
    ``constrain`` hints resolve against it at trace time. ``tp``/``pp``
    are the mesh's "model"/"pipe" axis sizes (1 without a mesh) — the
    degrees every consumer (trace recorder, predicted admission) prices
    this engine's steps at.
    """

    def __init__(self, cfg: ArchConfig, *, params=None, seed: int = 0, mesh=None):
        self.cfg = cfg
        self.api = build_model(cfg)
        self.mesh = mesh
        self.tp, self.pp = mesh_degrees(mesh)
        if params is None:
            params = self.api.init(jax.random.PRNGKey(seed))
        if mesh is not None:
            params = jax.device_put(params, to_named(param_pspecs(params, mesh), mesh))
        self.params = params
        self.base_key = jax.random.PRNGKey(seed)
        self._jit_decode = jax.jit(self.api.decode, donate_argnums=(1,))
        self._jit_prefill = jax.jit(self.api.prefill)

    def _ctx(self):
        return use_mesh(self.mesh) if self.mesh is not None else contextlib.nullcontext()

    def prefill(self, batch):
        with self._ctx():
            return self._jit_prefill(self.params, batch)

    def decode(self, caches, tokens, positions):
        with self._ctx():
            return self._jit_decode(self.params, caches, tokens, positions)

    def shard_cache(self, caches):
        """Place a cache tree on the mesh (identity without one)."""
        if self.mesh is None:
            return caches
        return jax.device_put(caches, to_named(cache_pspecs(caches, self.mesh), self.mesh))

    def grow_cache(self, caches, max_len: int):
        """``pad_cache`` to ``max_len`` and (re)place on the mesh — padding
        concatenates host zeros, which would otherwise decommit the
        sharding prefill produced."""
        return self.shard_cache(T.pad_cache(caches, self.cfg, max_len))

    def init_cache(self, batch: int, max_len: int):
        return self.shard_cache(self.api.init_cache(batch, max_len))

    def sample(self, logits, temperatures, key):
        """Greedy/categorical per row: ``logits (B, V_padded) -> (B,) int32``.
        Rows with temperature 0 take the argmax; others sample."""
        logits = logits[:, : self.cfg.vocab_size]
        temps = jnp.asarray(temperatures)[:, None]
        greedy = jnp.argmax(logits, axis=-1)
        sampled = jax.random.categorical(key, logits / jnp.maximum(temps, 1e-3))
        return jnp.where(temps[:, 0] > 0, sampled, greedy).astype(jnp.int32)


class _EngineBase:
    """Queue + runner plumbing common to both engines. Exposes the runner's
    identity (``params``/``mesh``/``tp``/``pp``) and binds an attached
    recorder to the engine's mesh degrees, so a recorder never needs the
    caller to declare ``tp=``/``pp=`` for a mesh-native engine."""

    def __init__(self, cfg: ArchConfig, *, params, seed, recorder, mesh):
        self.cfg = cfg
        self._runner = _ModelRunner(cfg, params=params, seed=seed, mesh=mesh)
        self.api = self._runner.api
        self.queue: deque[Request] = deque()
        # optional serve.trace.TraceRecorder: every executed step also emits
        # its decomposer call sequence (actual launched shapes)
        self.recorder = recorder
        if recorder is not None and mesh is not None:
            recorder.bind_mesh(self._runner.tp, self._runner.pp)

    @property
    def params(self):
        return self._runner.params

    @params.setter
    def params(self, value):
        self._runner.params = value

    @property
    def mesh(self):
        return self._runner.mesh

    @property
    def tp(self) -> int:
        """Tensor-parallel degree the engine executes at (the mesh's
        "model" axis size; 1 single-process)."""
        return self._runner.tp

    @property
    def pp(self) -> int:
        return self._runner.pp

    def submit(self, req: Request):
        self.queue.append(req)


class ServeEngine(_EngineBase):
    def __init__(self, cfg: ArchConfig, params=None, seed: int = 0, max_batch: int = 8,
                 recorder=None, mesh=None):
        super().__init__(cfg, params=params, seed=seed, recorder=recorder, mesh=mesh)
        self.max_batch = max_batch
        self._batch_idx = 0  # folds into the engine seed for per-batch keys

    # ------------------------------------------------------------------
    def _pad_batch(self, prompts: list[np.ndarray]):
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = np.zeros((B, L), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p  # left-pad so last token aligns
            lens[i] = len(p)
        return jnp.asarray(toks), jnp.asarray(lens), L

    def _extra_inputs(self, B: int, key):
        extra = {}
        if self.cfg.family == "audio":
            extra["frames"] = 0.1 * jax.random.normal(
                key, (B, self.cfg.enc_frames, self.cfg.d_model)
            ).astype(self.cfg.compute_dtype)
        if self.cfg.family == "vlm":
            extra["image_embeds"] = 0.1 * jax.random.normal(
                key, (B, self.cfg.n_img_tokens, self.cfg.d_model)
            ).astype(self.cfg.compute_dtype)
        return extra

    def step_batch(self) -> list[Result]:
        """Admit up to max_batch requests, serve them to completion."""
        if not self.queue:
            return []
        batch_reqs = [
            self.queue.popleft()
            for _ in range(min(self.max_batch, len(self.queue)))
        ]
        B = len(batch_reqs)
        toks, lens, L = self._pad_batch([r.prompt for r in batch_reqs])
        max_new = max(r.max_new for r in batch_reqs)
        # every batch samples under its own key chain: the engine seed
        # folded with a batch counter (identical seeds still reproduce)
        key = jax.random.fold_in(self._runner.base_key, self._batch_idx)
        self._batch_idx += 1
        key, extra_key = jax.random.split(key)

        t0 = time.perf_counter()
        if self.recorder is not None:
            self.recorder.record_step(
                f"prefill[b{B}xL{L}]", self.cfg, B, L, L, phase="prefill"
            )
        batch = {"tokens": toks, **self._extra_inputs(B, extra_key)}
        logits, caches = self._runner.prefill(batch)
        caches = self._runner.grow_cache(caches, L + max_new)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0
        if self.recorder is not None:
            # stamp the prefill step with its wall-clock: measured-vs-
            # predicted residuals (serve.monitor) pair this with the
            # recorded call group
            self.recorder.mark_measured(prefill_s)

        outputs: list[list[int]] = [[] for _ in range(B)]
        t0 = time.perf_counter()
        key, sub = jax.random.split(key)
        cur = self._sample(logits, batch_reqs, sub)
        for i in range(B):
            outputs[i].append(int(cur[i]))
        for step in range(max_new - 1):
            pos = jnp.full((B,), L + step, jnp.int32)
            if self.recorder is not None:
                # the step attends the prompt plus every generated token
                # including the one being written at pos; `active` counts
                # the sequences that still accept a token this tick
                # (shorter-max_new rows ride along in the padded batch)
                still = sum(
                    1 for i in range(B)
                    if len(outputs[i]) < batch_reqs[i].max_new
                )
                self.recorder.record_step(
                    f"decode@{L + step}", self.cfg, B, 1, L + step + 1,
                    phase="decode", active=still,
                )
            t_step = time.perf_counter()
            logits, caches = self._runner.decode(caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = self._sample(logits, batch_reqs, sub)
            for i in range(B):
                if len(outputs[i]) < batch_reqs[i].max_new:
                    outputs[i].append(int(cur[i]))
            if self.recorder is not None:
                # int(cur[i]) above synced the step; this is real wall-clock
                self.recorder.mark_measured(time.perf_counter() - t_step)
        jax.block_until_ready(cur)
        decode_s = time.perf_counter() - t0
        return [
            Result(
                r.rid, outputs[i], prefill_s, decode_s,
                ticks=len(outputs[i]), latency_s=prefill_s + decode_s,
            )
            for i, r in enumerate(batch_reqs)
        ]

    def _sample(self, logits, reqs, key):
        return self._runner.sample(
            logits, [r.temperature for r in reqs], key
        )


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next write position (absolute, excl. meta)
    emitted: Optional[list] = None
    cur: int = 0  # last sampled token
    t_admit: float = 0.0  # perf_counter at admission (residency metrics)
    prefill_s: float = 0.0
    ticks: int = 0  # scheduler steps this request took a token in

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatchingEngine(_EngineBase):
    """In-flight batching: a fixed pool of decode slots steps in lock-step;
    finished requests free their slot and waiting requests are admitted at
    the next step boundary (each admission prefills into its slot's region
    of the shared KV cache). This is the vLLM/Orca-style scheduler shape on
    top of the same pjit-able decode step.

    Shape conventions (they matter for anything consuming traces or
    predictions of this engine):

      * every decode tick launches the **full padded slot pool** — the
        launched batch is ``slots`` regardless of how many are active, and
        a tick generates one token per *active* slot;
      * the *attended* KV span of a tick is ``max(active positions) + 1``
        (the logical work the decomposer and the hwsim oracle price); the
        reference masked decode kernel physically sweeps the padded cache,
        so wall-clock of this CPU process is not the modeled latency;
      * all latencies in the admission machinery are **seconds predicted
        on the admission predictor's hardware**, not host wall-clock —
        this engine is a functional reference, the predictor is the model
        of the serving fleet.

    Admission policy (``admission=``):

      * ``"fixed"`` (default): admit whenever a slot is free — the classic
        fixed slot-count heuristic;
      * ``"predicted"``: before each admission, ask ``predictor`` (any
        ``repro.predict`` backend) for the decode-tick latency of the
        would-be batch at its **worst-case future KV span** (every active
        slot and the candidate projected to their final positions), and
        admit only while that stays within ``decode_slo_s``. Steps are
        priced at the engine's actual parallel degrees (``self.tp`` — the
        mesh's "model" axis size for a mesh-native engine). Predicted
        latency grows with the KV span (up to scheduler-quantization
        wiggle of a fraction of a percent — size the SLO with that
        margin), so a request admitted under the SLO keeps every
        subsequent tick under it too. A request that violates the
        SLO even alone in the pool is admitted anyway with a warning
        (progress guarantee; counted in ``slo_forced_admits``). If the
        predictor cannot price a step (unfitted comm regressor, untrained
        kernel family under ``fallback="error"``), the engine warns once
        and falls back cleanly to fixed admission
        (``admission_fallback_reason``). Decisions are logged in
        ``admission_log`` (one dict per considered candidate).

    Implementation notes for the single-process reference: the shared cache
    is (B_slots, max_len, ...); per-slot prefill recomputes the prompt with
    the slot's row batched alone and writes its KV into the slot row
    (dynamic_update_slice), so running requests are never interrupted.
    """

    def __init__(self, cfg: ArchConfig, *, slots: int = 4, max_len: int = 128,
                 params=None, seed: int = 0, recorder=None,
                 admission: str = "fixed", predictor=None,
                 decode_slo_s: Optional[float] = None, mesh=None,
                 audit=None, tuned: Optional[dict] = None):
        assert cfg.family not in ("ssm", "hybrid", "audio", "vlm"), (
            "reference continuous-batching engine supports KV-cache LMs"
        )
        if admission not in ("fixed", "predicted"):
            raise ValueError(f"admission must be 'fixed' or 'predicted', got {admission!r}")
        if admission == "predicted" and (predictor is None or decode_slo_s is None):
            raise ValueError(
                "admission='predicted' needs predictor= (a repro.predict "
                "backend for the target hardware) and decode_slo_s= (the "
                "per-tick decode latency SLO in predicted seconds)"
            )
        if audit and predictor is not None:
            # audit=True: pre-flight coverage lint — a predictor that cannot
            # price the decode workload (stale CommRegressor, untrained
            # family) fails construction instead of the first admission tick.
            # A callable substitutes a custom lint:
            # audit(predictor, hw_name) -> list[Diagnostic].
            from repro.analysis import AuditError, audit_predictor

            found = (
                audit_predictor(predictor)
                if audit is True
                else audit(predictor, getattr(getattr(predictor, "hw", None), "name", ""))
            )
            errors = [d for d in found if d.severity == "error"]
            if errors:
                raise AuditError(errors)
        super().__init__(cfg, params=params, seed=seed, recorder=recorder, mesh=mesh)
        self.max_len = max_len
        self.admission = admission
        self.predictor = predictor
        self.decode_slo_s = decode_slo_s
        #: autotuned kernel block table for this engine's hardware
        #: (``repro.tune.TunedConfigs.for_hw(hw)``); predicted admission
        #: prices decode ticks with these blocks merged in
        self.tuned = tuned
        #: one dict per admission decision: rid, projected kv, predicted_s,
        #: slo_s, admitted, forced (admitted despite violating, alone in pool)
        self.admission_log: list[dict] = []
        self.slo_forced_admits = 0
        self.admission_fallback_reason: Optional[str] = None
        self.slots = [_Slot() for _ in range(slots)]
        self.caches = self._runner.init_cache(slots, max_len)
        self.done: list[Result] = []
        self._key = jax.random.PRNGKey(seed + 1)

    # ------------------------------------------------------------------
    # predicted admission

    def _projected_kv(self, req: Request) -> int:
        """Worst-case attended KV span of any future tick of the would-be
        batch: every active slot and the candidate projected to their
        final write positions (conservative within one token). Predicted
        tick latency grows with this span (modulo sub-percent scheduler
        quantization), so one check at admission covers the request's
        whole residency."""
        cap = self.max_len - 1
        spans = [min(len(req.prompt) + req.max_new, cap)]
        for s in self.slots:
            if not s.free:
                spans.append(min(s.pos + max(s.req.max_new - len(s.emitted), 0), cap))
        return max(spans) + 1

    def _predicted_tick_s(self, kv: int) -> Optional[float]:
        """Predicted decode-tick latency (seconds on the predictor's
        hardware) for the full slot pool attending ``kv``, priced at the
        engine's actual tensor-parallel degree; None when the predictor
        cannot price the step (the engine has then already fallen back to
        fixed admission)."""
        from repro.core.e2e import model_calls

        try:
            return self.predictor.predict(
                model_calls(self.cfg, len(self.slots), 1, kv, tp=self.tp,
                            tuned=self.tuned)
            ).total_s
        except RuntimeError as e:  # unfitted estimator / comm regressor
            self.admission_fallback_reason = f"{type(e).__name__}: {e}"
            self.admission = "fixed"
            warnings.warn(
                f"predicted admission unavailable ({e}); falling back to "
                "fixed slot admission",
                stacklevel=4,
            )
            return None

    def _admit_ok(self, req: Request) -> bool:
        """One admission decision under the predicted policy (always True
        for fixed admission). Logged in ``admission_log``."""
        if self.admission != "predicted":
            return True
        kv = self._projected_kv(req)
        pred = self._predicted_tick_s(kv)
        if pred is None:
            return True  # fell back to fixed admission mid-run
        ok = pred <= self.decode_slo_s
        forced = False
        if not ok and all(s.free for s in self.slots):
            # the request violates the SLO even alone: admit anyway so the
            # queue cannot deadlock, but say so loudly
            forced, ok = True, True
            self.slo_forced_admits += 1
            warnings.warn(
                f"request {req.rid} cannot meet decode_slo_s="
                f"{self.decode_slo_s:.4g}s even alone in the pool "
                f"(predicted {pred:.4g}s); admitting anyway",
                stacklevel=3,
            )
        self.admission_log.append(
            {
                "rid": req.rid,
                "kv": kv,
                "predicted_s": pred,
                "slo_s": self.decode_slo_s,
                "admitted": ok,
                "forced": forced,
            }
        )
        return ok

    # ------------------------------------------------------------------
    def _admit(self):
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            if not self._admit_ok(self.queue[0]):
                break  # FIFO: a deferred head is retried next tick
            req = self.queue.popleft()
            L = len(req.prompt)
            t0 = time.perf_counter()
            if self.recorder is not None:
                # per-slot admission prefills recompute the prompt alone
                self.recorder.record_step(
                    f"admit#{req.rid}[L{L}]", self.cfg, 1, L, L, phase="prefill"
                )
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
            logits, cache1 = self._runner.prefill(batch)
            cache1 = self._runner.grow_cache(cache1, self.max_len)
            # copy this request's KV rows into slot i of the shared cache
            # (supported families' cache leaves are (n_layers, B, S, H, D):
            # the slot axis is always 1)
            self.caches = jax.tree.map(
                lambda full, one: full.at[:, i].set(one[:, 0]),
                self.caches,
                cache1,
            )
            self._key, sub = jax.random.split(self._key)
            tok = self._sample_one(logits[0], req, sub)
            now = time.perf_counter()
            slot.req, slot.pos, slot.emitted, slot.cur = req, L, [tok], tok
            slot.t_admit, slot.prefill_s, slot.ticks = t0, now - t0, 1
            if self.recorder is not None:
                # the admit step's wall-clock == the slot's prefill_s, so
                # trace residuals reproduce Result-derived ones exactly
                self.recorder.mark_measured(slot.prefill_s)

    def _sample_one(self, logits, req, key) -> int:
        logits = logits[: self.cfg.vocab_size]
        if req.temperature > 0:
            return int(jax.random.categorical(key, logits / req.temperature))
        return int(jnp.argmax(logits))

    def step(self):
        """One scheduler tick: admit, decode all active slots, retire."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return False
        toks = jnp.asarray([s.cur if not s.free else 0 for s in self.slots], jnp.int32)
        pos = jnp.asarray(
            [min(s.pos, self.max_len - 1) for s in self.slots], jnp.int32
        )
        if self.recorder is not None:
            # lock-step decode launches over the full slot pool; the padded
            # batch attends up to the most advanced active position
            kv = max(min(self.slots[i].pos, self.max_len - 1) for i in active) + 1
            self.recorder.record_step(
                f"tick[{len(active)}/{len(self.slots)}]",
                self.cfg, len(self.slots), 1, kv,
                phase="decode", active=len(active),
            )
        t_tick = time.perf_counter()
        logits, self.caches = self._runner.decode(self.caches, toks, pos)
        for i in active:
            s = self.slots[i]
            self._key, sub = jax.random.split(self._key)
            tok = self._sample_one(logits[i], s.req, sub)
            s.emitted.append(tok)
            s.pos += 1
            s.cur = tok
            s.ticks += 1
            if len(s.emitted) >= s.req.max_new or s.pos >= self.max_len - 1:
                now = time.perf_counter()
                self.done.append(
                    Result(
                        s.req.rid, s.emitted, s.prefill_s,
                        max(now - s.t_admit - s.prefill_s, 0.0),
                        ticks=s.ticks, latency_s=now - s.t_admit,
                    )
                )
                self.slots[i] = _Slot()
        if self.recorder is not None:
            # the per-slot int() sampling above synced the tick
            self.recorder.mark_measured(time.perf_counter() - t_tick)
        return True

    def run_to_completion(self) -> list[Result]:
        while self.queue or any(not s.free for s in self.slots):
            self.step()
        out, self.done = self.done, []
        return out
