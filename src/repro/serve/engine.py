"""Serving engine: batched prefill + decode with a KV cache, request queue
and sampler — the inference-side driver (the paper's subject is inference
performance, so the end-to-end example serves batched requests).

Single-process implementation with the same structure a multi-host server
uses: admission by batch, one prefill per admitted batch (right-padded to the
batch max), then lock-step decode with per-sequence stop handling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.transformer as T
from repro.configs.base import ArchConfig
from repro.models.registry import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: list
    prefill_s: float
    decode_s: float


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None, seed: int = 0, max_batch: int = 8,
                 recorder=None):
        self.cfg = cfg
        self.api = build_model(cfg)
        self.params = params if params is not None else self.api.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.queue: list[Request] = []
        # optional serve.trace.TraceRecorder: every executed step also emits
        # its decomposer call sequence (actual launched shapes)
        self.recorder = recorder
        self._decode = jax.jit(self.api.decode, donate_argnums=(1,))
        self._prefill = jax.jit(self.api.prefill)

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _pad_batch(self, prompts: list[np.ndarray]):
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = np.zeros((B, L), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p  # left-pad so last token aligns
            lens[i] = len(p)
        return jnp.asarray(toks), jnp.asarray(lens), L

    def _extra_inputs(self, B: int, key):
        extra = {}
        if self.cfg.family == "audio":
            extra["frames"] = 0.1 * jax.random.normal(
                key, (B, self.cfg.enc_frames, self.cfg.d_model)
            ).astype(self.cfg.compute_dtype)
        if self.cfg.family == "vlm":
            extra["image_embeds"] = 0.1 * jax.random.normal(
                key, (B, self.cfg.n_img_tokens, self.cfg.d_model)
            ).astype(self.cfg.compute_dtype)
        return extra

    def step_batch(self) -> list[Result]:
        """Admit up to max_batch requests, serve them to completion."""
        if not self.queue:
            return []
        batch_reqs = self.queue[: self.max_batch]
        self.queue = self.queue[self.max_batch :]
        B = len(batch_reqs)
        toks, lens, L = self._pad_batch([r.prompt for r in batch_reqs])
        max_new = max(r.max_new for r in batch_reqs)

        t0 = time.perf_counter()
        if self.recorder is not None:
            self.recorder.record_step(f"prefill[b{B}xL{L}]", self.cfg, B, L, L)
        batch = {"tokens": toks, **self._extra_inputs(B, jax.random.PRNGKey(1))}
        logits, caches = self._prefill(self.params, batch)
        caches = T.pad_cache(caches, self.cfg, L + max_new)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(17)
        outputs: list[list[int]] = [[] for _ in range(B)]
        t0 = time.perf_counter()
        cur = self._sample(logits, batch_reqs, key)
        for i in range(B):
            outputs[i].append(int(cur[i]))
        for step in range(max_new - 1):
            pos = jnp.full((B,), L + step, jnp.int32)
            if self.recorder is not None:
                # the step attends the prompt plus every generated token
                # including the one being written at pos
                self.recorder.record_step(
                    f"decode@{L + step}", self.cfg, B, 1, L + step + 1
                )
            logits, caches = self._decode(self.params, caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = self._sample(logits, batch_reqs, sub)
            for i in range(B):
                if len(outputs[i]) < batch_reqs[i].max_new:
                    outputs[i].append(int(cur[i]))
        jax.block_until_ready(cur)
        decode_s = time.perf_counter() - t0
        return [
            Result(r.rid, outputs[i], prefill_s, decode_s)
            for i, r in enumerate(batch_reqs)
        ]

    def _sample(self, logits, reqs, key):
        logits = logits[:, : self.cfg.vocab_size]
        temps = jnp.asarray([r.temperature for r in reqs])[:, None]
        greedy = jnp.argmax(logits, axis=-1)
        sampled = jax.random.categorical(key, logits / jnp.maximum(temps, 1e-3))
        return jnp.where(temps[:, 0] > 0, sampled, greedy).astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next write position (absolute, excl. meta)
    emitted: Optional[list] = None
    cur: int = 0  # last sampled token

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatchingEngine:
    """In-flight batching: a fixed pool of decode slots steps in lock-step;
    finished requests free their slot and waiting requests are admitted at
    the next step boundary (each admission prefills into its slot's region
    of the shared KV cache). This is the vLLM/Orca-style scheduler shape on
    top of the same pjit-able decode step.

    Implementation notes for the single-process reference: the shared cache
    is (B_slots, max_len, ...); per-slot prefill recomputes the prompt with
    the slot's row batched alone and writes its KV into the slot row
    (dynamic_update_slice), so running requests are never interrupted.
    """

    def __init__(self, cfg: ArchConfig, *, slots: int = 4, max_len: int = 128,
                 params=None, seed: int = 0, recorder=None):
        assert cfg.family not in ("ssm", "hybrid", "audio", "vlm"), (
            "reference continuous-batching engine supports KV-cache LMs"
        )
        self.cfg = cfg
        self.api = build_model(cfg)
        self.params = params if params is not None else self.api.init(jax.random.PRNGKey(seed))
        self.max_len = max_len
        self.recorder = recorder
        self.slots = [_Slot() for _ in range(slots)]
        self.caches = self.api.init_cache(slots, max_len)
        self.queue: list[Request] = []
        self.done: list[Result] = []
        self._decode = jax.jit(self.api.decode, donate_argnums=(1,))
        self._prefill = jax.jit(self.api.prefill)
        self._key = jax.random.PRNGKey(seed + 1)

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue.pop(0)
            L = len(req.prompt)
            if self.recorder is not None:
                # per-slot admission prefills recompute the prompt alone
                self.recorder.record_step(f"admit#{req.rid}[L{L}]", self.cfg, 1, L, L)
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
            logits, cache1 = self._prefill(self.params, batch)
            cache1 = T.pad_cache(cache1, self.cfg, self.max_len)
            # copy this request's KV rows into slot i of the shared cache
            # (supported families' cache leaves are (n_layers, B, S, H, D):
            # the slot axis is always 1)
            self.caches = jax.tree.map(
                lambda full, one: full.at[:, i].set(one[:, 0]),
                self.caches,
                cache1,
            )
            self._key, sub = jax.random.split(self._key)
            tok = self._sample_one(logits[0], req, sub)
            slot.req, slot.pos, slot.emitted, slot.cur = req, L, [tok], tok

    def _sample_one(self, logits, req, key) -> int:
        logits = logits[: self.cfg.vocab_size]
        if req.temperature > 0:
            return int(jax.random.categorical(key, logits / req.temperature))
        return int(jnp.argmax(logits))

    def step(self):
        """One scheduler tick: admit, decode all active slots, retire."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return False
        toks = jnp.asarray([s.cur if not s.free else 0 for s in self.slots], jnp.int32)
        pos = jnp.asarray(
            [min(s.pos, self.max_len - 1) for s in self.slots], jnp.int32
        )
        if self.recorder is not None:
            # lock-step decode launches over the full slot pool; the padded
            # batch attends up to the most advanced active position
            kv = max(min(self.slots[i].pos, self.max_len - 1) for i in active) + 1
            self.recorder.record_step(
                f"tick[{len(active)}/{len(self.slots)}]",
                self.cfg, len(self.slots), 1, kv,
            )
        logits, self.caches = self._decode(self.params, self.caches, toks, pos)
        for i in active:
            s = self.slots[i]
            self._key, sub = jax.random.split(self._key)
            tok = self._sample_one(logits[i], s.req, sub)
            s.emitted.append(tok)
            s.pos += 1
            s.cur = tok
            if len(s.emitted) >= s.req.max_new or s.pos >= self.max_len - 1:
                self.done.append(Result(s.req.rid, s.emitted, 0.0, 0.0))
                self.slots[i] = _Slot()
        return True

    def run_to_completion(self) -> list[Result]:
        while self.queue or any(not s.free for s in self.slots):
            self.step()
        out, self.done = self.done, []
        return out


