"""Scaled Matrix Multiplication (W8A8) Pallas TPU kernel — the paper's
Scaled MM family (Table V): int8 activations x int8 weights with int32 MXU
accumulation and a per-row/per-column fp32 scale dequant epilogue.

Grid (M/bm, N/bn, K/bk) with the K dimension sequential and an int32 VMEM
accumulator; (block_m, block_n, block_k) are the tuning knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import largest_divisor_block, tpu_compiler_params


def _scaled_mm_kernel(
    x_ref,  # (bm, bk) int8
    w_ref,  # (bk, bn) int8
    sx_ref,  # (bm, 1) f32 per-row activation scale
    sw_ref,  # (1, bn) f32 per-col weight scale
    o_ref,  # (bm, bn) out dtype
    acc_scr,  # (bm, bn) int32
    *,
    n_k: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(ik == n_k - 1)
    def _emit():
        deq = acc_scr[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        o_ref[...] = deq.astype(o_ref.dtype)


def scaled_mm_pallas(
    x,  # (M, K) int8
    w,  # (K, N) int8
    sx,  # (M,) f32
    sw,  # (N,) f32
    *,
    out_dtype=jnp.bfloat16,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = True,
):
    M, K = x.shape
    N = w.shape[1]
    block_m = largest_divisor_block(M, block_m)
    block_n = largest_divisor_block(N, block_n)
    block_k = largest_divisor_block(K, block_k)
    n_k = K // block_k
    return pl.pallas_call(
        functools.partial(_scaled_mm_kernel, n_k=n_k),
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w, sx[:, None].astype(jnp.float32), sw[None, :].astype(jnp.float32))
