"""Pure-jnp oracle for the W8A8 scaled matmul."""
import jax.numpy as jnp


def scaled_mm_ref(x, w, sx, sw, out_dtype=jnp.bfloat16):
    acc = jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    deq = acc.astype(jnp.float32) * sx[:, None].astype(jnp.float32) * sw[None, :].astype(jnp.float32)
    return deq.astype(out_dtype)


def quantize_rowwise(a):
    """fp -> (int8, per-row scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(a), axis=1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(a / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
