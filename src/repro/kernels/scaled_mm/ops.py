from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import largest_divisor_block
from repro.kernels.scaled_mm.kernel import scaled_mm_pallas
from repro.kernels.scaled_mm.ref import scaled_mm_ref


def grid_shape(
    M: int, K: int, N: int, *, block_m: int = 128, block_n: int = 128, block_k: int = 256
) -> tuple:
    """Static ``pallas_call`` grid of :func:`scaled_mm`: ``(M/bm, N/bn,
    K/bk)`` after largest-divisor block clamping — this kernel never
    launches a ragged grid, so (unlike flash_attention/fused_moe) the
    helper cannot raise."""
    bm = largest_divisor_block(M, block_m)
    bn = largest_divisor_block(N, block_n)
    bk = largest_divisor_block(K, block_k)
    return (M // bm, N // bn, K // bk)


def vmem_footprint(
    M: int, K: int, N: int,
    *, block_m: int = 128, block_n: int = 128, block_k: int = 256, out_dtype_bytes: int = 2,
) -> int:
    """Peak VMEM bytes one grid step of :func:`scaled_mm` holds resident:
    double-buffered int8 ``x (bm, bk)`` / ``w (bk, bn)`` blocks, the f32
    scale vectors ``(bm, 1)``/``(1, bn)``, the ``(bm, bn)`` output block
    in ``out_dtype``, plus the int32 accumulator scratch."""
    bm = largest_divisor_block(M, block_m)
    bn = largest_divisor_block(N, block_n)
    bk = largest_divisor_block(K, block_k)
    blocks = bm * bk * 1 + bk * bn * 1 + (bm + bn) * 4 + bm * bn * out_dtype_bytes
    scratch = bm * bn * 4
    return 2 * blocks + scratch


@partial(jax.jit, static_argnames=("out_dtype", "block_m", "block_n", "block_k",
                                   "interpret", "use_pallas"))
def scaled_mm(x, w, sx, sw, *, out_dtype=jnp.bfloat16, block_m=128, block_n=128,
              block_k=256, interpret=True, use_pallas=True):
    if not use_pallas:
        return scaled_mm_ref(x, w, sx, sw, out_dtype)
    return scaled_mm_pallas(
        x, w, sx, sw, out_dtype=out_dtype,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
