from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.scaled_mm.kernel import scaled_mm_pallas
from repro.kernels.scaled_mm.ref import scaled_mm_ref


@partial(jax.jit, static_argnames=("out_dtype", "block_m", "block_n", "block_k",
                                   "interpret", "use_pallas"))
def scaled_mm(x, w, sx, sw, *, out_dtype=jnp.bfloat16, block_m=128, block_n=128,
              block_k=256, interpret=True, use_pallas=True):
    if not use_pallas:
        return scaled_mm_ref(x, w, sx, sw, out_dtype)
    return scaled_mm_pallas(
        x, w, sx, sw, out_dtype=out_dtype,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
