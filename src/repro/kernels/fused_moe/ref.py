"""Pure-jnp oracle for the fused MoE grouped-GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_moe_ref(x, w_gate, w_up, w_down):
    x32 = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", x32, w_gate.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", x32, w_up.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(jnp.float32))
    return y.astype(x.dtype)
