"""Fused MoE grouped-GEMM Pallas TPU kernel — the paper's §VII case study.

Computes, for every expert e over its gathered token block x_e (capacity C):

    y_e = (silu(x_e @ w_gate[e]) * (x_e @ w_up[e])) @ w_down[e]

in one kernel: grid (E, C/block_m, F/block_f) with the down-projection
accumulated across the (sequential) F dimension in a VMEM scratch — the TPU
analogue of the SGLang Triton fused-MoE kernel whose BLOCK_SIZE / num_warps /
num_stages the paper autotunes. Here the tunable knobs are (block_m,
block_f); the ``repro.tune`` autotuner derives exactly this space from the
ops signature, pre-filters it through the static SP2xx lint, and measures
the predictor-ranked top-k (benchmarks/bench_perf_gap.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _moe_kernel(
    x_ref,  # (1, block_m, D)
    wg_ref,  # (1, D, block_f)
    wu_ref,  # (1, D, block_f)
    wd_ref,  # (1, block_f, D)
    o_ref,  # (1, block_m, D)
    acc_scr,  # (block_m, D) f32
    *,
    n_f: int,
):
    jf = pl.program_id(2)

    @pl.when(jf == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)
    g = jax.lax.dot_general(
        x, wg_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    u = jax.lax.dot_general(
        x, wu_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h = jax.nn.silu(g) * u  # (block_m, block_f)
    acc_scr[...] += jax.lax.dot_general(
        h, wd_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(jf == n_f - 1)
    def _emit():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def fused_moe_pallas(
    x,  # (E, C, D) gathered per-expert token blocks
    w_gate,  # (E, D, F)
    w_up,  # (E, D, F)
    w_down,  # (E, F, D)
    *,
    block_m: int = 128,
    block_f: int = 256,
    interpret: bool = True,
):
    E, C, D = x.shape
    F = w_gate.shape[2]
    block_m = min(block_m, C)
    block_f = min(block_f, F)
    assert C % block_m == 0 and F % block_f == 0
    n_m, n_f = C // block_m, F // block_f

    kernel = functools.partial(_moe_kernel, n_f=n_f)
    return pl.pallas_call(
        kernel,
        grid=(E, n_m, n_f),
        in_specs=[
            pl.BlockSpec((1, block_m, D), lambda e, im, jf: (e, im, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, im, jf: (e, 0, jf)),
            pl.BlockSpec((1, D, block_f), lambda e, im, jf: (e, 0, jf)),
            pl.BlockSpec((1, block_f, D), lambda e, im, jf: (e, jf, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, D), lambda e, im, jf: (e, im, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
