"""jit'd wrapper for the fused MoE kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_moe.kernel import fused_moe_pallas
from repro.kernels.fused_moe.ref import fused_moe_ref


def grid_shape(E: int, C: int, D: int, F: int, *, block_m: int = 128, block_f: int = 256) -> tuple:
    """Static ``pallas_call`` grid of :func:`fused_moe`: ``(E, C/block_m,
    F/block_f)`` after the ``min(block, dim)`` clamp. Raises ``ValueError``
    where the kernel would fail its divisibility assert."""
    bm, bf = min(block_m, C), min(block_f, F)
    if C % bm or F % bf:
        raise ValueError(
            f"fused_moe: C={C} %% block_m={bm} or F={F} %% block_f={bf} != 0 "
            f"(non-divisible tiling)"
        )
    return (E, C // bm, F // bf)


def vmem_footprint(
    E: int, C: int, D: int, F: int,
    *, block_m: int = 128, block_f: int = 256, dtype_bytes: int = 2,
) -> int:
    """Peak VMEM bytes one grid step of :func:`fused_moe` holds resident:
    double-buffered blocks ``x (bm, D)``, ``w_gate/w_up (D, bf)``,
    ``w_down (bf, D)``, ``out (bm, D)`` plus the f32 ``(bm, D)``
    accumulator scratch. The auditor's VMEM-overflow lint (SP201) compares
    this against ``TPUSpec.vmem_mb`` before any compile."""
    bm, bf = min(block_m, C), min(block_f, F)
    blocks = (bm * D + 2 * D * bf + bf * D + bm * D) * dtype_bytes
    scratch = bm * D * 4
    return 2 * blocks + scratch


@partial(jax.jit, static_argnames=("block_m", "block_f", "interpret", "use_pallas"))
def fused_moe(
    x, w_gate, w_up, w_down, *, block_m=128, block_f=256, interpret=True, use_pallas=True
):
    if not use_pallas:
        return fused_moe_ref(x, w_gate, w_up, w_down)
    return fused_moe_pallas(
        x, w_gate, w_up, w_down, block_m=block_m, block_f=block_f, interpret=interpret
    )
