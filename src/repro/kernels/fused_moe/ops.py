"""jit'd wrapper for the fused MoE kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_moe.kernel import fused_moe_pallas
from repro.kernels.fused_moe.ref import fused_moe_ref


@partial(jax.jit, static_argnames=("block_m", "block_f", "interpret", "use_pallas"))
def fused_moe(
    x, w_gate, w_up, w_down, *, block_m=128, block_f=256, interpret=True, use_pallas=True
):
    if not use_pallas:
        return fused_moe_ref(x, w_gate, w_up, w_down)
    return fused_moe_pallas(
        x, w_gate, w_up, w_down, block_m=block_m, block_f=block_f, interpret=interpret
    )
