# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across jax versions: the class is
    ``pltpu.CompilerParams`` on jax >= 0.5 and ``pltpu.TPUCompilerParams``
    on jax 0.4.x (same keyword surface for what we use)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
