# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across jax versions: the class is
    ``pltpu.CompilerParams`` on jax >= 0.5 and ``pltpu.TPUCompilerParams``
    on jax 0.4.x (same keyword surface for what we use)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def largest_divisor_block(total: int, block: int) -> int:
    """Largest divisor of ``total`` that is ``<= block`` (and >= 1).

    The block-clamping rule shared by the scaled_mm / rmsnorm / silu_mul
    kernels and their static ``grid_shape``/``vmem_footprint`` helpers:
    these kernels never launch a ragged grid — they shrink the block until
    it divides the dimension. (flash_attention and fused_moe instead
    *assert* divisibility after a plain ``min`` clamp; their helpers raise
    ``ValueError`` where the kernel would assert.)"""
    block = min(block, total)
    return next(b for b in range(block, 0, -1) if total % b == 0)
