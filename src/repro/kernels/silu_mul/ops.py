from functools import partial

import jax

from repro.kernels.silu_mul.kernel import silu_mul_pallas
from repro.kernels.silu_mul.ref import silu_mul_ref


@partial(jax.jit, static_argnames=("act", "block_rows", "interpret", "use_pallas"))
def act_mul(g, u, *, act="silu", block_rows=256, interpret=True, use_pallas=True):
    if not use_pallas:
        return silu_mul_ref(g, u, act=act)
    return silu_mul_pallas(g, u, act=act, block_rows=block_rows, interpret=interpret)
