from functools import partial

import jax

from repro.kernels import largest_divisor_block
from repro.kernels.silu_mul.kernel import silu_mul_pallas
from repro.kernels.silu_mul.ref import silu_mul_ref


def grid_shape(R: int, d: int, *, block_rows: int = 128) -> tuple:
    """Static ``pallas_call`` grid of :func:`act_mul` over ``R`` flattened
    rows: ``(R/block,)`` after largest-divisor clamping (never ragged)."""
    return (R // largest_divisor_block(R, block_rows),)


def vmem_footprint(R: int, d: int, *, block_rows: int = 128, dtype_bytes: int = 2) -> int:
    """Peak VMEM bytes one grid step of :func:`act_mul` holds resident:
    double-buffered ``g``/``u``/``out`` blocks of ``(rows, d)`` each (no
    scratch)."""
    rows = largest_divisor_block(R, block_rows)
    return 2 * (3 * rows * d) * dtype_bytes


@partial(jax.jit, static_argnames=("act", "block_rows", "interpret", "use_pallas"))
def act_mul(g, u, *, act="silu", block_rows=128, interpret=True, use_pallas=True):
    if not use_pallas:
        return silu_mul_ref(g, u, act=act)
    return silu_mul_pallas(g, u, act=act, block_rows=block_rows, interpret=interpret)
