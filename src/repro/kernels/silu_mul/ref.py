"""Pure-jnp oracle for SiLU&Mul / GeGLU&Mul."""
import jax
import jax.numpy as jnp


def silu_mul_ref(g, u, *, act: str = "silu"):
    g32, u32 = g.astype(jnp.float32), u.astype(jnp.float32)
    h = jax.nn.gelu(g32, approximate=True) if act == "geglu" else jax.nn.silu(g32)
    return (h * u32).astype(g.dtype)
