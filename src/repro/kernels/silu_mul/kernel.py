"""Fused SiLU&Mul (SwiGLU gate) Pallas TPU kernel — elementwise VPU + EX2."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import largest_divisor_block


def _silu_mul_kernel(g_ref, u_ref, o_ref, *, act: str):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    if act == "geglu":
        h = jax.nn.gelu(g, approximate=True)
    else:
        h = jax.nn.silu(g)
    o_ref[...] = (h * u).astype(o_ref.dtype)


def silu_mul_pallas(g, u, *, act: str = "silu", block_rows: int = 128, interpret: bool = True):
    orig_shape = g.shape
    d = g.shape[-1]
    gf, uf = g.reshape(-1, d), u.reshape(-1, d)
    R = gf.shape[0]
    block_rows = largest_divisor_block(R, block_rows)
    out = pl.pallas_call(
        functools.partial(_silu_mul_kernel, act=act),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), g.dtype),
        interpret=interpret,
    )(gf, uf)
    return out.reshape(orig_shape)
