"""FlashAttention-2-style Pallas TPU kernel.

TPU adaptation of the paper's Attention kernel family (Table V): online
softmax over KV blocks with VMEM accumulators. The grid's last dimension
(KV blocks) is sequential on a TensorCore, so the running (m, l, acc) state
lives in VMEM scratch across grid steps — the TPU analogue of FA2's
per-CTA streaming loop. Causal and sliding-window masking skip fully-masked
KV blocks via pl.when (the tile-level workload variance the paper's
Scheduling Simulator models).

Layouts: q is passed as (BKG, S, D) where BKG = batch * kv_heads * group
(GQA flattened); k/v as (BK, Skv, D). Block sizes (block_q, block_k) are the
kernel's autotuning knobs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1.0e30


def _fa_kernel(
    q_ref,  # (1, block_q, D)
    k_ref,  # (1, block_k, D)
    v_ref,  # (1, block_k, D)
    o_ref,  # (1, block_q, D)
    m_scr,  # (block_q, 1) f32
    l_scr,  # (block_q, 1) f32
    acc_scr,  # (block_q, D) f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    block_q: int,
    block_k: int,
    n_k: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # skip KV blocks that are entirely masked out (causal upper triangle /
    # outside the sliding window) — tile-level work skipping, FA2-style
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = corr * acc_scr[...] + pv
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _emit():
        l = l_scr[...]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0] = o.astype(o_ref.dtype)


def flash_attention_pallas(
    q,  # (BKG, S, D)
    k,  # (BK, Skv, D)
    v,
    *,
    group: int,  # q rows per kv head (BKG = BK * group)
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    BKG, S, D = q.shape
    BK, Skv, _ = k.shape
    assert BKG == BK * group
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    assert S % block_q == 0 and Skv % block_k == 0
    n_q, n_k = S // block_q, Skv // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(BKG, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik, g=group: (b // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BKG, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
