"""Pure-jnp oracle for the flash-attention kernel.

Reuses the model stack's chunked attention (single source of truth for
numerics): a dense masked-softmax attention over the same layout the kernel
consumes."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention_ref(
    q,  # (BKG, S, D)
    k,  # (BK, Skv, D)
    v,
    *,
    group: int,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
):
    BKG, S, D = q.shape
    BK, Skv, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kx = jnp.repeat(k, group, axis=0)
    vx = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, -1.0e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, vx.astype(jnp.float32)).astype(q.dtype)
