"""jit'd wrapper: model-layout (B, S, H, D) GQA attention dispatching to the
Pallas kernel (TPU) or the jnp reference (CPU / dry-run tracing)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def grid_shape(
    B: int, S: int, Skv: int, Hq: int, Hkv: int, D: int,
    *, block_q: int = 128, block_k: int = 128,
) -> tuple:
    """Static ``pallas_call`` grid of :func:`attention`: ``(BKG, n_q, n_k)``
    where ``BKG = B * Hkv * (Hq // Hkv)``. Raises ``ValueError`` exactly
    where the kernel would fail its divisibility assert (after the
    ``min(block, dim)`` clamp) — the contract ``repro.analysis`` lints
    before any compile."""
    bq, bk = min(block_q, S), min(block_k, Skv)
    if S % bq or Skv % bk:
        raise ValueError(
            f"flash_attention: S={S} %% block_q={bq} or Skv={Skv} %% "
            f"block_k={bk} != 0 (non-divisible tiling)"
        )
    return (B * Hkv * (Hq // Hkv), S // bq, Skv // bk)


def vmem_footprint(
    B: int, S: int, Skv: int, Hq: int, Hkv: int, D: int,
    *, block_q: int = 128, block_k: int = 128, dtype_bytes: int = 2,
) -> int:
    """Peak VMEM bytes one grid step of :func:`attention` holds resident:
    the double-buffered in/out BlockSpec blocks (Mosaic pipelines the next
    tile's DMA while computing, so every block is resident twice) plus the
    f32 scratch accumulators ``(block_q, 1) x2 + (block_q, D)``. Mirrors
    the kernel's BlockSpecs exactly; pinned by ``tests/test_analysis.py``."""
    bq, bk = min(block_q, S), min(block_k, Skv)
    blocks = (bq * D + 2 * bk * D + bq * D) * dtype_bytes  # q, k, v, out
    scratch = (bq * 1 + bq * 1 + bq * D) * 4
    return 2 * blocks + scratch


@partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret", "use_pallas",
    ),
)
def attention(
    q,  # (B, S, Hq, D)
    k,  # (B, Skv, Hkv, D)
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    use_pallas: bool = True,
):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = (
        q.reshape(B, S, Hkv, G, D)
        .transpose(0, 2, 3, 1, 4)
        .reshape(B * Hkv * G, S, D)
    )
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    fn = flash_attention_pallas if use_pallas else flash_attention_ref
    kw = dict(group=G, causal=causal, window=window, softcap=softcap)
    if use_pallas:
        kw.update(block_q=block_q, block_k=block_k, interpret=interpret)
    of = fn(qf, kf, vf, **kw)
    return (
        of.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)
    )
