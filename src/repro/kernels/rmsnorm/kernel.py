"""Fused RMSNorm Pallas TPU kernel (VPU + rsqrt transcendental)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import largest_divisor_block


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o = x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    o_ref[...] = o.astype(o_ref.dtype)


def rmsnorm_pallas(x, w, *, eps: float = 1e-6, block_rows: int = 256, interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    R = xf.shape[0]
    block_rows = largest_divisor_block(R, block_rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out.reshape(orig_shape)
