"""Pure-jnp oracle: re-exports the model stack's RMSNorm."""
from repro.models.layers import rmsnorm as rmsnorm_ref  # noqa: F401
