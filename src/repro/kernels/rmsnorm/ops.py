from functools import partial

import jax

from repro.kernels import largest_divisor_block
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def grid_shape(R: int, d: int, *, block_rows: int = 256) -> tuple:
    """Static ``pallas_call`` grid of :func:`rmsnorm` over ``R`` flattened
    rows: ``(R/block,)`` after largest-divisor clamping (never ragged)."""
    return (R // largest_divisor_block(R, block_rows),)


def vmem_footprint(R: int, d: int, *, block_rows: int = 256, dtype_bytes: int = 2) -> int:
    """Peak VMEM bytes one grid step of :func:`rmsnorm` holds resident:
    double-buffered ``x (rows, d)`` / ``w (d,)`` / ``out (rows, d)``
    blocks (no scratch)."""
    rows = largest_divisor_block(R, block_rows)
    return 2 * (rows * d + d + rows * d) * dtype_bytes


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret", "use_pallas"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=True, use_pallas=True):
    if not use_pallas:
        return rmsnorm_ref(x, w, eps)
    return rmsnorm_pallas(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
