from functools import partial

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret", "use_pallas"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=True, use_pallas=True):
    if not use_pallas:
        return rmsnorm_ref(x, w, eps)
    return rmsnorm_pallas(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
