"""Data pipelines: deterministic synthetic LM batches with host sharding."""
