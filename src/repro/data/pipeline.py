"""Deterministic synthetic data pipeline.

Serves token batches (plus stubbed modality-frontend embeddings) with:
  * deterministic content as a pure function of (seed, step) — restartable
    from any step without replaying history (fault-tolerant resume);
  * per-host sharding hooks (process_index/process_count) so the same code
    drives multi-host data loading;
  * background prefetch of the next batch.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1


class SyntheticLM:
    """Markov-ish synthetic token stream: deterministic per (seed, step).

    Tokens follow a skewed unigram distribution with local repetition
    structure so the loss actually decreases during training (unlike pure
    uniform noise)."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        assert data.batch % data.process_count == 0
        self.local_batch = data.batch // data.process_count

    def batch_at(self, step: int) -> dict:
        d = self.data
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, d.process_index])
        )
        B, S, V = self.local_batch, d.seq_len, self.cfg.vocab_size
        # skewed unigram (zipf-ish) base stream
        base = rng.zipf(1.5, size=(B, S)).astype(np.int64)
        tokens = (base % (V - 3)) + 3
        # inject copy structure: second half repeats first half shifted
        half = S // 2
        tokens[:, half:] = tokens[:, : S - half]
        tokens[:, 0] = 1  # BOS
        out = {"tokens": tokens.astype(np.int32)}
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (B, self.cfg.enc_frames, self.cfg.d_model), dtype=np.float32
            ).astype(np.float32) * 0.1
        if self.cfg.family == "vlm":
            out["image_embeds"] = rng.standard_normal(
                (B, self.cfg.n_img_tokens, self.cfg.d_model), dtype=np.float32
            ).astype(np.float32) * 0.1
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """One-batch-lookahead background prefetch."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return batch

    def close(self):
        self._stop.set()
