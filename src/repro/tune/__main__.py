"""``python -m repro.tune --kernel fused_moe --hw tpu-v4`` — tune one
real Pallas kernel and print (or save) the decision trail: candidate count,
SP2xx rejections, predicted ranking, timed top-k, realized speedup, and the
predicted-vs-measured rank correlation."""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.core.hardware import REGISTRY
from repro.predict.backends import PREDICTORS, get_predictor
from repro.tune.space import DEFAULT_WORKLOADS, TUNABLE_KERNELS, arch_workload
from repro.tune.tuner import TunedConfigs, tune


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Predictor-guided autotuning of the repo's Pallas kernels.",
    )
    ap.add_argument("--kernel", required=True, choices=sorted(TUNABLE_KERNELS))
    ap.add_argument("--hw", default="tpu-v4", choices=sorted(REGISTRY))
    ap.add_argument(
        "--predictor",
        default="roofline",
        choices=sorted(PREDICTORS),
        help="ranking backend (roofline needs no training; synperf needs a "
        "trained estimator in the bench cache)",
    )
    ap.add_argument("--top-k", type=int, default=4, help="candidates to measure")
    ap.add_argument("--repeats", type=int, default=3, help="timed runs per candidate")
    ap.add_argument(
        "--arch",
        default=None,
        help="derive the workload shape from a registry arch's prefill step "
        "instead of the CPU-scale default",
    )
    ap.add_argument(
        "--dim",
        action="append",
        default=[],
        metavar="NAME=INT",
        help="override a workload dimension (repeatable), e.g. --dim E=16",
    )
    ap.add_argument("--json", default=None, help="write the report summary to this path")
    ap.add_argument(
        "--save", default=None, help="write/update a TunedConfigs table at this path"
    )
    args = ap.parse_args(argv)

    hw = REGISTRY[args.hw]
    workload = (
        arch_workload(args.kernel, args.arch)
        if args.arch
        else dict(DEFAULT_WORKLOADS[args.kernel])
    )
    for item in args.dim:
        name, _, val = item.partition("=")
        if name not in workload:
            ap.error(f"--dim {name!r} is not a dimension of {sorted(workload)}")
        workload[name] = int(val)

    predictor = get_predictor(args.predictor, hw)
    report = tune(
        args.kernel,
        hw,
        workload=workload,
        predictor=predictor,
        predictor_name=args.predictor,
        top_k=args.top_k,
        repeats=args.repeats,
    )

    s = report.summary()
    mode = "interpret" if report.interpret else "compiled"
    print(f"[tune] {report.kernel} on {report.hw} ({mode}, ranked by {report.predictor})")
    print(f"  workload        {report.workload}")
    print(
        f"  candidates      {report.n_candidates} enumerated, "
        f"{report.n_rejected} rejected by SP2xx, "
        f"{len(report.survivors)} ranked, {len(report.measured)} measured"
    )
    for c in report.measured:
        tag = " <- best" if c is report.best else ""
        print(
            f"    {c.blocks}  predicted={c.predicted_s*1e3:8.3f}ms  "
            f"measured={(c.measured_s or 0.0)*1e3:8.3f}ms{tag}"
        )
    print(f"  default {report.default_blocks}  measured={report.t_default*1e3:.3f}ms")
    print(
        f"  best    {report.best.blocks}  speedup={report.speedup:.2f}x  "
        f"rank_correlation={report.rank_correlation:+.2f}"
    )

    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=2, sort_keys=True)
        print(f"  wrote {args.json}")
    if args.save:
        try:
            table = TunedConfigs.load(args.save)
        except FileNotFoundError:
            table = TunedConfigs()
        table.add_report(report)
        table.save(args.save)
        print(f"  wrote {args.save}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
