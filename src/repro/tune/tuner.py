"""Predictor-driven Pallas kernel autotuning (paper §VII-C, "beyond
simulation").

The loop the paper argues the predictor is *for*: enumerate candidate block
configs (signature-derived, :mod:`repro.tune.space`), drop everything the
static SP201-SP203 geometry lint would reject (nothing the auditor flags is
ever launched), rank the survivors with a :class:`~repro.predict.api.Predictor`
(each candidate's blocks ride into the decomposer as workload keys, so
tiling, alignment, and working sets all respond), then spend real execution
time only on the predicted top-k — timed ``pallas_call`` runs, interpret-mode
on CPU CI, real device timing when an accelerator is attached.

Two measurement substrates share the loop:

* :func:`tune` — the real kernels (``kernels/*/ops.py``), wall-clock timed;
* :func:`tune_workload` — the hwsim oracle as "hardware", for the
  dataset-scale §VII-C experiment (``benchmarks/bench_perf_gap.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hwsim
from repro.core.hardware import REGISTRY, TPUSpec
from repro.predict.api import KernelCall, Predictor
from repro.tune.space import (
    DEFAULT_WORKLOADS,
    block_params,
    candidate_space,
    decomposer_workload,
    enumerate_candidates,
    kernel_entry,
    predict_kind,
)

__all__ = [
    "Candidate",
    "TuneReport",
    "TuneResult",
    "TunedConfigs",
    "geomean_speedup",
    "grid_steps",
    "measure",
    "pearson",
    "prefilter",
    "rank_candidates",
    "spearman",
    "tune",
    "tune_underperformers",
    "tune_workload",
]


# ----------------------------------------------------------------------
# statistics helpers
# ----------------------------------------------------------------------


def pearson(x: Sequence[float], y: Sequence[float]) -> float:
    xa, ya = np.asarray(x, float), np.asarray(y, float)
    if len(xa) < 2 or xa.std() == 0 or ya.std() == 0:
        return 0.0
    return float(np.corrcoef(xa, ya)[0, 1])


def _ranks(x: Sequence[float]) -> np.ndarray:
    a = np.asarray(x, float)
    order = np.argsort(a, kind="stable")
    r = np.empty(len(a), float)
    r[order] = np.arange(len(a), dtype=float)
    return r


def spearman(x: Sequence[float], y: Sequence[float]) -> float:
    """Rank correlation — the predicted-vs-measured ordering score."""
    if len(x) < 2:
        return 0.0
    return pearson(_ranks(x), _ranks(y))


def geomean_speedup(results: Sequence["TuneResult"]) -> float:
    if not results:
        return 1.0
    return float(np.exp(np.mean([np.log(r.speedup) for r in results])))


# ----------------------------------------------------------------------
# candidate pipeline: prefilter -> rank -> measure
# ----------------------------------------------------------------------


@dataclasses.dataclass
class Candidate:
    """One block config moving through the tuning pipeline."""

    blocks: Dict[str, int]
    predicted_s: float = float("nan")
    ceiling_s: float = float("nan")
    measured_s: Optional[float] = None
    grid_steps: Optional[int] = None

    @property
    def predicted_gap(self) -> float:
        """Predicted headroom above the analytical ceiling (>= 1)."""
        if not np.isfinite(self.ceiling_s) or self.ceiling_s <= 0:
            return float("nan")
        return self.predicted_s / self.ceiling_s


def grid_steps(kernel: str, kw: Dict[str, int], blocks: Dict[str, int]) -> int:
    """Total ``pallas_call`` grid steps the candidate launches."""
    from repro.analysis.kernels import KERNEL_HELPERS

    grid_fn, _ = KERNEL_HELPERS[kernel]
    return int(np.prod(grid_fn(**kw, **blocks)))


def prefilter(
    kernel: str,
    kw: Dict[str, int],
    candidates: Sequence[Dict[str, int]],
    *,
    hws: Optional[Sequence[TPUSpec]] = None,
    dtype_bytes: int = 2,
) -> Tuple[List[Candidate], List[Tuple[Dict[str, int], List[Any]]]]:
    """Static SP201-SP203 lint over every candidate; returns
    ``(survivors, rejected)`` where each rejection carries its diagnostics.
    Defaults to the FULL hardware registry, so a surviving config is legal
    on every device the auditor knows — not just the tuning target."""
    from repro.analysis.kernels import check_blocks

    survivors: List[Candidate] = []
    rejected: List[Tuple[Dict[str, int], List[Any]]] = []
    for blocks in candidates:
        diags = check_blocks(kernel, kw, blocks, hws=hws, dtype_bytes=dtype_bytes)
        if diags:
            rejected.append((blocks, diags))
        else:
            survivors.append(
                Candidate(blocks=dict(blocks), grid_steps=grid_steps(kernel, kw, blocks))
            )
    return survivors, rejected


def rank_candidates(
    kernel: str,
    X: Dict[str, Any],
    candidates: List[Candidate],
    predictor: Optional[Predictor],
    hw: TPUSpec,
) -> List[Candidate]:
    """Fill ``predicted_s``/``ceiling_s`` and sort ascending by predicted
    time. ``predictor=None`` ranks with the hwsim oracle directly. The sort
    is deterministic: ties break toward larger blocks (fewer grid steps,
    cheaper launch), then by the canonical block tuple."""
    kind = predict_kind(kernel)
    for c in candidates:
        Xc = {**X, **c.blocks}
        if predictor is None:
            c.predicted_s = hwsim.simulate(kind, Xc, hw)
            c.ceiling_s = float("nan")
        else:
            est = predictor.predict([KernelCall(kind, Xc)])
            c.predicted_s = est.kernel_s
            c.ceiling_s = float("nan") if est.theoretical_s is None else est.theoretical_s
    candidates.sort(
        key=lambda c: (
            c.predicted_s,
            -sum(c.blocks.values()),
            tuple(sorted(c.blocks.items())),
        )
    )
    return candidates


# ----------------------------------------------------------------------
# real-kernel measurement
# ----------------------------------------------------------------------


def make_inputs(kernel: str, kw: Dict[str, int], seed: int = 0) -> tuple:
    """Deterministic device arrays shaped for ``kernel_entry(kernel)``."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)

    def f32(*shape: int) -> Any:
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))

    if kernel == "fused_moe":
        E, C, D, F = kw["E"], kw["C"], kw["D"], kw["F"]
        return (f32(E, C, D), f32(E, D, F), f32(E, D, F), f32(E, F, D))
    if kernel == "scaled_mm":
        M, K, N = kw["M"], kw["K"], kw["N"]
        x = jnp.asarray(rng.integers(-127, 128, (M, K), dtype=np.int8))
        w = jnp.asarray(rng.integers(-127, 128, (K, N), dtype=np.int8))
        sx = jnp.asarray(rng.uniform(0.5, 2.0, (M,)).astype(np.float32))
        sw = jnp.asarray(rng.uniform(0.5, 2.0, (N,)).astype(np.float32))
        return (x, w, sx, sw)
    if kernel == "flash_attention":
        B, S, Skv = kw["B"], kw["S"], kw["Skv"]
        Hq, Hkv, D = kw["Hq"], kw["Hkv"], kw["D"]
        return (f32(B, S, Hq, D), f32(B, Skv, Hkv, D), f32(B, Skv, Hkv, D))
    if kernel == "silu_mul":
        return (f32(kw["R"], kw["d"]), f32(kw["R"], kw["d"]))
    if kernel == "rmsnorm":
        return (f32(kw["R"], kw["d"]), f32(kw["d"]))
    raise KeyError(f"unknown kernel {kernel!r}")


def measure(
    kernel: str,
    kw: Dict[str, int],
    blocks: Dict[str, int],
    *,
    args: Optional[tuple] = None,
    repeats: int = 3,
    interpret: Optional[bool] = None,
) -> float:
    """Wall-clock seconds of one timed ``pallas_call`` execution: one
    warmup (compile) run, then min over ``repeats``. ``interpret`` defaults
    to True off-accelerator (CPU CI) and False when a real backend is up."""
    import jax

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if args is None:
        args = make_inputs(kernel, kw)
    call = functools.partial(kernel_entry(kernel), *args, interpret=interpret, **blocks)
    jax.block_until_ready(call())  # warmup: compile/trace outside the clock
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        best = min(best, time.perf_counter() - t0)
    return best


# ----------------------------------------------------------------------
# the full loop over real kernels
# ----------------------------------------------------------------------


@dataclasses.dataclass
class TuneReport:
    """Everything one :func:`tune` run decided and observed."""

    kernel: str
    hw: str
    workload: Dict[str, int]
    default_blocks: Dict[str, int]
    n_candidates: int
    n_rejected: int
    survivors: List[Candidate]  # ranked, predicted_s filled
    measured: List[Candidate]  # the launched subset (default first)
    best: Candidate
    t_default: float
    interpret: bool
    predictor: str

    @property
    def speedup(self) -> float:
        assert self.best.measured_s is not None
        return self.t_default / self.best.measured_s

    @property
    def rank_correlation(self) -> float:
        """Spearman between predicted and measured times over the launched
        set — the paper's 'predictor as optimization oracle' score."""
        pts = [
            (c.predicted_s, c.measured_s)
            for c in self.measured
            if c.measured_s is not None and np.isfinite(c.predicted_s)
        ]
        if len(pts) < 2:
            return 0.0
        return spearman([p for p, _ in pts], [m for _, m in pts])

    def summary(self) -> Dict[str, Any]:
        return {
            "kernel": self.kernel,
            "hw": self.hw,
            "workload": self.workload,
            "default_blocks": self.default_blocks,
            "best_blocks": self.best.blocks,
            "t_default_s": self.t_default,
            "t_best_s": self.best.measured_s,
            "speedup": self.speedup,
            "rank_correlation": self.rank_correlation,
            "n_candidates": self.n_candidates,
            "n_rejected": self.n_rejected,
            "n_measured": len(self.measured),
            "interpret": self.interpret,
            "predictor": self.predictor,
        }


def tune(
    kernel: str,
    hw: TPUSpec,
    *,
    workload: Optional[Dict[str, int]] = None,
    predictor: Optional[Predictor] = None,
    predictor_name: str = "",
    top_k: int = 4,
    repeats: int = 3,
    space: Optional[Dict[str, Sequence[int]]] = None,
    interpret: Optional[bool] = None,
    measure_fn: Optional[Callable[..., float]] = None,
    dtype_bytes: int = 2,
) -> TuneReport:
    """Tune one real Pallas kernel on one workload shape.

    Enumerates the signature-derived space, prefilters via SP2xx against
    the full registry, ranks with ``predictor`` (hwsim oracle when None),
    measures the predicted top-k plus the signature-default config, and
    returns the full :class:`TuneReport`. ``measure_fn`` swaps the timing
    substrate (tests stub it to keep CI fast)."""
    kw = dict(workload if workload is not None else DEFAULT_WORKLOADS[kernel])
    defaults = block_params(kernel)
    cands = enumerate_candidates(kernel, space)
    survivors, rejected = prefilter(kernel, kw, cands, dtype_bytes=dtype_bytes)
    if not survivors:
        raise ValueError(
            f"no {kernel} candidate survives the SP2xx prefilter on workload {kw} "
            f"({len(rejected)} rejected) — widen the space or change the shape"
        )
    X = decomposer_workload(kernel, kw)
    rank_candidates(kernel, X, survivors, predictor, hw)

    mfn = measure_fn if measure_fn is not None else measure
    args = make_inputs(kernel, kw) if measure_fn is None else None
    # default config measured first: the speedup denominator, and — when it
    # also appears among survivors — an extra rank-correlation point
    t_default = mfn(kernel, kw, defaults, args=args, repeats=repeats, interpret=interpret)
    measured: List[Candidate] = []
    for c in survivors[: max(1, top_k)]:
        c.measured_s = (
            t_default
            if c.blocks == defaults
            else mfn(kernel, kw, c.blocks, args=args, repeats=repeats, interpret=interpret)
        )
        measured.append(c)
    best = min(measured, key=lambda c: c.measured_s or float("inf"))

    import jax

    return TuneReport(
        kernel=kernel,
        hw=hw.name,
        workload=kw,
        default_blocks=defaults,
        n_candidates=len(cands),
        n_rejected=len(rejected),
        survivors=survivors,
        measured=measured,
        best=best,
        t_default=t_default,
        interpret=(jax.default_backend() == "cpu") if interpret is None else interpret,
        predictor=predictor_name or (type(predictor).__name__ if predictor else "oracle"),
    )


# ----------------------------------------------------------------------
# TunedConfigs: the table serve engines / core.e2e consume
# ----------------------------------------------------------------------


@dataclasses.dataclass
class TunedConfigs:
    """Tuned block choices keyed ``hw name -> kernel family -> blocks``.

    The family key is the *predictor* kind (``attention``, not
    ``flash_attention``) so ``core.e2e.model_calls(..., tuned=...)`` can
    merge blocks into matching :class:`KernelCall` workloads directly."""

    configs: Dict[str, Dict[str, Dict[str, int]]] = dataclasses.field(default_factory=dict)

    def set(self, hw: str, kind: str, blocks: Dict[str, int]) -> None:
        self.configs.setdefault(hw, {})[kind] = {k: int(v) for k, v in blocks.items()}

    def add_report(self, report: TuneReport) -> None:
        self.set(report.hw, predict_kind(report.kernel), report.best.blocks)

    def for_hw(self, hw: str | TPUSpec) -> Dict[str, Dict[str, int]]:
        """``{kernel family: blocks}`` for one device — the ``tuned=``
        argument of ``core.e2e.model_calls`` / the serve engines."""
        name = hw.name if isinstance(hw, TPUSpec) else hw
        return {k: dict(v) for k, v in self.configs.get(name, {}).items()}

    def save(self, path: str) -> None:
        import json

        with open(path, "w") as f:
            json.dump({"tuned_configs": self.configs}, f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "TunedConfigs":
        import json

        with open(path) as f:
            payload = json.load(f)
        table = payload.get("tuned_configs", payload)
        return cls(
            configs={
                hw: {kind: {k: int(v) for k, v in blocks.items()} for kind, blocks in kinds.items()}
                for hw, kinds in table.items()
            }
        )


# ----------------------------------------------------------------------
# hwsim-substrate tuning (dataset-scale §VII-C, bench_perf_gap)
# ----------------------------------------------------------------------


@dataclasses.dataclass
class TuneResult:
    """One tuned hwsim workload (the dataset-scale experiment's unit)."""

    workload: dict
    hw: str
    t_default: float
    t_best: float
    best_config: dict
    predicted_s: Tuple[float, ...] = ()
    measured_s: Tuple[float, ...] = ()

    @property
    def speedup(self) -> float:
        return self.t_default / self.t_best

    @property
    def rank_correlation(self) -> float:
        if len(self.measured_s) < 2:
            return 0.0
        return spearman(self.predicted_s, self.measured_s)


def _moe_helper_kwargs(X: dict, blocks: Dict[str, int]) -> Dict[str, int]:
    """ops-helper kwargs for a fused-MoE *dataset* workload (decomposer X).
    Dataset rows carry no per-expert capacity, so ``C`` is set to the
    candidate's ``block_m`` — the divisibility the static lint then enforces
    is exactly the kernel's real constraint (``F % block_f``)."""
    return {
        "E": int(X["E"]),
        "C": int(blocks.get("block_m", 128)),
        "D": int(X["H"]),
        "F": int(X["N"]),
    }


def tune_workload(
    workload: dict,
    hw: TPUSpec,
    *,
    kernel: str = "fused_moe",
    predictor: Optional[Predictor] = None,
    top_k: int = 5,
    space: Optional[Dict[str, Sequence[int]]] = None,
) -> TuneResult:
    """§VII-C tuning of one hwsim dataset workload: same
    prefilter -> predictor-rank -> measure-top-k loop as :func:`tune`, with
    ``hwsim.simulate`` standing in as the hardware. ``predictor=None``
    degenerates to oracle ranking (exhaustive-equivalent, used by the
    ``core.tuner`` compatibility shim)."""
    from repro.analysis.kernels import check_blocks

    kind = predict_kind(kernel)
    t_default = hwsim.simulate(kind, workload, hw)
    survivors: List[Candidate] = []
    for blocks in enumerate_candidates(kernel, space):
        kw = _moe_helper_kwargs(workload, blocks) if kernel == "fused_moe" else blocks
        if check_blocks(kernel, kw, blocks, hws=[hw]):
            continue
        survivors.append(Candidate(blocks=dict(blocks)))
    rank_candidates(kernel, workload, survivors, predictor, hw)

    best_t, best_cfg = t_default, {}
    predicted: List[float] = []
    measured: List[float] = []
    for c in survivors[: max(1, top_k)]:
        t = (
            c.predicted_s
            if predictor is None  # oracle ranking already IS the measurement
            else hwsim.simulate(kind, workload, hw, config=c.blocks)
        )
        c.measured_s = t
        predicted.append(c.predicted_s)
        measured.append(t)
        if t < best_t:
            best_t, best_cfg = t, c.blocks
    return TuneResult(
        workload=workload,
        hw=hw.name,
        t_default=t_default,
        t_best=best_t,
        best_config=best_cfg,
        predicted_s=tuple(predicted),
        measured_s=tuple(measured),
    )


def tune_underperformers(
    ds: Any,
    under_mask: np.ndarray,
    per_hw_limit: int = 40,
    *,
    predictors: Optional[Dict[str, Predictor]] = None,
    top_k: int = 5,
) -> Dict[str, List[TuneResult]]:
    """Tune up to N unique underperforming dataset configurations per
    hardware (paper Fig. 9 protocol). ``predictors`` maps hw name to the
    ranking predictor for that device (None entries = oracle ranking)."""
    out: Dict[str, List[TuneResult]] = {}
    hw_arr = np.asarray(ds.hw_names)
    for hw_name in sorted(set(ds.hw_names)):
        idxs = np.where((hw_arr == hw_name) & under_mask)[0][:per_hw_limit]
        pred = (predictors or {}).get(hw_name)
        out[hw_name] = [
            tune_workload(ds.workloads[i], REGISTRY[hw_name], predictor=pred, top_k=top_k)
            for i in idxs
        ]
    return out
