"""Search-space derivation for the Pallas kernel autotuner.

The old ``core.tuner`` searched a hard-coded ``{block_m, block_f, stages}``
space — including a ``stages`` knob no Pallas kernel in this repo accepts.
Here every knob is derived from (and validated against) the kernel's actual
``ops.py`` entry-point signature: a tunable parameter is exactly a keyword
argument named ``block_*``, and naming anything else raises
:class:`UnknownKnobError` instead of silently tuning a phantom.
"""
from __future__ import annotations

import inspect
import itertools
from typing import Any, Callable, Dict, Iterable, List, Tuple

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.fused_moe import ops as moe_ops
from repro.kernels.rmsnorm import ops as rmsnorm_ops
from repro.kernels.scaled_mm import ops as scaled_mm_ops
from repro.kernels.silu_mul import ops as silu_mul_ops

#: kernel name -> (ops module, entry-point attribute, predictor family kind)
KERNEL_OPS: Dict[str, Tuple[Any, str, str]] = {
    "flash_attention": (flash_ops, "attention", "attention"),
    "fused_moe": (moe_ops, "fused_moe", "fused_moe"),
    "scaled_mm": (scaled_mm_ops, "scaled_mm", "scaled_mm"),
    "silu_mul": (silu_mul_ops, "act_mul", "silu_mul"),
    "rmsnorm": (rmsnorm_ops, "rmsnorm", "rmsnorm"),
}

TUNABLE_KERNELS = tuple(KERNEL_OPS)

#: the block-size lattice candidates are drawn from (per knob); the static
#: SP2xx pre-filter prunes combinations a given workload/device rejects
BLOCK_VALUES: Tuple[int, ...] = (32, 64, 128, 256, 512)


class UnknownKnobError(ValueError):
    """A search space named a knob the kernel's signature does not accept."""

    def __init__(self, kernel: str, unknown: Iterable[str], accepted: Iterable[str]):
        self.kernel = kernel
        self.unknown = sorted(unknown)
        self.accepted = sorted(accepted)
        super().__init__(
            f"kernel {kernel!r} accepts no knob(s) {self.unknown} — its ops "
            f"signature tunes exactly {self.accepted}; a knob the kernel "
            f"ignores would be searched for nothing (the old `stages` bug)"
        )


def kernel_entry(kernel: str) -> Callable[..., Any]:
    """The jit'd ops entry point of ``kernel`` (e.g. ``fused_moe.fused_moe``)."""
    mod, attr, _ = KERNEL_OPS[kernel]
    return getattr(mod, attr)


def predict_kind(kernel: str) -> str:
    """The predictor/decomposer family name of ``kernel`` (they differ only
    for flash_attention, whose family is ``attention``)."""
    return KERNEL_OPS[kernel][2]


def block_params(kernel: str) -> Dict[str, int]:
    """``{knob: default}`` straight from the kernel's ops signature —
    every keyword parameter named ``block_*``. ``inspect.signature``
    follows the ``jax.jit`` wrapper to the underlying function."""
    sig = inspect.signature(kernel_entry(kernel))
    return {
        name: p.default
        for name, p in sig.parameters.items()
        if name.startswith("block_") and p.default is not inspect.Parameter.empty
    }


def validate_space(kernel: str, space: Dict[str, Iterable[int]]) -> Dict[str, Tuple[int, ...]]:
    """Check every knob in ``space`` against the kernel signature; returns
    the space with value tuples, raising :class:`UnknownKnobError` on any
    knob the kernel would silently ignore."""
    accepted = block_params(kernel)
    unknown = set(space) - set(accepted)
    if unknown:
        raise UnknownKnobError(kernel, unknown, accepted)
    return {k: tuple(int(v) for v in vs) for k, vs in space.items()}


def candidate_space(kernel: str, values: Tuple[int, ...] = BLOCK_VALUES) -> Dict[str, Tuple[int, ...]]:
    """The default search space: every signature-derived knob over the
    block lattice."""
    return {name: values for name in block_params(kernel)}


def enumerate_candidates(
    kernel: str, space: Dict[str, Iterable[int]] | None = None
) -> List[Dict[str, int]]:
    """All knob-value combinations of ``space`` (default:
    :func:`candidate_space`), each validated against the ops signature."""
    sp = validate_space(kernel, dict(space) if space is not None else candidate_space(kernel))
    names = sorted(sp)
    return [dict(zip(names, combo)) for combo in itertools.product(*(sp[n] for n in names))]


# ----------------------------------------------------------------------
# workload plumbing: ops-helper kwargs <-> decomposer workload dicts
# ----------------------------------------------------------------------

#: CPU-scale default tuning workloads per kernel (stand-ins for the
#: registry serving shapes that fit interpret-mode timing; override with
#: --arch / explicit dims for accelerator-scale runs)
DEFAULT_WORKLOADS: Dict[str, Dict[str, int]] = {
    "fused_moe": {"E": 8, "C": 512, "D": 256, "F": 512},
    "scaled_mm": {"M": 1024, "K": 512, "N": 512},
    "flash_attention": {"B": 2, "S": 512, "Skv": 512, "Hq": 8, "Hkv": 8, "D": 64},
    "silu_mul": {"R": 4096, "d": 1024},
    "rmsnorm": {"R": 4096, "d": 512},
}


def decomposer_workload(kernel: str, kw: Dict[str, int]) -> Dict[str, Any]:
    """Map the ops-helper kwargs (the measured kernel's shape) to the
    decomposer workload dict the predictor prices. The fused-MoE mapping
    assumes balanced routing at the gathered capacity (``M = E*C`` routed
    pairs at top-1), which is the shape the kernel actually executes."""
    if kernel == "fused_moe":
        return {
            "M": kw["E"] * kw["C"], "E": kw["E"], "topk": 1,
            "H": kw["D"], "N": kw["F"], "skew": 0.0, "seed": 0,
        }
    if kernel == "scaled_mm":
        return {"M": kw["M"], "N": kw["N"], "K": kw["K"]}
    if kernel == "flash_attention":
        return {
            "bs": kw["B"], "nkv": kw["Hkv"], "group": kw["Hq"] // kw["Hkv"],
            "hd": kw["D"], "qlen": kw["S"], "kvlen": kw["Skv"], "causal": 1,
        }
    if kernel in ("silu_mul", "rmsnorm"):
        return {"seq": kw["R"], "dim": kw["d"]}
    raise KeyError(f"unknown kernel {kernel!r}; tunable: {sorted(KERNEL_OPS)}")


def arch_workload(kernel: str, arch: str, *, B: int = 2, lin: int = 512,
                  smoke: bool = False) -> Dict[str, int]:
    """The ops-helper kwargs one prefill step of registry arch ``arch``
    implies for ``kernel`` (via the auditor's ``kernel_workloads``);
    ``smoke=True`` uses the arch's CPU-scale smoke variant."""
    from repro.analysis.kernels import kernel_workloads
    from repro.configs import get_arch

    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    for name, kw in kernel_workloads(cfg, B=B, lin=lin):
        if name == kernel:
            return dict(kw)
    raise ValueError(
        f"arch {arch!r} launches no {kernel!r} kernel (its prefill workloads: "
        f"{[n for n, _ in kernel_workloads(cfg, B=B, lin=lin)]})"
    )
