"""SynPerf reproduction: hybrid analytical-ML GPU performance prediction
on a production-shaped JAX/Pallas training + serving stack."""

__version__ = "0.1.0"
