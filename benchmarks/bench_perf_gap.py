"""Paper §VII (Figs 8-9, Table X): P80 ceiling, Performance-Gap diagnosis and
model-guided autotuning of the fused MoE kernel."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, get_dataset
from repro.core.quantile import perf_gap, train_ceiling
from repro.core.tuner import geomean_speedup, pearson, tune_underperformers


def run(csv: Csv):
    ds = get_dataset("fused_moe")
    ceiling = train_ceiling(ds, quantile=0.8)
    report = perf_gap(ceiling, ds, threshold=0.1)

    grid, cdf = report.cdf()
    # fraction of points with gap below 0.1 (paper: ~80%)
    below = float((report.gaps <= 0.1).mean())
    csv.add("fig8/frac_gap_below_0.1", 0.0, f"{below:.3f}")
    for hw, count in sorted(report.per_hw_counts.items(), key=lambda kv: -kv[1]):
        csv.add(f"fig8/underperforming/{hw}", 0.0,
                f"{count} ({100*report.per_hw_frac[hw]:.1f}%)")

    # --- Table X: tune underperformers, correlate counts with speedups.
    # Paper protocol: §VII-C tunes on hardware from the TRAINING set only
    # (A40/L20/A100/H800 are all seen GPUs); on unseen hw part of the
    # diagnosed "gap" is ceiling-model extrapolation error, not kernel
    # config badness, which dilutes the correlation — we report both.
    from repro.core.dataset import SEEN

    tuned = tune_underperformers(ds, report.underperforming, per_hw_limit=30)
    counts, speedups = [], []
    counts_seen, speedups_seen = [], []
    for hw, results in sorted(tuned.items(), key=lambda kv: -len(kv[1])):
        if not results:
            continue
        g = geomean_speedup(results)
        counts.append(report.per_hw_counts[hw])
        speedups.append(g)
        if hw in SEEN:
            counts_seen.append(report.per_hw_counts[hw])
            speedups_seen.append(g)
        csv.add(f"table10/{hw}", 0.0,
                f"underperf={report.per_hw_counts[hw]}|geomean_speedup={g:.2f}x"
                f"|{'seen' if hw in SEEN else 'unseen'}")
    csv.add("table10/pearson_seen_hw_paper_protocol", 0.0,
            f"{pearson(counts_seen, speedups_seen):.2f}")
    csv.add("table10/pearson_all_hw", 0.0, f"{pearson(counts, speedups):.2f}")
    best = max((max((r.speedup for r in rs), default=1.0) for rs in tuned.values()), default=1.0)
    csv.add("table10/max_speedup", 0.0, f"{best:.2f}x")

    # --- Fig 9: gap before/after tuning on the tuned points ----------------
    for hw, results in tuned.items():
        if not results:
            continue
        before, after = [], []
        hw_rows = [i for i, (h, u) in enumerate(zip(ds.hw_names, report.underperforming)) if h == hw and u]
        yhat = ceiling.predict_ceiling(ds.X[hw_rows]) if hw_rows else np.array([])
        for j, r in enumerate(results):
            i = hw_rows[j]
            eff_before = ds.y_eff[i]
            eff_after = min(eff_before * r.speedup, 1.0)
            before.append(float(yhat[j] - eff_before))
            after.append(float(yhat[j] - eff_after))
        csv.add(f"fig9/{hw}", 0.0,
                f"gap_before={np.mean(before):.3f}|gap_after={np.mean(after):.3f}")
