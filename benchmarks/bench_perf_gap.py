"""Paper §VII (Figs 8-9, Table X): P80 ceiling, Performance-Gap diagnosis and
predictor-guided autotuning — both substrates of ``repro.tune``:

  * real kernels — ``tune("fused_moe", ...)`` over the actual Pallas kernel
    with timed interpret-mode execution. Criteria (asserted in ``--smoke``):
    the selected config beats the default blocks by ``MIN_REAL_SPEEDUP`` in
    wall-clock, every measured candidate passes the static SP2xx lint on
    every registry device, and predicted-vs-measured rank correlation is at
    least ``MIN_RANK_CORR`` (the paper's predictor-as-oracle claim);
  * hwsim dataset — the §VII-C experiment: tune the ceiling-diagnosed
    underperformers with synperf ranking + hwsim measurement. Criteria
    (asserted in ``--smoke``): the diagnosed gap closes (mean gap after <
    before), the geomean speedup is real (> 1), and the top-k *regret* —
    measured-best over exhaustive hwsim best — stays under
    ``MAX_SIM_REGRET``. Regret is the honest oracle-quality metric here:
    the estimator is trained on default-block configs only, so its
    within-workload block ordering (reported as
    ``sim_rank_correlation_mean``, ungated) is weak even while its top-k
    reliably contains a near-optimal config.

Standalone: ``python -m benchmarks.bench_perf_gap [--smoke] [--json PATH]``
(non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from benchmarks.common import Csv, get_backend, write_bench_json
from repro.core.dataset import SEEN
from repro.core.hardware import REGISTRY
from repro.core.quantile import perf_gap, train_ceiling
from repro.tune import (
    geomean_speedup,
    pearson,
    tune,
    tune_underperformers,
    tune_workload,
)

MIN_REAL_SPEEDUP = 1.10  # measured locally ~3.5x; generous for noisy runners
MIN_RANK_CORR = 0.5  # over the measured top-k (4 points)
MAX_SIM_REGRET = 1.05  # mean top-k regret vs exhaustive best (measured ~1.02)
REAL_TUNE_HW = "tpu-v4"
REAL_TOP_K = 4
REAL_REPEATS = 2
SIM_TOP_K = 5
EXHAUSTIVE_TOP_K = 10**9  # "measure every survivor" (hwsim is cheap)

#: the artifact's schema: every key write_bench_json must carry
#: (tests/test_bench_schemas.py checks the compare.py gates against this).
#: ``sim_rank_correlation_mean`` is deliberately in the schema even though
#: its *value* is not trajectory-gated yet (trained on default blocks only
#: — the ROADMAP estimator item): the smoke gate asserts it is reported
#: and finite over a nonzero tuned-workload set, so the known hole cannot
#: silently disappear from the artifact.
BENCH_KEYS = (
    "real", "sim", "real_speedup", "real_rank_correlation",
    "sim_geomean_speedup", "sim_rank_correlation_mean", "sim_mean_regret",
    "gap_closure_delta", "n_tuned_workloads",
)


def _real_kernel_tuning(csv: Csv) -> dict:
    """Tune the real fused-MoE Pallas kernel, timed execution."""
    from repro.analysis.kernels import check_blocks

    hw = REGISTRY[REAL_TUNE_HW]
    predictor = get_backend("roofline", hw)
    report = tune(
        "fused_moe",
        hw,
        predictor=predictor,
        predictor_name="roofline",
        top_k=REAL_TOP_K,
        repeats=REAL_REPEATS,
    )
    s = report.summary()
    csv.add(
        "tune/fused_moe_speedup",
        report.t_default * 1e6,
        f"{report.speedup:.2f}x ({report.default_blocks} -> {report.best.blocks}, "
        f"{'interpret' if report.interpret else 'compiled'})",
    )
    csv.add(
        "tune/fused_moe_rank_correlation",
        0.0,
        f"{report.rank_correlation:+.2f} over {len(report.measured)} measured",
    )
    csv.add(
        "tune/fused_moe_candidates",
        0.0,
        f"{report.n_candidates} enumerated, {report.n_rejected} SP2xx-rejected, "
        f"{len(report.survivors)} ranked",
    )
    # every launched candidate must be clean on EVERY registry device — the
    # same lint `python -m repro.analysis` runs (SP201-SP203 geometry;
    # SP204 is a config-vocabulary check with no block dependence)
    dirty = [
        c.blocks
        for c in report.measured
        if check_blocks("fused_moe", report.workload, c.blocks)
    ]
    s["launched_all_pass_sp2xx"] = not dirty
    s["dirty_candidates"] = dirty
    return s


def _dataset_tuning(csv: Csv) -> dict:
    """The paper's §VII-C experiment on the hwsim dataset."""
    from benchmarks.common import get_dataset

    ds = get_dataset("fused_moe")
    ceiling = train_ceiling(ds, quantile=0.8)
    report = perf_gap(ceiling, ds, threshold=0.1)

    below = float((report.gaps <= 0.1).mean())
    csv.add("fig8/frac_gap_below_0.1", 0.0, f"{below:.3f}")
    for hw, count in sorted(report.per_hw_counts.items(), key=lambda kv: -kv[1]):
        csv.add(f"fig8/underperforming/{hw}", 0.0,
                f"{count} ({100*report.per_hw_frac[hw]:.1f}%)")

    # --- Table X: tune underperformers with synperf ranking + hwsim
    # measurement (predicted != measured, so the rank correlation is a real
    # claim), correlate per-hw counts with realized speedups.
    # Paper protocol: §VII-C tunes on hardware from the TRAINING set only;
    # on unseen hw part of the diagnosed "gap" is ceiling-model
    # extrapolation error, not kernel config badness, which dilutes the
    # correlation — we report both.
    predictors = {name: get_backend("synperf", REGISTRY[name])
                  for name in sorted(set(ds.hw_names))}
    tuned = tune_underperformers(
        ds, report.underperforming, per_hw_limit=30, predictors=predictors,
        top_k=SIM_TOP_K,
    )
    counts, speedups = [], []
    counts_seen, speedups_seen = [], []
    rank_corrs = []
    regrets = []
    for hw, results in sorted(tuned.items(), key=lambda kv: -len(kv[1])):
        if not results:
            continue
        g = geomean_speedup(results)
        counts.append(report.per_hw_counts[hw])
        speedups.append(g)
        if hw in SEEN:
            counts_seen.append(report.per_hw_counts[hw])
            speedups_seen.append(g)
        rank_corrs += [r.rank_correlation for r in results]
        # regret: measured-best among the predictor's top-k over the
        # exhaustive hwsim best (predictor=None measures every survivor)
        for r in results:
            oracle = tune_workload(r.workload, REGISTRY[hw],
                                   predictor=None, top_k=EXHAUSTIVE_TOP_K)
            regrets.append(r.t_best / oracle.t_best)
        csv.add(f"table10/{hw}", 0.0,
                f"underperf={report.per_hw_counts[hw]}|geomean_speedup={g:.2f}x"
                f"|{'seen' if hw in SEEN else 'unseen'}")
    pearson_seen = pearson(counts_seen, speedups_seen)
    pearson_all = pearson(counts, speedups)
    csv.add("table10/pearson_seen_hw_paper_protocol", 0.0, f"{pearson_seen:.2f}")
    csv.add("table10/pearson_all_hw", 0.0, f"{pearson_all:.2f}")
    best = max((max((r.speedup for r in rs), default=1.0) for rs in tuned.values()),
               default=1.0)
    csv.add("table10/max_speedup", 0.0, f"{best:.2f}x")
    all_results = [r for rs in tuned.values() for r in rs]
    overall = geomean_speedup(all_results)
    sim_rank_corr = float(np.mean(rank_corrs)) if rank_corrs else 0.0
    mean_regret = float(np.mean(regrets)) if regrets else 1.0
    max_regret = float(np.max(regrets)) if regrets else 1.0
    csv.add("table10/geomean_speedup_all", 0.0, f"{overall:.3f}x")
    csv.add("table10/sim_rank_correlation_mean", 0.0,
            f"{sim_rank_corr:+.2f} over {len(rank_corrs)} tuned workloads "
            f"(reported, not gated: trained on default blocks only)")
    csv.add("table10/sim_mean_regret", 0.0,
            f"{mean_regret:.4f} (max {max_regret:.4f}) top-{SIM_TOP_K} vs "
            f"exhaustive best over {len(regrets)} workloads")

    # --- Fig 9: gap before/after tuning on the tuned points ----------------
    gaps_before, gaps_after = [], []
    per_hw_gap = {}
    for hw, results in tuned.items():
        if not results:
            continue
        before, after = [], []
        hw_rows = [i for i, (h, u) in enumerate(zip(ds.hw_names, report.underperforming))
                   if h == hw and u]
        yhat = ceiling.predict_ceiling(ds.X[hw_rows]) if hw_rows else np.array([])
        for j, r in enumerate(results):
            i = hw_rows[j]
            eff_before = ds.y_eff[i]
            eff_after = min(eff_before * r.speedup, 1.0)
            before.append(float(yhat[j] - eff_before))
            after.append(float(yhat[j] - eff_after))
        per_hw_gap[hw] = (float(np.mean(before)), float(np.mean(after)))
        gaps_before += before
        gaps_after += after
        csv.add(f"fig9/{hw}", 0.0,
                f"gap_before={np.mean(before):.3f}|gap_after={np.mean(after):.3f}")
    gap_before = float(np.mean(gaps_before)) if gaps_before else 0.0
    gap_after = float(np.mean(gaps_after)) if gaps_after else 0.0
    csv.add("fig9/gap_closure", 0.0,
            f"mean {gap_before:.3f} -> {gap_after:.3f} over {len(gaps_before)} tuned")

    return {
        "frac_gap_below_0.1": below,
        "pearson_seen_hw": pearson_seen,
        "pearson_all_hw": pearson_all,
        "max_speedup": best,
        "sim_geomean_speedup": overall,
        "sim_rank_correlation_mean": sim_rank_corr,
        "sim_mean_regret": mean_regret,
        "sim_max_regret": max_regret,
        "gap_before_mean": gap_before,
        "gap_after_mean": gap_after,
        "per_hw_gap": per_hw_gap,
        "n_tuned_workloads": len(all_results),
    }


def run(csv: Csv, smoke: bool = False) -> dict:
    real = _real_kernel_tuning(csv)
    sim = _dataset_tuning(csv)
    results = {"real": real, "sim": sim,
               # flat ratio-valued metrics for the trajectory baseline
               "real_speedup": real["speedup"],
               "real_rank_correlation": real["rank_correlation"],
               "sim_geomean_speedup": sim["sim_geomean_speedup"],
               "sim_rank_correlation_mean": sim["sim_rank_correlation_mean"],
               "sim_mean_regret": sim["sim_mean_regret"],
               "gap_closure_delta": sim["gap_before_mean"] - sim["gap_after_mean"],
               "n_tuned_workloads": sim["n_tuned_workloads"]}
    if smoke:
        # the within-workload rank correlation is reported-not-gated (see
        # BENCH_KEYS), but "reported" is itself a gate: it must be a real
        # number over a nonzero tuned set, or the ROADMAP's known hole
        # would silently vanish from the artifact
        assert sim["n_tuned_workloads"] > 0, (
            "dataset tuning tuned zero workloads — sim_rank_correlation_mean "
            "would be a fabricated 0.0"
        )
        assert math.isfinite(sim["sim_rank_correlation_mean"]), (
            f"sim_rank_correlation_mean is not finite: "
            f"{sim['sim_rank_correlation_mean']!r}"
        )
        assert real["launched_all_pass_sp2xx"], (
            f"tuner launched candidates the SP2xx lint rejects: "
            f"{real['dirty_candidates']}"
        )
        assert real["speedup"] >= MIN_REAL_SPEEDUP, (
            f"tuned fused_moe config {real['best_blocks']} is only "
            f"{real['speedup']:.2f}x over the default blocks "
            f"(< {MIN_REAL_SPEEDUP}x) in timed execution"
        )
        assert real["rank_correlation"] >= MIN_RANK_CORR, (
            f"predicted-vs-measured rank correlation {real['rank_correlation']:+.2f} "
            f"< {MIN_RANK_CORR} over the measured top-{real['n_measured']}"
        )
        assert sim["sim_mean_regret"] <= MAX_SIM_REGRET, (
            f"synperf top-{SIM_TOP_K} mean regret {sim['sim_mean_regret']:.4f} "
            f"> {MAX_SIM_REGRET} vs the exhaustive hwsim best over "
            f"{sim['n_tuned_workloads']} workloads"
        )
        assert sim["sim_geomean_speedup"] > 1.0, (
            f"dataset tuning produced no speedup "
            f"(geomean {sim['sim_geomean_speedup']:.3f}x)"
        )
        assert sim["gap_after_mean"] < sim["gap_before_mean"], (
            f"diagnosed performance gap did not close: mean "
            f"{sim['gap_before_mean']:.3f} -> {sim['gap_after_mean']:.3f}"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert speedup + SP2xx-cleanliness + rank "
                         "correlation + gap closure (CI gate)")
    ap.add_argument("--json", help="write BENCH_perf_gap.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,value,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results,
                         passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
