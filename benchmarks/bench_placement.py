"""Fleet-placement benchmark (ISSUE 4): routing quality of ``FleetRouter``
and the cost/behavior of prediction-driven admission.

Reports three things:

  * routing quality — the router (synperf estimator, cost objective)
    prices the 12k-call decode trace on every registry entry and its
    top-1 choice is scored against the oracle-cheapest hardware; also
    reported: the latency-objective top-1 and where the oracle's best
    lands in the predicted ranking. Criterion (asserted in ``--smoke``):
    predicted top-1 == oracle top-1 under the cost objective;
  * routing overhead — wall-clock of a full-registry ``route()`` over the
    12k-call trace (the ranking layer adds only float comparisons on top
    of the shared sweep);
  * predicted admission — a ``ContinuousBatchingEngine`` (smoke config)
    run twice on the same request set: fixed slot admission vs
    ``admission="predicted"`` with a decode-latency SLO sized from the
    oracle's worst-case tick (x1.05 headroom for scheduler-quantization
    wiggle). Criterion (asserted in ``--smoke``): every executed decode
    tick prices under the SLO, and within the same scheduler-tick budget
    the predicted policy lets at least as many requests into service as
    the fixed baseline (run-to-completion counts would be vacuous — the
    progress guarantee serves everything eventually under both).

Standalone: ``python -m benchmarks.bench_placement [--smoke] [--json PATH]``
(non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Csv, decode_sweep_trace, get_pipeweave, write_bench_json

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "trace_calls", "cost_top1_predicted", "cost_top1_oracle",
    "cost_top1_match", "oracle_best_rank_in_predicted",
    "latency_top1_predicted", "latency_top1_oracle", "cost_rank_spearman",
    "route_s", "best_cost_usd", "admission_hw", "slo_s", "max_tick_s",
    "slo_met", "decode_ticks", "tick_budget", "admitted_fixed",
    "admitted_predicted", "admission_decisions", "forced_admits",
    "overhead_us_per_decision",
)
from repro.configs import get_arch
from repro.core.hardware import get_hw
from repro.predict import FeatureCache, get_predictor
from repro.serve.placement import FleetRouter

ADMISSION_HW = "tpu-v5e"
SLO_HEADROOM = 1.05  # hwsim tick latency wiggles sub-percent vs KV span


def _route_quality(csv: Csv, pw, trace) -> dict:
    cache = FeatureCache()
    router = FleetRouter(objective="cost", estimator=pw, cache=cache)
    oracle_router = FleetRouter(backend="oracle", objective="cost", cache=cache)

    t0 = time.perf_counter()
    predicted = router.route(trace)
    route_s = time.perf_counter() - t0
    oracle = oracle_router.route(trace)

    top1_match = predicted.best == oracle.best
    oracle_best_rank = predicted.ranking().index(oracle.best)
    pred_lat = router.route(trace, objective="latency")
    oracle_lat = oracle_router.route(trace, objective="latency")

    csv.add("placement/route_us_per_call", route_s * 1e6 / len(trace),
            f"{route_s*1e3:.1f}ms full-registry route, {len(trace)} calls")
    csv.add("placement/cost_top1", 0.0,
            f"predicted={predicted.best} oracle={oracle.best} "
            f"({'MATCH' if top1_match else 'MISMATCH'})")
    csv.add("placement/oracle_best_rank_in_predicted", 0.0, f"{oracle_best_rank}")
    csv.add("placement/latency_top1", 0.0,
            f"predicted={pred_lat.best} oracle={oracle_lat.best}")
    # rank agreement over the whole fleet (Spearman rho on cost ranking)
    pr = {r.hw: i for i, r in enumerate(predicted.rows)}
    orr = {r.hw: i for i, r in enumerate(oracle.rows)}
    names = sorted(pr)
    rho = float(np.corrcoef([pr[n] for n in names], [orr[n] for n in names])[0, 1])
    csv.add("placement/cost_rank_spearman", 0.0, f"{rho:.3f}")
    return {
        "cost_top1_predicted": predicted.best,
        "cost_top1_oracle": oracle.best,
        "cost_top1_match": top1_match,
        "oracle_best_rank_in_predicted": oracle_best_rank,
        "latency_top1_predicted": pred_lat.best,
        "latency_top1_oracle": oracle_lat.best,
        "cost_rank_spearman": rho,
        "route_s": route_s,
        "best_cost_usd": predicted.rows[0].cost_usd,
    }


def _requests(cfg, n: int, seed: int = 0, max_new: int = 4):
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        L = int(rng.integers(8, 20))
        out.append(Request(rid=i, prompt=rng.integers(
            1, cfg.vocab_size, L).astype(np.int32), max_new=max_new))
    return out


def _admission(csv: Csv) -> dict:
    from repro.core.e2e import model_calls
    from repro.serve.engine import ContinuousBatchingEngine
    from repro.serve.trace import TraceRecorder

    cfg = get_arch("qwen3-0.6b").smoke()
    hw = get_hw(ADMISSION_HW)
    pred = get_predictor("oracle", hw, cache=FeatureCache())
    slots, max_len = 3, 48
    worst = pred.predict(model_calls(cfg, slots, 1, max_len, tp=1)).total_s
    slo = worst * SLO_HEADROOM

    # admissions are compared within a fixed tick budget: with
    # run-to-completion both policies eventually serve everything (the
    # progress guarantee), so completed counts could never differ — the
    # meaningful quantity is how many requests each policy lets *into
    # service* in the same number of scheduler ticks
    n_requests, tick_budget = 6, 8

    def run_engine(admission):
        rec = TraceRecorder()
        kw = {} if admission == "fixed" else {
            "admission": "predicted", "predictor": pred, "decode_slo_s": slo}
        eng = ContinuousBatchingEngine(
            cfg, slots=slots, max_len=max_len, seed=0, recorder=rec, **kw)
        for r in _requests(cfg, n_requests):
            eng.submit(r)
        t0 = time.perf_counter()
        for _ in range(tick_budget):
            eng.step()
        admitted = n_requests - len(eng.queue)  # entered service in budget
        eng.run_to_completion()  # drain: the SLO claim covers every tick
        return eng, rec, admitted, time.perf_counter() - t0

    # fixed first warms the jit caches the predicted run also uses, so the
    # wall-clock delta isolates the admission-decision overhead (plus noise)
    eng_f, rec_f, admitted_fixed, wall_f = run_engine("fixed")
    eng_p, rec_p, admitted_pred, wall_p = run_engine("predicted")
    decisions = len(eng_p.admission_log)
    # price every executed decode tick of the predicted run: the SLO claim
    tick_lat = [
        pred.predict([step]).total_s
        for step, m in zip(rec_p.steps, rec_p.meta)
        if m.phase == "decode"
    ]
    max_tick = max(tick_lat)
    per_decision_us = (
        max(wall_p - wall_f, 0.0) / max(decisions, 1) * 1e6
    )

    csv.add("placement/admission_slo_ms", 0.0, f"{slo*1e3:.3f}ms on {ADMISSION_HW}")
    csv.add("placement/admission_max_tick_ms", 0.0,
            f"{max_tick*1e3:.3f}ms over {len(tick_lat)} ticks "
            f"({'under' if max_tick <= slo else 'OVER'} SLO)")
    csv.add("placement/admitted_in_budget", 0.0,
            f"predicted={admitted_pred} fixed={admitted_fixed} "
            f"(of {n_requests} in {tick_budget} ticks)")
    csv.add("placement/admission_overhead_us_per_decision", per_decision_us,
            f"{decisions} decisions, run {wall_p*1e3:.0f}ms vs {wall_f*1e3:.0f}ms fixed")
    return {
        "admission_hw": ADMISSION_HW,
        "slo_s": slo,
        "max_tick_s": max_tick,
        "slo_met": bool(max_tick <= slo),
        "decode_ticks": len(tick_lat),
        "tick_budget": tick_budget,
        "admitted_fixed": admitted_fixed,
        "admitted_predicted": admitted_pred,
        "admission_decisions": decisions,
        "forced_admits": eng_p.slo_forced_admits,
        "overhead_us_per_decision": per_decision_us,
    }


def run(csv: Csv, smoke: bool = False) -> dict:
    pw = get_pipeweave()
    cfg = get_arch("qwen3-0.6b")
    trace = decode_sweep_trace(cfg)
    csv.add("placement/trace_calls", 0.0, f"{len(trace)} calls, decode sweep 48 steps")

    results = {"trace_calls": len(trace)}
    results.update(_route_quality(csv, pw, trace))
    results.update(_admission(csv))

    if smoke:
        assert results["cost_top1_match"], (
            f"router's cost top-1 {results['cost_top1_predicted']!r} != "
            f"oracle-cheapest {results['cost_top1_oracle']!r} on the decode trace"
        )
        assert results["slo_met"], (
            f"predicted admission exceeded its decode SLO: worst tick "
            f"{results['max_tick_s']*1e3:.3f}ms > {results['slo_s']*1e3:.3f}ms"
        )
        assert results["admitted_fixed"] > 0, "tick budget admitted nothing"
        assert results["admitted_predicted"] >= results["admitted_fixed"], (
            f"predicted admission let {results['admitted_predicted']} requests "
            f"into service within {results['tick_budget']} ticks < fixed "
            f"baseline's {results['admitted_fixed']}"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the routing + admission criteria (CI gate)")
    ap.add_argument("--json", help="write BENCH_placement.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,us_per_call,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
