"""Perf-trajectory gate: compare the gated ``BENCH_*.json`` metrics of this
run against the committed baseline snapshot (``results/bench_baseline/``),
failing when a ratio-valued metric regresses beyond its tolerance.

Only *ratio-valued* metrics are gated (speedups, correlations, error
reductions, fractions) — they are dimensionless and hold on shared CI
runners where absolute timings do not. The baseline manifest
(``metrics.json``) declares per-metric: which artifact file and JSON key it
comes from, the baseline value, the good direction, and the relative
tolerance.

Usage::

    python -m benchmarks.compare --baseline results/bench_baseline [DIR]
    python -m benchmarks.compare --write-baseline results/bench_baseline [DIR]

``DIR`` is where the fresh ``BENCH_*.json`` artifacts live (default: cwd).
``--write-baseline`` refreshes the snapshot from the same artifacts
(tolerances/directions of existing entries are preserved).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

#: the gated trajectory: every entry is a dimensionless ratio. ``direction``
#: "higher" means larger is better (gate fires when value drops below
#: baseline*(1-rel_tol)); "lower" the reverse. Adding a metric = add a row
#: here + regenerate the snapshot with --write-baseline.
GATED_METRICS: List[Dict[str, Any]] = [
    # perf-gap / autotuning (ISSUE 8)
    {"file": "BENCH_perf_gap.json", "key": "real_speedup",
     "direction": "higher", "rel_tol": 0.35},  # interpret-mode timing ratio
    {"file": "BENCH_perf_gap.json", "key": "real_rank_correlation",
     "direction": "higher", "rel_tol": 0.5},
    {"file": "BENCH_perf_gap.json", "key": "sim_geomean_speedup",
     "direction": "higher", "rel_tol": 0.05},
    {"file": "BENCH_perf_gap.json", "key": "sim_mean_regret",
     "direction": "lower", "rel_tol": 0.05},
    # kernel MAPE (paper Table VIII)
    {"file": "BENCH_kernel_mape.json", "key": "error_reduction_seen",
     "direction": "higher", "rel_tol": 0.3},
    {"file": "BENCH_kernel_mape.json", "key": "error_reduction_unseen",
     "direction": "higher", "rel_tol": 0.3},
    # batched-predictor overhead (ISSUE 2): speedup ratio
    {"file": "BENCH_overhead.json", "key": "batched_speedup",
     "direction": "higher", "rel_tol": 0.3},
    # multi-hw sweep (ISSUE 3): sweep cost over single-hw cost
    {"file": "BENCH_sweep.json", "key": "ratio_vs_single",
     "direction": "lower", "rel_tol": 0.3},
    # placement (ISSUE 4): routing agreement with the oracle
    # (top-1 match is a boolean in the artifact, already asserted by the
    # placement smoke gate — only the ratio-valued spearman is tracked here)
    {"file": "BENCH_placement.json", "key": "cost_rank_spearman",
     "direction": "higher", "rel_tol": 0.15},
    # parallelism (ISSUE 5): interleaved-1F1B bubble over GPipe's
    {"file": "BENCH_parallelism.json", "key": "bubble_ratio",
     "direction": "lower", "rel_tol": 0.1},
    # overlap-aware comm (ISSUE 10): zero-bubble ZB-H1 bubble over 1F1B's
    # at the same gate point (analytic, deterministic)
    {"file": "BENCH_parallelism.json", "key": "zb_ratio",
     "direction": "lower", "rel_tol": 0.1},
    # overlap-aware comm (ISSUE 10): overlap-priced over additive total on
    # the >=12k-call decode trace (roofline backend, deterministic)
    {"file": "BENCH_parallelism.json", "key": "overlap_total_ratio",
     "direction": "lower", "rel_tol": 0.15},
    # drift control loop (ISSUE 9): re-routed over frozen p95 on a
    # step-drifted stream — how much of the drift-induced queueing the
    # monitor claws back (lower = better; far below 1 when the loop works)
    {"file": "BENCH_fleet.json", "key": "reroute_p95_ratio",
     "direction": "lower", "rel_tol": 0.5},
]


def _read_metric(run_dir: str, file: str, key: str) -> Optional[float]:
    path = os.path.join(run_dir, file)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    val = payload.get(key)
    if isinstance(val, (int, float)) and not isinstance(val, bool):
        return float(val)
    return None


def collect(run_dir: str) -> Dict[str, Dict[str, Any]]:
    """The current run's gated metric values, keyed ``file::key``."""
    out: Dict[str, Dict[str, Any]] = {}
    for m in GATED_METRICS:
        val = _read_metric(run_dir, m["file"], m["key"])
        if val is not None:
            out[f"{m['file']}::{m['key']}"] = {**m, "value": val}
    return out


def write_baseline(baseline_dir: str, run_dir: str) -> int:
    os.makedirs(baseline_dir, exist_ok=True)
    path = os.path.join(baseline_dir, "metrics.json")
    metrics = collect(run_dir)
    if not metrics:
        print(f"no gated BENCH_*.json metrics found in {run_dir!r}", file=sys.stderr)
        return 2
    with open(path, "w") as f:
        json.dump({"metrics": metrics}, f, indent=2, sort_keys=True)
    print(f"wrote {path} ({len(metrics)} gated metrics)")
    return 0


def compare(baseline_dir: str, run_dir: str) -> int:
    path = os.path.join(baseline_dir, "metrics.json")
    with open(path) as f:
        baseline = json.load(f)["metrics"]
    failures = []
    checked = 0
    for name, base in sorted(baseline.items()):
        cur = _read_metric(run_dir, base["file"], base["key"])
        if cur is None:
            # the artifact may legitimately be absent (partial run); missing
            # metrics are reported but do not fail the gate on their own
            print(f"  SKIP {name}: no current value in {run_dir}")
            continue
        checked += 1
        bval, tol = float(base["value"]), float(base["rel_tol"])
        if base["direction"] == "higher":
            floor = bval * (1.0 - tol)
            ok = cur >= floor
            bound = f">= {floor:.4g}"
        else:
            ceil = bval * (1.0 + tol)
            ok = cur <= ceil
            bound = f"<= {ceil:.4g}"
        status = "ok  " if ok else "FAIL"
        print(f"  {status} {name}: {cur:.4g} (baseline {bval:.4g}, gate {bound})")
        if not ok:
            failures.append(name)
    if checked == 0:
        print("no gated metrics present in the current run — nothing compared",
              file=sys.stderr)
        return 2
    if failures:
        print(f"\n{len(failures)} metric(s) regressed beyond tolerance: "
              f"{failures}", file=sys.stderr)
        return 1
    print(f"\nall {checked} gated metrics within tolerance of the baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--baseline", metavar="DIR",
                      help="compare the current artifacts against this snapshot")
    mode.add_argument("--write-baseline", metavar="DIR",
                      help="(re)write the snapshot from the current artifacts")
    ap.add_argument("run_dir", nargs="?", default=".",
                    help="directory holding the fresh BENCH_*.json artifacts")
    args = ap.parse_args(argv)
    if args.write_baseline:
        return write_baseline(args.write_baseline, args.run_dir)
    return compare(args.baseline, args.run_dir)


if __name__ == "__main__":
    sys.exit(main())
