"""Benchmark suite orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Caches datasets/trained models in
results/bench_cache so repeated runs are fast.

Exit status is the CI contract: non-zero when any sub-benchmark raises
(each failure is also recorded as a ``<tag>/_FAILED`` row and in the
``--json`` summary) or when ``--only`` names an unknown tag — a misspelled
filter must not silently gate on an empty run.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table7_decomposer", "benchmarks.bench_decomposer"),
    ("table8_kernel_mape", "benchmarks.bench_kernel_mape"),
    ("fig4_ablation", "benchmarks.bench_ablation"),
    ("fig7_overhead", "benchmarks.bench_overhead"),
    ("fig8_table10_perf_gap", "benchmarks.bench_perf_gap"),
    ("table9_e2e", "benchmarks.bench_e2e"),
    ("sweep", "benchmarks.bench_sweep"),
    ("placement", "benchmarks.bench_placement"),
    ("fleet", "benchmarks.bench_fleet"),
    ("parallelism", "benchmarks.bench_parallelism"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated module tags to run")
    ap.add_argument("--json", help="write a machine-readable run summary here")
    args = ap.parse_args()
    from benchmarks.common import Csv

    known = {tag for tag, _ in MODULES}
    selected = known
    if args.only:
        selected = set(args.only.split(","))
        unknown = selected - known
        if unknown:
            print(
                f"unknown --only tags: {sorted(unknown)}; known: {sorted(known)}",
                file=sys.stderr,
            )
            return 2

    csv = Csv()
    print("name,us_per_call,derived")
    statuses = {}
    failures = 0
    for tag, modname in MODULES:
        if tag not in selected:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(csv)
            statuses[tag] = {"status": "ok", "elapsed_s": time.time() - t0}
            csv.add(f"{tag}/_elapsed_s", 0.0, f"{time.time()-t0:.1f}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            statuses[tag] = {
                "status": "failed",
                "elapsed_s": time.time() - t0,
                "error": f"{type(e).__name__}: {e}",
            }
            csv.add(f"{tag}/_FAILED", 0.0, f"{type(e).__name__} (see stderr)")
    if args.json:
        from benchmarks.common import write_bench_json

        write_bench_json(args.json, csv, modules=statuses, failures=failures)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
