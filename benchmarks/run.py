"""Benchmark suite orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Caches datasets/trained models in
results/bench_cache so repeated runs are fast.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table7_decomposer", "benchmarks.bench_decomposer"),
    ("table8_kernel_mape", "benchmarks.bench_kernel_mape"),
    ("fig4_ablation", "benchmarks.bench_ablation"),
    ("fig7_overhead", "benchmarks.bench_overhead"),
    ("fig8_table10_perf_gap", "benchmarks.bench_perf_gap"),
    ("table9_e2e", "benchmarks.bench_e2e"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated module tags to run")
    args = ap.parse_args()
    from benchmarks.common import Csv

    csv = Csv()
    print("name,us_per_call,derived")
    failures = 0
    for tag, modname in MODULES:
        if args.only and tag not in args.only.split(","):
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(csv)
            csv.add(f"{tag}/_elapsed_s", 0.0, f"{time.time()-t0:.1f}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            csv.add(f"{tag}/_FAILED", 0.0, "see stderr")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
