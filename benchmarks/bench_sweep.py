"""Multi-hardware sweep benchmark (ISSUE 3): one 12k-call decode trace
priced on many registry entries.

Reports three things:

  * wall-clock — a shared ``SweepPredictor`` pass over ``SWEEP_HWS`` (6
    devices) vs a single-hw batched predict vs N independent per-hw
    predicts (the naive sweep). Criterion (asserted in ``--smoke``):
    shared sweep < 3x single-hw (naive is ~6x) — grouping runs once and
    decompose+schedule are shared under ``task_sig``;
  * per-hw accuracy — measured (hwsim oracle) vs predicted total for the
    trace on the *full* registry, aggregated over the paper's seen/unseen
    hardware split;
  * sweep scaling — wall-clock per additional device.

Standalone: ``python -m benchmarks.bench_sweep [--smoke] [--json PATH]``
(non-zero exit when the smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import gc
import math
import sys
import time

from benchmarks.common import Csv, decode_sweep_trace, get_pipeweave, write_bench_json

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "trace_calls", "n_hw", "single_hw_s", "shared_sweep_s", "naive_sweep_s",
    "ratio_vs_single", "naive_ratio_vs_single", "max_ratio_target",
    "shared_vs_naive_rel_diff", "per_hw_err_pct", "mape_seen", "mape_unseen",
    "single_total_ms",
)
from repro.configs import get_arch
from repro.core.hardware import REGISTRY, get_hw
from repro.predict import FeatureCache, SweepPredictor, get_predictor

# >= 6 hardware (ISSUE 3 criterion), both splits, all three chip counts
SWEEP_HWS = ("tpu-v5e", "tpu-v4", "tpu-v5p", "tpu-v6e", "tpu-v5e-16", "tpu-v7p")
SINGLE_HW = "tpu-v5e"
MAX_RATIO = 3.0  # shared sweep must beat 3x single-hw predict


def _timed(fn, reps: int = 1) -> tuple:
    """(wall seconds per pass, last result): times ``reps`` consecutive
    passes as one sample so scheduler jitter amortizes over a longer
    window (a single pass is ~20ms — too short to gate on alone)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def run(csv: Csv, smoke: bool = False) -> dict:
    pw = get_pipeweave()
    cfg = get_arch("qwen3-0.6b")
    trace = decode_sweep_trace(cfg)
    csv.add("sweep/trace_calls", 0.0, f"{len(trace)} calls, decode sweep 48 steps")

    # fresh caches per timed pass: the comparison must not lean on state
    # warmed by a previous run (same protocol as bench_overhead)
    def single_pass():
        p = get_predictor("synperf", get_hw(SINGLE_HW), estimator=pw, cache=FeatureCache())
        return p.predict(trace)

    def naive_pass():
        return {
            name: get_predictor(
                "synperf", get_hw(name), estimator=pw, cache=FeatureCache()
            ).predict(trace)
            for name in SWEEP_HWS
        }

    def shared_pass():
        return SweepPredictor(SWEEP_HWS, estimator=pw, cache=FeatureCache()).predict(trace)

    single_pass()  # warm numpy/BLAS paths once
    # best-of-N on each side, each sample timing 3 consecutive passes
    # inside a GC-disabled window (GC pauses over the 12k-call flatten are
    # the main single-process noise; batching amortizes scheduler jitter)
    rounds = []
    for _ in range(5 if smoke else 3):
        gc.collect()
        gc.disable()
        try:
            t_single, single_est = _timed(single_pass, reps=3)
            t_shared, shared_res = _timed(shared_pass, reps=3)
        finally:
            gc.enable()
        rounds.append((t_single, t_shared))
    single_s = min(t for t, _ in rounds)
    shared_s = min(t for _, t in rounds)
    ratio = shared_s / max(single_s, 1e-12)
    naive_s, naive_res = _timed(naive_pass)
    naive_ratio = naive_s / max(single_s, 1e-12)
    csv.add("sweep/single_hw_us_per_call", single_s * 1e6 / len(trace),
            f"{single_s*1e3:.1f}ms total on {SINGLE_HW}")
    csv.add("sweep/shared_sweep_us_per_call", shared_s * 1e6 / len(trace),
            f"{shared_s*1e3:.1f}ms over {len(SWEEP_HWS)} hw")
    csv.add("sweep/naive_sweep_us_per_call", naive_s * 1e6 / len(trace),
            f"{naive_s*1e3:.1f}ms ({naive_ratio:.1f}x single)")
    csv.add("sweep/ratio_vs_single", 0.0,
            f"{ratio:.2f}x (target <{MAX_RATIO}x, naive ~{naive_ratio:.1f}x)")

    # correctness: the shared pass must equal the naive per-hw passes
    max_rel = max(
        abs(shared_res[n].total_s - naive_res[n].total_s)
        / max(naive_res[n].total_s, 1e-12)
        for n in SWEEP_HWS
    )
    csv.add("sweep/shared_vs_naive_rel_diff", 0.0, f"{max_rel:.2e}")

    # ---- accuracy: measured (oracle) vs predicted over the full registry --
    hws = SWEEP_HWS if smoke else tuple(REGISTRY)
    sp = SweepPredictor(hws, estimator=pw, cache=FeatureCache())
    cmp = sp.compare(trace)
    per_hw = {}
    for name in hws:
        err = cmp.err_pct(name)
        per_hw[name] = err
        csv.add(f"sweep/err/{name}", 0.0, f"{err:.1f}%")
    split = cmp.split_mape()
    csv.add("sweep/mape_seen", 0.0, f"{split['seen']:.1f}%")
    csv.add("sweep/mape_unseen", 0.0, f"{split['unseen']:.1f}%")
    for fam, err in sorted(cmp.family_mape().items()):
        csv.add(f"sweep/family_mape/{fam}", 0.0, f"{err:.1f}%")

    results = {
        "trace_calls": len(trace),
        "n_hw": len(SWEEP_HWS),
        "single_hw_s": single_s,
        "shared_sweep_s": shared_s,
        "naive_sweep_s": naive_s,
        "ratio_vs_single": ratio,
        "naive_ratio_vs_single": naive_ratio,
        "max_ratio_target": MAX_RATIO,
        "shared_vs_naive_rel_diff": max_rel,
        "per_hw_err_pct": per_hw,
        # null, not the non-standard NaN literal, when a split is empty
        "mape_seen": None if math.isnan(split["seen"]) else split["seen"],
        "mape_unseen": None if math.isnan(split["unseen"]) else split["unseen"],
        "single_total_ms": single_est.total_s * 1e3,
    }
    if smoke:
        assert max_rel < 1e-9, f"shared sweep diverged from per-hw predicts: {max_rel:.2e}"
        assert ratio < MAX_RATIO, (
            f"sweep over {len(SWEEP_HWS)} hw took {ratio:.2f}x a single-hw "
            f"predict (target <{MAX_RATIO}x; naive is ~{naive_ratio:.1f}x) — "
            "featurization sharing regressed"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the <3x sweep criterion (CI gate) and trim "
                         "the accuracy table to the sweep hardware")
    ap.add_argument("--json", help="write BENCH_sweep.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,us_per_call,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
