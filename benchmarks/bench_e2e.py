"""Paper Table IX + Fig 6: end-to-end inference prediction MAPE across
models / parallelism / request mixes, PipeWeave vs baselines.

Workload mixes mirror the paper's arxiv_* (avg input 2630) and splitwise_*
(avg input 982) batches; models come from the assigned architecture registry
(single-unit + TP=2/4/8 and TP=4&PP=2 configurations). Every estimator —
PipeWeave and the four §VI baselines — runs through the same
``repro.predict`` backend interface (one batched ``request_estimate`` per
cell) against the oracle backend."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, get_backend
from repro.configs import get_arch
from repro.core.dataset import SEEN
from repro.core.e2e import request_estimate
from repro.core.hardware import REGISTRY

CONFIGS = [
    # (arch, tp, pp, hw list)
    ("qwen3-0.6b", 1, 1, ["tpu-v5e", "tpu-v4", "tpu-v6e", "tpu-v5e-16"]),
    ("gemma2-2b", 1, 1, ["tpu-v5e", "tpu-v5p", "tpu-v6e", "tpu-v4-turbo"]),
    ("stablelm-3b", 2, 1, ["tpu-v5e", "tpu-v5p", "tpu-v6e"]),
    ("deepseek-67b", 4, 1, ["tpu-v5p", "tpu-v6e", "tpu-v7p"]),
    ("deepseek-67b", 8, 1, ["tpu-v5e-lite", "tpu-v6e"]),
    ("dbrx-132b", 4, 1, ["tpu-v5p", "tpu-v6e"]),
    ("arctic-480b", 8, 1, ["tpu-v5p", "tpu-v7p"]),
    ("deepseek-67b", 4, 2, ["tpu-v5e-lite", "tpu-v6e"]),
]

MIXES = [
    ("arxiv_8", 8, 2630, 300),
    ("arxiv_16", 16, 2630, 300),
    ("splitwise_48", 48, 982, 150),
    ("splitwise_64", 64, 982, 150),
]

BACKENDS = ("synperf", "roofline", "linear", "habitat", "neusight")


def run(csv: Csv):
    rows = {name: {"seen": [], "unseen": []} for name in BACKENDS}

    for arch, tp, pp, hw_names in CONFIGS:
        cfg = get_arch(arch)
        for mix_name, B, lin, lout in MIXES[:2] if cfg.n_params() > 5e10 else MIXES:
            for hw_name in hw_names:
                hw = REGISTRY[hw_name]
                oracle = get_backend("oracle", hw)
                actual = request_estimate(
                    cfg, B, lin, lout, tp=tp, pp=pp, predictor=oracle
                ).total_s
                split = "seen" if hw_name in SEEN else "unseen"
                preds = {}
                for name in BACKENDS:
                    est = request_estimate(
                        cfg, B, lin, lout, tp=tp, pp=pp,
                        predictor=get_backend(name, hw),
                    )
                    err = abs(est.total_s - actual) / actual * 100
                    preds[name] = err
                    rows[name][split].append(err)
                csv.add(
                    f"table9/{arch}_tp{tp}pp{pp}/{mix_name}/{hw_name}",
                    0.0,
                    "|".join(f"{n}={preds[n]:.1f}%" for n in preds),
                )

    for name, d in rows.items():
        for split in ("seen", "unseen"):
            if d[split]:
                csv.add(f"table9/avg_{split}/{name}", 0.0, f"{np.mean(d[split]):.1f}%")
    ours = np.mean(rows["synperf"]["seen"] + rows["synperf"]["unseen"])
    best = min(
        np.mean(rows[b]["seen"] + rows[b]["unseen"]) for b in BACKENDS if b != "synperf"
    )
    csv.add("table9/error_reduction_overall", 0.0, f"{best/max(ours,1e-9):.1f}x")
