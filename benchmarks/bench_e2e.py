"""Paper Table IX + Fig 6: end-to-end inference prediction MAPE across
models / parallelism / request mixes, PipeWeave vs baselines.

Workload mixes mirror the paper's arxiv_* (avg input 2630) and splitwise_*
(avg input 982) batches; models come from the assigned architecture registry
(single-unit + TP=2/4/8 and TP=4&PP=2 configurations)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, get_baseline, get_dataset, get_pipeweave
from repro.configs import get_arch
from repro.core.dataset import SEEN, mape
from repro.core.e2e import CommRegressor, oracle_times, request_latency
from repro.core.hardware import REGISTRY

CONFIGS = [
    # (arch, tp, pp, hw list)
    ("qwen3-0.6b", 1, 1, ["tpu-v5e", "tpu-v4", "tpu-v6e", "tpu-v5e-16"]),
    ("gemma2-2b", 1, 1, ["tpu-v5e", "tpu-v5p", "tpu-v6e", "tpu-v4-turbo"]),
    ("stablelm-3b", 2, 1, ["tpu-v5e", "tpu-v5p", "tpu-v6e"]),
    ("deepseek-67b", 4, 1, ["tpu-v5p", "tpu-v6e", "tpu-v7p"]),
    ("deepseek-67b", 8, 1, ["tpu-v5e-lite", "tpu-v6e"]),
    ("dbrx-132b", 4, 1, ["tpu-v5p", "tpu-v6e"]),
    ("arctic-480b", 8, 1, ["tpu-v5p", "tpu-v7p"]),
    ("deepseek-67b", 4, 2, ["tpu-v5e-lite", "tpu-v6e"]),
]

MIXES = [
    ("arxiv_8", 8, 2630, 300),
    ("arxiv_16", 16, 2630, 300),
    ("splitwise_48", 48, 982, 150),
    ("splitwise_64", 64, 982, 150),
]


def _kernel_time_from(predictor, ds_cache, hw):
    def f(kind, X):
        return predictor.predict_latency(kind, X, hw)

    return f


class _BaselineAdapter:
    """Wrap a fitted kernel baseline into a predict_latency interface."""

    def __init__(self, models: dict):
        self.models = models

    def predict_latency(self, kind, X, hw):
        from repro.core.dataset import KernelDataset, featurize

        fs = featurize(kind, X, hw)
        ds = KernelDataset(
            kind,
            fs.vector(hw)[None],
            np.array([1.0], np.float32),
            np.array([fs.theoretical_s]),
            np.array([fs.theoretical_s]),
            [hw.name],
            [X],
        )
        return float(self.models[kind].predict(ds)[0])


def run(csv: Csv):
    pw = get_pipeweave()
    baselines = {
        name: _BaselineAdapter({k: get_baseline(name, k) for k in
                                ("gemm", "attention", "rmsnorm", "silu_mul", "fused_moe")})
        for name in ("roofline", "linear", "habitat", "neusight")
    }
    comms: dict = {}
    rows = {name: {"seen": [], "unseen": []} for name in ("pipeweave", *baselines)}

    for arch, tp, pp, hw_names in CONFIGS:
        cfg = get_arch(arch)
        for mix_name, B, lin, lout in MIXES[:2] if cfg.n_params() > 5e10 else MIXES:
            for hw_name in hw_names:
                hw = REGISTRY[hw_name]
                if hw_name not in comms:
                    comms[hw_name] = CommRegressor().fit(hw)
                kt_o, ct_o = oracle_times(hw)
                actual = request_latency(
                    cfg, B, lin, lout, tp=tp, pp=pp, kernel_time=kt_o, comm_time=ct_o
                )
                split = "seen" if hw_name in SEEN else "unseen"
                preds = {}
                for name, predictor in (("pipeweave", pw), *baselines.items()):
                    p = request_latency(
                        cfg, B, lin, lout, tp=tp, pp=pp,
                        kernel_time=lambda k, X, pr=predictor: pr.predict_latency(k, X, hw),
                        comm_time=comms[hw_name].predict,
                    )
                    err = abs(p - actual) / actual * 100
                    preds[name] = err
                    rows[name][split].append(err)
                csv.add(
                    f"table9/{arch}_tp{tp}pp{pp}/{mix_name}/{hw_name}",
                    0.0,
                    "|".join(f"{n}={preds[n]:.1f}%" for n in preds),
                )

    for name, d in rows.items():
        for split in ("seen", "unseen"):
            if d[split]:
                csv.add(f"table9/avg_{split}/{name}", 0.0, f"{np.mean(d[split]):.1f}%")
    ours = np.mean(rows["pipeweave"]["seen"] + rows["pipeweave"]["unseen"])
    best = min(
        np.mean(rows[b]["seen"] + rows[b]["unseen"]) for b in baselines
    )
    csv.add("table9/error_reduction_overall", 0.0, f"{best/max(ours,1e-9):.1f}x")
