"""Paper Table VIII + Fig 5: kernel-level prediction MAPE of PipeWeave vs the
four baselines, split by seen/unseen hardware, per kernel family."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, get_all_datasets, get_baseline, get_pipeweave
from repro.core.dataset import SEEN, mape

BASELINE_NAMES = ("roofline", "linear", "habitat", "neusight")


def run(csv: Csv):
    datasets = get_all_datasets()
    pw = get_pipeweave()

    table = {}
    for kind, ds in datasets.items():
        seen = np.array([h in SEEN for h in ds.hw_names])
        preds = {"pipeweave": pw.predict_dataset(ds)}
        for b in BASELINE_NAMES:
            preds[b] = get_baseline(b, kind).predict(ds)
        for name, p in preds.items():
            table[(kind, name, "seen")] = mape(p[seen], ds.actual_s[seen])
            table[(kind, name, "unseen")] = mape(p[~seen], ds.actual_s[~seen])
            csv.add(
                f"table8/{kind}/{name}",
                0.0,
                f"seen={table[(kind, name, 'seen')]:.1f}%|unseen={table[(kind, name, 'unseen')]:.1f}%",
            )

    for split in ("seen", "unseen"):
        for name in ("pipeweave", *BASELINE_NAMES):
            avg = np.mean([table[(k, name, split)] for k in datasets])
            csv.add(f"table8/avg_{split}/{name}", 0.0, f"{avg:.1f}%")
    # headline error-reduction factor vs best baseline (paper: 6.7x / 3.8x)
    for split in ("seen", "unseen"):
        ours = np.mean([table[(k, "pipeweave", split)] for k in datasets])
        best_base = min(
            np.mean([table[(k, b, split)] for k in datasets]) for b in BASELINE_NAMES
        )
        csv.add(f"table8/error_reduction_{split}", 0.0, f"{best_base/max(ours,1e-9):.1f}x")
