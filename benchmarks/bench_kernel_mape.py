"""Paper Table VIII + Fig 5: kernel-level prediction MAPE of PipeWeave vs the
four baselines, split by seen/unseen hardware, per kernel family.

Criteria (asserted in ``--smoke``): PipeWeave's average MAPE beats the best
baseline on BOTH splits (error reduction > ``MIN_ERROR_REDUCTION``) and
stays under ``MAX_SEEN_MAPE`` / ``MAX_UNSEEN_MAPE`` absolute — the paper's
kernel-accuracy headline as a standing regression gate.

Standalone: ``python -m benchmarks.bench_kernel_mape [--smoke] [--json PATH]``
(non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import Csv, get_all_datasets, get_baseline, get_pipeweave, write_bench_json

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "mape_seen", "mape_unseen", "best_baseline_seen",
    "best_baseline_unseen", "error_reduction_seen",
    "error_reduction_unseen",
)
from repro.core.dataset import SEEN, mape

BASELINE_NAMES = ("roofline", "linear", "habitat", "neusight")

MIN_ERROR_REDUCTION = 1.2  # x over the best baseline, both splits
MAX_SEEN_MAPE = 25.0  # %; CI runs at 60 workloads / 60 epochs
MAX_UNSEEN_MAPE = 45.0  # %


def run(csv: Csv, smoke: bool = False) -> dict:
    datasets = get_all_datasets()
    pw = get_pipeweave()

    table = {}
    for kind, ds in datasets.items():
        seen = np.array([h in SEEN for h in ds.hw_names])
        preds = {"pipeweave": pw.predict_dataset(ds)}
        for b in BASELINE_NAMES:
            preds[b] = get_baseline(b, kind).predict(ds)
        for name, p in preds.items():
            table[(kind, name, "seen")] = mape(p[seen], ds.actual_s[seen])
            table[(kind, name, "unseen")] = mape(p[~seen], ds.actual_s[~seen])
            csv.add(
                f"table8/{kind}/{name}",
                0.0,
                f"seen={table[(kind, name, 'seen')]:.1f}%|unseen={table[(kind, name, 'unseen')]:.1f}%",
            )

    avg = {}
    for split in ("seen", "unseen"):
        for name in ("pipeweave", *BASELINE_NAMES):
            avg[(name, split)] = float(
                np.mean([table[(k, name, split)] for k in datasets])
            )
            csv.add(f"table8/avg_{split}/{name}", 0.0, f"{avg[(name, split)]:.1f}%")
    # headline error-reduction factor vs best baseline (paper: 6.7x / 3.8x)
    reduction = {}
    for split in ("seen", "unseen"):
        ours = avg[("pipeweave", split)]
        best_base = min(avg[(b, split)] for b in BASELINE_NAMES)
        reduction[split] = best_base / max(ours, 1e-9)
        csv.add(f"table8/error_reduction_{split}", 0.0, f"{reduction[split]:.1f}x")

    results = {
        "mape_seen": avg[("pipeweave", "seen")],
        "mape_unseen": avg[("pipeweave", "unseen")],
        "best_baseline_seen": min(avg[(b, "seen")] for b in BASELINE_NAMES),
        "best_baseline_unseen": min(avg[(b, "unseen")] for b in BASELINE_NAMES),
        "error_reduction_seen": reduction["seen"],
        "error_reduction_unseen": reduction["unseen"],
    }
    if smoke:
        assert reduction["seen"] >= MIN_ERROR_REDUCTION, (
            f"seen-hw error reduction {reduction['seen']:.2f}x < "
            f"{MIN_ERROR_REDUCTION}x over the best baseline"
        )
        assert reduction["unseen"] >= MIN_ERROR_REDUCTION, (
            f"unseen-hw error reduction {reduction['unseen']:.2f}x < "
            f"{MIN_ERROR_REDUCTION}x over the best baseline"
        )
        assert results["mape_seen"] <= MAX_SEEN_MAPE, (
            f"seen-hw MAPE {results['mape_seen']:.1f}% > {MAX_SEEN_MAPE}% cap"
        )
        assert results["mape_unseen"] <= MAX_UNSEEN_MAPE, (
            f"unseen-hw MAPE {results['mape_unseen']:.1f}% > {MAX_UNSEEN_MAPE}% cap"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert MAPE caps + error-reduction floors (CI gate)")
    ap.add_argument("--json", help="write BENCH_kernel_mape.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,value,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
