"""Paper Fig 4: ablation of the analytical feature families — full model vs
w/o MIO features, w/o Math features, and w/o MLP (roofline predictor) on the
GEMM and Attention datasets."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, get_dataset
from repro.core.dataset import SEEN, mape
from repro.core.features import PIPES
from repro.core.nn import fit_mlp

MATH = [i for i, p in enumerate(PIPES) if p in ("mxu", "vpu", "xu")]
MIO = [i for i, p in enumerate(PIPES) if p in ("hbm", "vmem")]


def _mask_cols(X, pipes_idx):
    X = X.copy()
    for i in pipes_idx:
        X[:, 5 * i : 5 * i + 5] = 0.0
    n = 5 * len(PIPES)
    # also zero the pipe-balance ratios of the ablated pipes
    for i in pipes_idx:
        X[:, n + 3 + i] = 0.0
    return X


def run(csv: Csv):
    for kind in ("gemm", "attention"):
        ds = get_dataset(kind)
        seen = np.array([h in SEEN for h in ds.hw_names])
        tr_m = seen  # train split on seen hw
        variants = {
            "full": ds.X,
            "wo_mio": _mask_cols(ds.X, MIO),
            "wo_math": _mask_cols(ds.X, MATH),
        }
        results = {}
        for name, X in variants.items():
            m = fit_mlp(X[tr_m], ds.y_eff[tr_m], seed=3, max_epochs=250)
            pred = ds.theoretical_s / np.clip(m.predict(X), 1e-3, 1.0)
            results[name] = mape(pred, ds.actual_s)
        results["wo_mlp"] = mape(ds.theoretical_s, ds.actual_s)
        for name, v in results.items():
            csv.add(f"fig4/{kind}/{name}", 0.0, f"{v:.1f}%")
        for name in ("wo_mio", "wo_math", "wo_mlp"):
            csv.add(
                f"fig4/{kind}/gain_vs_{name}",
                0.0,
                f"{results[name]/max(results['full'],1e-9):.1f}x",
            )
