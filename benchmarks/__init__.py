"""Trajectory benchmarks (``python -m benchmarks.run`` / ``benchmarks.bench_*``).

A regular package so mypy's ``packages = ["repro", "benchmarks"]`` discovery
and ``python -m benchmarks.<module>`` resolve the same files.
"""
