"""Fleet-simulator benchmark (ISSUE 6): replay a large synthetic request
stream through a routed fleet and gate the simulator's queueing physics.

Reports three things:

  * exactness — a single request entering an idle fleet waits zero, so its
    simulated latency must equal the isolated placement estimate
    (``FleetRouter`` row ``total_s``) to 1e-9. Criterion (asserted in
    ``--smoke``);
  * queueing-delay monotonicity — the same 200k-request stream (common
    random numbers: one seed, arrivals scaled by rate) replayed at 30/60/90%
    of the fleet's saturation rate must show non-decreasing p95 latency,
    strictly increasing from the lightest to the heaviest load. Criterion
    (asserted in ``--smoke``);
  * simulation overhead — host wall-clock per simulated request of the
    discrete-event replay (the O(n log replicas) heap loop). Criterion
    (asserted in ``--smoke``): under ``OVERHEAD_US_BUDGET`` per request;
  * the drift control loop (ISSUE 9) — an injected step-drift on the
    assigned hardware, sized to flip the placement once corrected, is
    replayed twice: frozen assignment vs ``monitor=`` re-routing.
    Criteria (asserted in ``--smoke``): the re-routed replay's p95 is
    *strictly* lower than the frozen one's, the drifted stream trips at
    least one re-route, and an undrifted monitored stream trips **zero**
    (false-positive bound). ``reroute_p95_ratio`` (re-routed / frozen
    p95, lower = the loop helps more) feeds the ``benchmarks.compare``
    trajectory gate.

Also reported (not gated): the routed assignment of the two-class traffic
mix, per-hardware utilization at each load point, and an autoscaled replay
at 90% load (replica trajectory endpoints, p95 vs the fixed pool).

Standalone: ``python -m benchmarks.bench_fleet [--smoke] [--json PATH]``
(non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Csv, get_pipeweave, write_bench_json
from repro.configs import get_arch
from repro.predict import FeatureCache
from repro.serve.fleet import AutoscalePolicy, FleetSimulator, WorkloadClass
from repro.serve.monitor import DriftSpec, ResidualMonitor
from repro.serve.placement import FleetRouter

N_REQUESTS = 200_000
LOAD_FRACTIONS = (0.3, 0.6, 0.9)
REPLICAS = 4
OVERHEAD_US_BUDGET = 50.0  # generous for shared CI runners; locally ~3us
SEED = 3
# drift control loop: event-by-event Python path, so a smaller stream
N_DRIFT = 50_000
DRIFT_LOAD = 0.6  # fraction of the *undrifted* saturation rate

#: the artifact's schema: every key write_bench_json must carry
#: (tests/test_bench_schemas.py checks the compare.py gates against this)
BENCH_KEYS = (
    "n_requests", "assignment", "saturation_rate_rps",
    "empty_fleet_abs_err_s", "load_fractions", "p95_s", "max_utilization",
    "sim_overhead_us_per_request", "autoscaled_p95_s", "autoscale_replicas",
    "drift_hw", "drift_factor", "reroute_count_drifted",
    "reroute_count_undrifted", "p95_frozen_drifted_s",
    "p95_rerouted_drifted_s", "reroute_p95_ratio",
)


def _build_sim() -> FleetSimulator:
    cfg = get_arch("qwen3-0.6b").smoke()
    chat = WorkloadClass("chat", cfg, B=1, lin=256, lout=32, weight=3.0)
    bulk = WorkloadClass("bulk", cfg, B=1, lin=1024, lout=64, weight=1.0)
    router = FleetRouter(estimator=get_pipeweave(), cache=FeatureCache())
    return FleetSimulator([chat, bulk], router=router, replicas=REPLICAS)


def run(csv: Csv, smoke: bool = False) -> dict:
    sim = _build_sim()
    sat = sim.saturation_rate_rps()
    csv.add("fleet/saturation_rate_rps", 0.0, f"{sat:.1f} req/s, "
            f"{REPLICAS} replicas, assignment={sim.assignment}")

    # exactness: idle fleet == isolated placement estimate
    single = sim.replay(arrivals=np.array([0.0]), class_ids=np.array([0]))
    svc = sim.service_s("chat")
    exact_err = abs(single.latency_p50_s - svc)
    csv.add("fleet/empty_fleet_abs_err_s", exact_err,
            f"sim {single.latency_p50_s:.9g}s vs placement {svc:.9g}s")

    # monotonicity + overhead over the big stream
    p95s, utils = [], []
    wall_total = 0.0
    for frac in LOAD_FRACTIONS:
        t0 = time.perf_counter()
        report = sim.replay(rate_rps=frac * sat, n_requests=N_REQUESTS, seed=SEED)
        wall = time.perf_counter() - t0
        wall_total += wall
        p95s.append(report.latency_p95_s)
        util = max(l.utilization for l in report.per_hw.values())
        utils.append(util)
        csv.add(f"fleet/p95_ms_at_{int(frac*100)}pct", report.latency_p95_s * 1e3,
                f"util {util:.1%}, {N_REQUESTS} reqs in {wall:.2f}s")
    overhead_us = wall_total / (len(LOAD_FRACTIONS) * N_REQUESTS) * 1e6
    csv.add("fleet/sim_overhead_us_per_request", overhead_us,
            f"{len(LOAD_FRACTIONS)}x{N_REQUESTS} requests, {wall_total:.2f}s total")

    # autoscaling at the heaviest load (reported, not gated)
    policy = AutoscalePolicy(window_s=200 * svc, target_utilization=0.6,
                             min_replicas=REPLICAS, max_replicas=32)
    fixed_p95 = p95s[-1]
    scaled = sim.replay(rate_rps=LOAD_FRACTIONS[-1] * sat,
                        n_requests=N_REQUESTS, seed=SEED, autoscale=policy)
    traj = {hw: (l.replicas, l.final_replicas) for hw, l in scaled.per_hw.items()}
    csv.add("fleet/autoscaled_p95_ms", scaled.latency_p95_s * 1e3,
            f"fixed {fixed_p95*1e3:.2f}ms, replicas {traj}")

    # drift control loop: step-drift the dominant assigned hardware by a
    # factor sized to flip the placement once the monitor corrects for it
    # (1.5x the best-vs-runner-up service ratio, at least 2x), then replay
    # the same stream frozen vs monitored
    drift_hw = sim.assignment["chat"]
    chat_rows = sim.placements["chat"]
    runner_up = next(r for r in chat_rows.rows if r.hw != drift_hw)
    drift_factor = max(2.0, 1.5 * runner_up.total_s / chat_rows[drift_hw].total_s)
    drift = DriftSpec(hw=drift_hw, factor=drift_factor)
    drift_rate = DRIFT_LOAD * sat

    calm = sim.replay(rate_rps=drift_rate, n_requests=N_DRIFT, seed=SEED,
                      monitor=ResidualMonitor())
    frozen = sim.replay(rate_rps=drift_rate, n_requests=N_DRIFT, seed=SEED,
                        drift=drift)
    routed = sim.replay(rate_rps=drift_rate, n_requests=N_DRIFT, seed=SEED,
                        drift=drift, monitor=ResidualMonitor())
    ratio = routed.latency_p95_s / frozen.latency_p95_s
    csv.add("fleet/reroute_p95_ratio", ratio,
            f"{drift_factor:.2f}x drift on {drift_hw}: frozen p95 "
            f"{frozen.latency_p95_s*1e3:.2f}ms, re-routed "
            f"{routed.latency_p95_s*1e3:.2f}ms, {len(routed.reroutes)} "
            f"re-route(s), {len(calm.reroutes)} on the calm stream")

    results = {
        "n_requests": N_REQUESTS,
        "assignment": sim.assignment,
        "saturation_rate_rps": sat,
        "empty_fleet_abs_err_s": exact_err,
        "load_fractions": list(LOAD_FRACTIONS),
        "p95_s": p95s,
        "max_utilization": utils,
        "sim_overhead_us_per_request": overhead_us,
        "autoscaled_p95_s": scaled.latency_p95_s,
        "autoscale_replicas": traj,
        "drift_hw": drift_hw,
        "drift_factor": drift_factor,
        "reroute_count_drifted": len(routed.reroutes),
        "reroute_count_undrifted": len(calm.reroutes),
        "p95_frozen_drifted_s": frozen.latency_p95_s,
        "p95_rerouted_drifted_s": routed.latency_p95_s,
        "reroute_p95_ratio": ratio,
    }
    if smoke:
        assert exact_err <= 1e-9, (
            f"empty-fleet latency {single.latency_p50_s!r} deviates from the "
            f"isolated placement estimate {svc!r} by {exact_err:.3g}s > 1e-9"
        )
        assert p95s[0] <= p95s[1] <= p95s[2] and p95s[2] > p95s[0], (
            f"p95 latency not monotone in arrival rate: {p95s} at loads "
            f"{LOAD_FRACTIONS} of saturation"
        )
        assert overhead_us <= OVERHEAD_US_BUDGET, (
            f"fleet simulation costs {overhead_us:.1f}us per request > "
            f"{OVERHEAD_US_BUDGET}us budget"
        )
        assert len(calm.reroutes) == 0, (
            f"undrifted monitored replay tripped {len(calm.reroutes)} "
            f"re-route(s): {calm.reroutes} — the sustained-residual "
            "threshold is supposed to bound false positives to zero"
        )
        assert len(routed.reroutes) >= 1, (
            f"{drift_factor:.2f}x step drift on {drift_hw} never tripped "
            "the monitor"
        )
        assert routed.latency_p95_s < frozen.latency_p95_s, (
            f"re-routed p95 {routed.latency_p95_s:.4g}s not strictly below "
            f"the frozen assignment's {frozen.latency_p95_s:.4g}s under "
            f"{drift_factor:.2f}x drift on {drift_hw}"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert exactness + monotonicity + overhead (CI gate)")
    ap.add_argument("--json", help="write BENCH_fleet.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,value,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results,
                         passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
