"""§Roofline deliverable: three-term roofline per (arch x shape x mesh) from
the REAL compiled dry-run artifacts (results/dryrun)."""
from __future__ import annotations

from benchmarks.common import Csv
from repro.roofline.analysis import load_rows, pick_hillclimb_cells


def run(csv: Csv):
    rows = load_rows()
    if not rows:
        csv.add("roofline/status", 0.0, "no dryrun artifacts (run repro.launch.dryrun --all)")
        return
    for r in rows:
        csv.add(
            f"roofline/{r.arch}/{r.shape}/{r.mesh}",
            0.0,
            f"compute={r.compute_s:.3e}s|mem={r.memory_s:.3e}s|coll={r.collective_s:.3e}s"
            f"|dominant={r.dominant}|useful={r.useful_ratio:.2f}|frac={r.roofline_fraction:.2f}",
        )
    picks = pick_hillclimb_cells(rows)
    for why, r in picks.items():
        csv.add(f"roofline/hillclimb/{why}", 0.0, f"{r.arch}/{r.shape}/{r.mesh}")
