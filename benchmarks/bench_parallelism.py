"""Parallelism-aware prediction smoke (ISSUE 5): EP all-to-all byte
exactness, pipeline bubble-model exactness, and the 1F1B-beats-GPipe
margin.

Three standing criteria (asserted under ``--smoke``, the CI gate):

1. **EP-bytes exactness** — ``core.decomposer.ep_alltoall_bytes`` (the
   workload-dict arithmetic the e2e ``CommCall``s carry) equals
   ``launch.dryrun.count_ep_alltoall_bytes`` (the ledger counted through
   the executed model layer's ``dispatch_geometry``) *exactly*, on every
   MoE arch in the registry across prefill/decode/train shapes.
2. **Bubble-model exactness** — the closed-form ``schedule_ticks`` equals
   the event-driven ring simulation for GPipe and interleaved 1F1B over
   the whole (S, M, V) grid (the executed shard_map schedules are pinned
   to the same counts in tier-1 ``tests/test_dist.py``).
3. **1F1B margin** — at the production point (S=4, M=2S, V=2) the
   interleaved bubble fraction must stay <= ``MAX_BUBBLE_RATIO`` x
   GPipe's (analytically (S-1)/(V*M+S-1) vs (S-1)/(M+S-1) ~ 0.58x).

Standalone: ``python -m benchmarks.bench_parallelism [--smoke] [--json
PATH]`` (non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

# init the backend before repro.launch.dryrun pins XLA_FLAGS (the 512
# virtual dry-run devices are for the real lowering runs, not this smoke)
jax.devices()

from benchmarks.common import Csv, write_bench_json  # noqa: E402

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "moe_archs", "ep_cells", "ep_max_rel_diff", "ep_commcalls_exact",
    "ep_swept_per_hw", "bubble_grid_points", "bubble_grid_mismatches",
    "bubble_gpipe", "bubble_1f1b", "bubble_ratio",
    "max_bubble_ratio_target",
)
from repro.configs import get_arch, list_archs  # noqa: E402
from repro.core.decomposer import COMPUTE_DTYPE_BYTES, ep_alltoall_bytes  # noqa: E402
from repro.core.e2e import layer_calls, pp_bubble  # noqa: E402
from repro.core.hardware import get_hw  # noqa: E402
from repro.dist.pipeline import bubble_fraction, schedule_ticks, simulate_schedule  # noqa: E402
from repro.launch.dryrun import count_ep_alltoall_bytes  # noqa: E402
from repro.predict import CommCall, SweepPredictor  # noqa: E402

#: 1F1B bubble must be at most this fraction of GPipe's at the gate point
MAX_BUBBLE_RATIO = 0.65
GATE_S, GATE_V = 4, 2

EP_SHAPES = ((32, 2048, False), (4, 128, False), (128, 1, False), (8, 512, True))


def run(csv: Csv, smoke: bool = False) -> dict:
    # ---- 1. EP byte exactness across the MoE registry -------------------
    moe_archs = [a for a in list_archs() if get_arch(a).n_experts]
    n_cells = 0
    max_rel = 0.0
    t0 = time.perf_counter()
    for arch in moe_archs:
        cfg = get_arch(arch)
        for B, qlen, train in EP_SHAPES:
            led = count_ep_alltoall_bytes(cfg, B, qlen, train=train)
            cf = cfg.capacity_factor if train else max(cfg.capacity_factor, 2.0)
            mine = ep_alltoall_bytes({
                "T": B * qlen, "d": cfg.d_model, "E": cfg.n_experts,
                "topk": cfg.top_k, "capacity_factor": cf,
                "moe_group": cfg.moe_group,
                "dtype_bytes": COMPUTE_DTYPE_BYTES[cfg.compute_dtype],
            })
            rel = abs(mine - led["dispatch_bytes"]) / max(led["dispatch_bytes"], 1.0)
            max_rel = max(max_rel, rel)
            n_cells += 1
    ep_s = time.perf_counter() - t0
    csv.add("parallelism/ep_bytes_cells", ep_s * 1e6 / max(n_cells, 1),
            f"{n_cells} (arch x shape) cells, max rel diff {max_rel:.1e}")
    ep_exact = max_rel == 0.0

    # the modeled calls carry exactly these bytes (spot check on dbrx)
    cfg = get_arch("dbrx-132b")
    a2a = [c for c in layer_calls(cfg, 4, 128, 128, tp=4)
           if isinstance(c, CommCall) and c.op == "all_to_all"]
    led = count_ep_alltoall_bytes(cfg, 4, 128)
    calls_exact = (len(a2a) == 2
                   and all(c.nbytes == led["dispatch_bytes"] for c in a2a))
    nbytes_str = f"{a2a[0].nbytes:.3e}B" if a2a else "none emitted"
    csv.add("parallelism/ep_commcalls", 0.0,
            f"dbrx layer: {len(a2a)} all_to_all x {nbytes_str} "
            f"({'exact' if calls_exact else 'MISMATCH'})")

    # ...and a sweep prices them per hardware
    trace = [("step", 1.0, layer_calls(cfg, 2, 1, 256, tp=4))]
    res = SweepPredictor(["tpu-v5e", "tpu-v6e"], "roofline").predict(trace)
    per_hw_a2a = {n: e.by_comm_op.get("all_to_all", 0.0) for n, e in res.items()}
    swept = all(v > 0 for v in per_hw_a2a.values())
    csv.add("parallelism/ep_swept", 0.0,
            " ".join(f"{n}={v*1e6:.1f}us" for n, v in per_hw_a2a.items()))

    # ---- 2. bubble-model exactness over the schedule grid ----------------
    t0 = time.perf_counter()
    n_grid = 0
    mismatches = 0
    for S in range(1, 9):
        for M in range(1, 25):
            if simulate_schedule(S, M, "gpipe") != schedule_ticks(S, M, "gpipe"):
                mismatches += 1
            n_grid += 1
            for V in (1, 2, 3, 4):
                if simulate_schedule(S, M, "1f1b", V) != schedule_ticks(S, M, "1f1b", V):
                    mismatches += 1
                n_grid += 1
    grid_s = time.perf_counter() - t0
    csv.add("parallelism/bubble_grid", grid_s * 1e6 / n_grid,
            f"{n_grid} (S,M,V) schedules, {mismatches} sim-vs-closed-form "
            "mismatches")

    # ---- 3. 1F1B margin at the production point --------------------------
    M = 2 * GATE_S
    b_gp = bubble_fraction(GATE_S, M, "gpipe")
    b_il = bubble_fraction(GATE_S, M, "1f1b", GATE_V)
    ratio = b_il / b_gp
    csv.add("parallelism/bubble_gpipe", 0.0, f"{b_gp:.4f} (S={GATE_S}, M={M})")
    csv.add("parallelism/bubble_1f1b", 0.0,
            f"{b_il:.4f} (V={GATE_V}) = {ratio:.2f}x gpipe "
            f"(target <={MAX_BUBBLE_RATIO}x)")
    csv.add("parallelism/pp_surcharge", 0.0,
            f"gpipe {pp_bubble(GATE_S, M):.4f}x vs 1f1b "
            f"{pp_bubble(GATE_S, M, '1f1b', GATE_V):.4f}x")

    results = {
        "moe_archs": moe_archs,
        "ep_cells": n_cells,
        "ep_max_rel_diff": max_rel,
        "ep_commcalls_exact": calls_exact,
        "ep_swept_per_hw": {n: v for n, v in per_hw_a2a.items()},
        "bubble_grid_points": n_grid,
        "bubble_grid_mismatches": mismatches,
        "bubble_gpipe": b_gp,
        "bubble_1f1b": b_il,
        "bubble_ratio": ratio,
        "max_bubble_ratio_target": MAX_BUBBLE_RATIO,
    }
    if smoke:
        assert ep_exact, (
            f"EP all-to-all bytes diverged from the dry-run ledger "
            f"(max rel diff {max_rel:.2e} over {n_cells} cells) — "
            "decomposer.ep_alltoall_bytes vs models.moe.dispatch_geometry drift"
        )
        assert calls_exact, "layer_calls EP CommCalls lost byte exactness"
        assert swept, f"sweep failed to price EP traffic per hw: {per_hw_a2a}"
        assert mismatches == 0, (
            f"{mismatches} schedule grid points where the closed-form tick "
            "count diverged from the ring simulation"
        )
        assert ratio <= MAX_BUBBLE_RATIO, (
            f"1F1B bubble is {ratio:.2f}x GPipe's at S={GATE_S}, M={M} "
            f"(target <={MAX_BUBBLE_RATIO}x) — interleaving regressed"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the exactness + margin criteria (CI gate)")
    ap.add_argument("--json", help="write BENCH_parallelism.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,us_per_call,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
