"""Parallelism-aware prediction smoke (ISSUE 5): EP all-to-all byte
exactness, pipeline bubble-model exactness, and the 1F1B-beats-GPipe
margin.

Three standing criteria (asserted under ``--smoke``, the CI gate):

1. **EP-bytes exactness** — ``core.decomposer.ep_alltoall_bytes`` (the
   workload-dict arithmetic the e2e ``CommCall``s carry) equals
   ``launch.dryrun.count_ep_alltoall_bytes`` (the ledger counted through
   the executed model layer's ``dispatch_geometry``) *exactly*, on every
   MoE arch in the registry across prefill/decode/train shapes.
2. **Bubble-model exactness** — the closed-form ``schedule_ticks`` equals
   the event-driven ring simulation for GPipe and interleaved 1F1B over
   the whole (S, M, V) grid (the executed shard_map schedules are pinned
   to the same counts in tier-1 ``tests/test_dist.py``).
3. **1F1B margin** — at the production point (S=4, M=2S, V=2) the
   interleaved bubble fraction must stay <= ``MAX_BUBBLE_RATIO`` x
   GPipe's (analytically (S-1)/(V*M+S-1) vs (S-1)/(M+S-1) ~ 0.58x).
4. **ZB-H1 margin** (ISSUE 10) — at the same point the zero-bubble
   schedule's bubble must stay <= ``MAX_ZB_RATIO`` x 1F1B's
   (analytically r/(3VM+r... exactly 3/51 vs 3/19 = 19/51 ~ 0.37x),
   with the zb-h1 grid folded into criterion 2's exactness sweep.
5. **Overlap bound** (ISSUE 10) — the overlap-priced estimate of a
   >=12k-call decode trace lands in ``[kernel-only, additive]`` and
   actually engages (strictly below additive when comm exists).

Standalone: ``python -m benchmarks.bench_parallelism [--smoke] [--json
PATH]`` (non-zero exit when a smoke criterion fails — the CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

# init the backend before repro.launch.dryrun pins XLA_FLAGS (the 512
# virtual dry-run devices are for the real lowering runs, not this smoke)
jax.devices()

from benchmarks.common import Csv, write_bench_json  # noqa: E402

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "moe_archs", "ep_cells", "ep_max_rel_diff", "ep_commcalls_exact",
    "ep_swept_per_hw", "bubble_grid_points", "bubble_grid_mismatches",
    "bubble_gpipe", "bubble_1f1b", "bubble_ratio",
    "max_bubble_ratio_target",
    "bubble_zb_h1", "zb_ratio", "max_zb_ratio_target",
    "overlap_trace_calls", "overlap_total_ratio", "overlap_bounded",
)
from repro.configs import get_arch, list_archs  # noqa: E402
from repro.core.decomposer import COMPUTE_DTYPE_BYTES, ep_alltoall_bytes  # noqa: E402
from repro.core.e2e import layer_calls, pp_bubble  # noqa: E402
from repro.core.hardware import get_hw  # noqa: E402
from repro.dist.pipeline import bubble_fraction, schedule_ticks, simulate_schedule  # noqa: E402
from repro.launch.dryrun import count_ep_alltoall_bytes  # noqa: E402
from repro.predict import CommCall, SweepPredictor, get_predictor  # noqa: E402

#: 1F1B bubble must be at most this fraction of GPipe's at the gate point
MAX_BUBBLE_RATIO = 0.65
#: ZB-H1 bubble must be at most this fraction of 1F1B's at the same point
#: (analytically (3/51)/(3/19) = 19/51 ~ 0.373)
MAX_ZB_RATIO = 0.4
GATE_S, GATE_V = 4, 2

EP_SHAPES = ((32, 2048, False), (4, 128, False), (128, 1, False), (8, 512, True))


def run(csv: Csv, smoke: bool = False) -> dict:
    # ---- 1. EP byte exactness across the MoE registry -------------------
    moe_archs = [a for a in list_archs() if get_arch(a).n_experts]
    n_cells = 0
    max_rel = 0.0
    t0 = time.perf_counter()
    for arch in moe_archs:
        cfg = get_arch(arch)
        for B, qlen, train in EP_SHAPES:
            led = count_ep_alltoall_bytes(cfg, B, qlen, train=train)
            cf = cfg.capacity_factor if train else max(cfg.capacity_factor, 2.0)
            mine = ep_alltoall_bytes({
                "T": B * qlen, "d": cfg.d_model, "E": cfg.n_experts,
                "topk": cfg.top_k, "capacity_factor": cf,
                "moe_group": cfg.moe_group,
                "dtype_bytes": COMPUTE_DTYPE_BYTES[cfg.compute_dtype],
            })
            rel = abs(mine - led["dispatch_bytes"]) / max(led["dispatch_bytes"], 1.0)
            max_rel = max(max_rel, rel)
            n_cells += 1
    ep_s = time.perf_counter() - t0
    csv.add("parallelism/ep_bytes_cells", ep_s * 1e6 / max(n_cells, 1),
            f"{n_cells} (arch x shape) cells, max rel diff {max_rel:.1e}")
    ep_exact = max_rel == 0.0

    # the modeled calls carry exactly these bytes (spot check on dbrx)
    cfg = get_arch("dbrx-132b")
    a2a = [c for c in layer_calls(cfg, 4, 128, 128, tp=4)
           if isinstance(c, CommCall) and c.op == "all_to_all"]
    led = count_ep_alltoall_bytes(cfg, 4, 128)
    calls_exact = (len(a2a) == 2
                   and all(c.nbytes == led["dispatch_bytes"] for c in a2a))
    nbytes_str = f"{a2a[0].nbytes:.3e}B" if a2a else "none emitted"
    csv.add("parallelism/ep_commcalls", 0.0,
            f"dbrx layer: {len(a2a)} all_to_all x {nbytes_str} "
            f"({'exact' if calls_exact else 'MISMATCH'})")

    # ...and a sweep prices them per hardware
    trace = [("step", 1.0, layer_calls(cfg, 2, 1, 256, tp=4))]
    res = SweepPredictor(["tpu-v5e", "tpu-v6e"], "roofline").predict(trace)
    per_hw_a2a = {n: e.by_comm_op.get("all_to_all", 0.0) for n, e in res.items()}
    swept = all(v > 0 for v in per_hw_a2a.values())
    csv.add("parallelism/ep_swept", 0.0,
            " ".join(f"{n}={v*1e6:.1f}us" for n, v in per_hw_a2a.items()))

    # ---- 2. bubble-model exactness over the schedule grid ----------------
    t0 = time.perf_counter()
    n_grid = 0
    mismatches = 0
    for S in range(1, 9):
        for M in range(1, 25):
            if simulate_schedule(S, M, "gpipe") != schedule_ticks(S, M, "gpipe"):
                mismatches += 1
            n_grid += 1
            for V in (1, 2, 3, 4):
                for sched in ("1f1b", "zb-h1"):
                    if simulate_schedule(S, M, sched, V) != schedule_ticks(S, M, sched, V):
                        mismatches += 1
                    n_grid += 1
    grid_s = time.perf_counter() - t0
    csv.add("parallelism/bubble_grid", grid_s * 1e6 / n_grid,
            f"{n_grid} (S,M,V) schedules, {mismatches} sim-vs-closed-form "
            "mismatches")

    # ---- 3. 1F1B margin at the production point --------------------------
    M = 2 * GATE_S
    b_gp = bubble_fraction(GATE_S, M, "gpipe")
    b_il = bubble_fraction(GATE_S, M, "1f1b", GATE_V)
    ratio = b_il / b_gp
    csv.add("parallelism/bubble_gpipe", 0.0, f"{b_gp:.4f} (S={GATE_S}, M={M})")
    csv.add("parallelism/bubble_1f1b", 0.0,
            f"{b_il:.4f} (V={GATE_V}) = {ratio:.2f}x gpipe "
            f"(target <={MAX_BUBBLE_RATIO}x)")
    csv.add("parallelism/pp_surcharge", 0.0,
            f"gpipe {pp_bubble(GATE_S, M):.4f}x vs 1f1b "
            f"{pp_bubble(GATE_S, M, '1f1b', GATE_V):.4f}x vs zb-h1 "
            f"{pp_bubble(GATE_S, M, 'zb-h1', GATE_V):.4f}x")

    # ---- 4. ZB-H1 margin at the same point -------------------------------
    b_zb = bubble_fraction(GATE_S, M, "zb-h1", GATE_V)
    zb_ratio = b_zb / b_il
    csv.add("parallelism/bubble_zb_h1", 0.0,
            f"{b_zb:.4f} (V={GATE_V}) = {zb_ratio:.2f}x 1f1b "
            f"(target <={MAX_ZB_RATIO}x)")

    # ---- 5. overlap-priced estimate bounded on a long decode trace -------
    step_calls = layer_calls(cfg, 2, 1, 256, tp=4)
    repeats = max(1, -(-12_000 // len(step_calls)))  # >= 12k calls total
    trace_calls = step_calls * repeats
    t0 = time.perf_counter()
    roofline = get_predictor("roofline", get_hw("tpu-v5e"))
    add = roofline.predict(trace_calls)
    ovl = add.overlapped()
    overlap_s = time.perf_counter() - t0
    overlap_ratio = ovl.total_s / add.total_s if add.total_s > 0 else 1.0
    overlap_bounded = (add.kernel_s - 1e-12 <= ovl.total_s <= add.total_s + 1e-12
                       and ovl.total_s < add.total_s)
    csv.add("parallelism/overlap_trace", overlap_s * 1e6 / len(trace_calls),
            f"{len(trace_calls)} calls: overlap {ovl.total_s*1e3:.2f}ms = "
            f"{overlap_ratio:.3f}x additive {add.total_s*1e3:.2f}ms "
            f"({'bounded' if overlap_bounded else 'OUT OF BOUNDS'})")

    results = {
        "moe_archs": moe_archs,
        "ep_cells": n_cells,
        "ep_max_rel_diff": max_rel,
        "ep_commcalls_exact": calls_exact,
        "ep_swept_per_hw": {n: v for n, v in per_hw_a2a.items()},
        "bubble_grid_points": n_grid,
        "bubble_grid_mismatches": mismatches,
        "bubble_gpipe": b_gp,
        "bubble_1f1b": b_il,
        "bubble_ratio": ratio,
        "max_bubble_ratio_target": MAX_BUBBLE_RATIO,
        "bubble_zb_h1": b_zb,
        "zb_ratio": zb_ratio,
        "max_zb_ratio_target": MAX_ZB_RATIO,
        "overlap_trace_calls": len(trace_calls),
        "overlap_total_ratio": overlap_ratio,
        "overlap_bounded": overlap_bounded,
    }
    if smoke:
        assert ep_exact, (
            f"EP all-to-all bytes diverged from the dry-run ledger "
            f"(max rel diff {max_rel:.2e} over {n_cells} cells) — "
            "decomposer.ep_alltoall_bytes vs models.moe.dispatch_geometry drift"
        )
        assert calls_exact, "layer_calls EP CommCalls lost byte exactness"
        assert swept, f"sweep failed to price EP traffic per hw: {per_hw_a2a}"
        assert mismatches == 0, (
            f"{mismatches} schedule grid points where the closed-form tick "
            "count diverged from the ring simulation"
        )
        assert ratio <= MAX_BUBBLE_RATIO, (
            f"1F1B bubble is {ratio:.2f}x GPipe's at S={GATE_S}, M={M} "
            f"(target <={MAX_BUBBLE_RATIO}x) — interleaving regressed"
        )
        assert zb_ratio <= MAX_ZB_RATIO, (
            f"ZB-H1 bubble is {zb_ratio:.2f}x 1F1B's at S={GATE_S}, M={M} "
            f"(target <={MAX_ZB_RATIO}x) — the split backward stopped "
            "filling the warmup/cooldown bubble"
        )
        assert overlap_bounded, (
            f"overlap-priced trace estimate left [kernel, additive]: "
            f"kernel {add.kernel_s:.6f}s, overlap {ovl.total_s:.6f}s, "
            f"additive {add.total_s:.6f}s over {len(trace_calls)} calls"
        )
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the exactness + margin criteria (CI gate)")
    ap.add_argument("--json", help="write BENCH_parallelism.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,us_per_call,derived")
    try:
        results = run(csv, smoke=args.smoke)
        failed = False
    except AssertionError as e:
        print(f"# SMOKE FAILURE: {e}", file=sys.stderr)
        results = {"error": str(e)}
        failed = True
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=not failed)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
