"""Paper Table VII: accuracy of the analytical operation counts.

Ground truth here is the loop-aware HLO cost walk of the REAL compiled XLA
modules for the matching jnp/Pallas computations — the NCU analogue available
in this container. We compare the Kernel Decomposer + Feature Analyzer's
total MXU op counts against compiled-HLO dot FLOPs for GEMM and
FlashAttention workloads, plus the CTA/task-count consistency check
(paper §VI-B 'fully consistent')."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core.dataset import featurize
from repro.core.decomposer import decompose
from repro.core.hardware import get_hw
from repro.roofline.hlo_cost import analyze_hlo


def _hlo_dot_flops(fn, *specs) -> float:
    compiled = jax.jit(fn).lower(*specs).compile()
    return analyze_hlo(compiled.as_text()).dot_flops


def gemm_cases():
    rng = np.random.default_rng(0)
    for _ in range(12):
        M = int(rng.integers(64, 2048))
        N = int(rng.integers(1, 16)) * 128
        K = int(rng.integers(1, 16)) * 128
        yield {"M": M, "N": N, "K": K}


def attention_cases():
    rng = np.random.default_rng(1)
    for _ in range(8):
        yield {
            "bs": int(rng.integers(1, 3)),
            "nkv": int(rng.integers(1, 3)),
            "group": int(rng.integers(1, 3)),
            "hd": 64,
            "qlen": int(rng.integers(1, 5)) * 128,
            "kvlen": int(rng.integers(1, 5)) * 128,
            "causal": 0,  # XLA ref computes the full score matrix
        }


def run(csv: Csv):
    hw = get_hw("tpu-v5e")
    # --- GEMM: analytical total MXU ops vs compiled HLO dot flops ---------
    errs = []
    for w in gemm_cases():
        fs = featurize("gemm", w, hw)
        x = jax.ShapeDtypeStruct((w["M"], w["K"]), jnp.bfloat16)
        y = jax.ShapeDtypeStruct((w["K"], w["N"]), jnp.bfloat16)
        hlo = _hlo_dot_flops(lambda a, b: a @ b, x, y)
        errs.append(abs(fs.totals["mxu"] - hlo) / hlo)
    csv.add("table7/gemm_total_ops_mape_pct", 0.0, f"{100*np.mean(errs):.3f}")

    # --- Attention: alpha=4 MMA counting vs compiled HLO ------------------
    errs = []
    for w in attention_cases():
        fs = featurize("attention", w, hw)
        B, S, Sk = w["bs"], w["qlen"], w["kvlen"]
        H = w["nkv"] * w["group"]
        d = w["hd"]
        q = jax.ShapeDtypeStruct((B, H, S, d), jnp.bfloat16)
        k = jax.ShapeDtypeStruct((B, H, Sk, d), jnp.bfloat16)
        v = jax.ShapeDtypeStruct((B, H, Sk, d), jnp.bfloat16)

        def attn(q, k, v):
            s = jnp.einsum("bhqd,bhkd->bhqk", q, k)
            p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
            return jnp.einsum("bhqk,bhkd->bhqd", p, v)

        hlo = _hlo_dot_flops(attn, q, k, v)
        errs.append(abs(fs.totals["mxu"] - hlo) / hlo)
    csv.add("table7/attention_total_ops_mape_pct", 0.0, f"{100*np.mean(errs):.3f}")

    # --- task-count consistency (CTA analogue): grid size matches ---------
    mismatches = 0
    for w in gemm_cases():
        tasks = decompose("gemm", w, hw)
        from repro.core.decomposer import gemm_tile_heuristic, _ceil

        tm, tn = gemm_tile_heuristic(w["M"], w["N"], w["K"], hw)
        if len(tasks) != _ceil(w["M"], tm) * _ceil(w["N"], tn):
            mismatches += 1
    csv.add("table7/task_count_mismatches", 0.0, str(mismatches))

    # --- max-per-chip ops: static vs workqueue divergence (FA2 vs FA3 story)
    w = {"bs": 4, "nkv": 4, "group": 2, "hd": 128, "qlen": 4096, "kvlen": 4096, "causal": 1}
    fs = featurize("attention", w, hw)
    ideal = fs.totals["mxu"] / hw.num_chips
    csv.add(
        "table7/causal_max_chip_imbalance",
        0.0,
        f"{fs.max_chip['mxu']/ideal:.3f}",
    )
