"""Paper Fig 7: prediction accuracy vs simulation overhead. The detailed
simulator here is hwsim (the cycle-ish oracle); PipeWeave's prediction is one
analytical pass + one MLP forward. We report per-GEMM time for each and the
resulting error/overhead trade-off, plus the batched-predictor speedup: a
decode sweep estimated per-call via ``PipeWeave.predict_latency`` (fresh
featurize + batch-1 forward per call) vs one ``repro.predict`` batched
``predict(calls)`` (canonical-shape dedup + memoized featurize + one
vectorized forward per family). Target: >=10x."""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Csv, decode_sweep_trace, get_pipeweave, write_bench_json

#: the artifact's schema (tests/test_bench_schemas.py gates compare.py
#: keys against this)
BENCH_KEYS = (
    "trace_calls", "batched_speedup", "speedup_target",
    "rel_diff_vs_scalar", "pred_us_per_gemm", "hwsim_us_per_gemm",
)
from repro.core import hwsim
from repro.core.dataset import mape, sample_workload
from repro.core.hardware import get_hw
from repro.configs import get_arch
from repro.predict import FeatureCache, get_predictor

SPEEDUP_TARGET = 10.0  # batched predict vs per-call scalar (ISSUE 2)


def run(csv: Csv) -> dict:
    pw = get_pipeweave()
    hw = get_hw("tpu-v5e")
    rng = np.random.default_rng(11)
    workloads = [sample_workload("gemm", rng) for _ in range(60)]

    # prediction = analytical featurization + one *batched* MLP forward
    from repro.core.dataset import featurize

    t0 = time.perf_counter()
    fss = [featurize("gemm", w, hw) for w in workloads]
    X = np.stack([fs.vector(hw) for fs in fss])
    theo = np.array([fs.theoretical_s for fs in fss])
    preds = theo / pw.predict_eff("gemm", X)
    t_pred = (time.perf_counter() - t0) / len(workloads) * 1e6
    t0 = time.perf_counter()
    actual = [hwsim.simulate("gemm", w, hw) for w in workloads]
    t_sim = (time.perf_counter() - t0) / len(workloads) * 1e6

    csv.add("fig7/pipeweave_us_per_gemm", t_pred, f"mape={mape(preds, actual):.1f}%")
    csv.add("fig7/pipeline_sim_us_per_gemm", t_sim, "hwsim oracle (vectorized, NOT cycle-accurate)")
    # the paper's Fig 7 compares against cycle-accurate simulators that are
    # 3-7 orders slower; hwsim is deliberately fast, so we additionally report
    # the projected ratio vs a 10 ms/kernel cycle-accurate tool (AMALI-class)
    csv.add("fig7/speed_ratio_vs_hwsim", 0.0, f"{t_sim/max(t_pred,1e-9):.2f}x")
    csv.add("fig7/speed_ratio_vs_cycle_accurate_10ms", 0.0, f"{1e4/max(t_pred,1e-9):.0f}x")

    # ---- batched predictor API vs per-call scalar (ISSUE 2 criterion) ----
    # the workload is the kernel-invocation *trace* a serving engine would
    # issue for a lock-step decode sweep — layers unrolled, one call per
    # launch — which is exactly what per-call prediction has to chew through
    cfg = get_arch("qwen3-0.6b")
    trace = decode_sweep_trace(cfg)

    def scalar_pass():
        return sum(pw.predict_latency(c.kind, c.X, hw) for c in trace)

    def batched_pass():
        # fresh feature cache each pass: the speedup must not lean on
        # state warmed by a previous timed run
        p = get_predictor("synperf", hw, estimator=pw, cache=FeatureCache())
        return p.predict(trace)

    batched_pass()  # warm numpy/BLAS paths once
    t0 = time.perf_counter()
    scalar_total = scalar_pass()
    scalar_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    est = batched_pass()
    batched_us = (time.perf_counter() - t0) * 1e6
    speedup = scalar_us / max(batched_us, 1e-9)
    agree = abs(est.total_s - scalar_total) / max(scalar_total, 1e-12)

    csv.add("fig7/scalar_predict_latency_us_per_call", scalar_us / len(trace),
            f"{len(trace)}-call decode-sweep trace (48 steps)")
    csv.add("fig7/batched_predict_us_per_call", batched_us / len(trace),
            f"rel_diff_vs_scalar={agree:.2e}")
    csv.add("fig7/batched_speedup", 0.0,
            f"{speedup:.1f}x (target >={SPEEDUP_TARGET:.0f}x, ISSUE 2)")
    return {
        "trace_calls": len(trace),
        "batched_speedup": speedup,
        "speedup_target": SPEEDUP_TARGET,
        "rel_diff_vs_scalar": agree,
        "pred_us_per_gemm": t_pred,
        "hwsim_us_per_gemm": t_sim,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help=f"exit non-zero unless batched speedup >= "
                         f"{SPEEDUP_TARGET:.0f}x (the CI gate)")
    ap.add_argument("--json", help="write BENCH_overhead.json-style artifact here")
    args = ap.parse_args(argv)
    csv = Csv()
    print("name,us_per_call,derived")
    results = run(csv)
    ok = results["batched_speedup"] >= SPEEDUP_TARGET
    if args.check and not ok:
        print(
            f"# CHECK FAILURE: batched speedup {results['batched_speedup']:.1f}x "
            f"< {SPEEDUP_TARGET:.0f}x target",
            file=sys.stderr,
        )
    if args.json:
        write_bench_json(args.json, csv, declared=BENCH_KEYS, **results, passed=bool(ok))
    return 0 if (ok or not args.check) else 1


if __name__ == "__main__":
    sys.exit(main())
