"""Paper Fig 7: prediction accuracy vs simulation overhead. The detailed
simulator here is hwsim (the cycle-ish oracle); PipeWeave's prediction is one
analytical pass + one MLP forward. We report per-GEMM time for each and the
resulting error/overhead trade-off."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, get_dataset, get_pipeweave
from repro.core import hwsim
from repro.core.dataset import mape, sample_workload
from repro.core.hardware import get_hw


def run(csv: Csv):
    pw = get_pipeweave()
    hw = get_hw("tpu-v5e")
    rng = np.random.default_rng(11)
    workloads = [sample_workload("gemm", rng) for _ in range(60)]

    # prediction = analytical featurization + one *batched* MLP forward
    from repro.core.dataset import featurize

    t0 = time.perf_counter()
    fss = [featurize("gemm", w, hw) for w in workloads]
    X = np.stack([fs.vector(hw) for fs in fss])
    theo = np.array([fs.theoretical_s for fs in fss])
    preds = theo / pw.predict_eff("gemm", X)
    t_pred = (time.perf_counter() - t0) / len(workloads) * 1e6

    t0 = time.perf_counter()
    actual = [hwsim.simulate("gemm", w, hw) for w in workloads]
    t_sim = (time.perf_counter() - t0) / len(workloads) * 1e6

    csv.add("fig7/pipeweave_us_per_gemm", t_pred, f"mape={mape(preds, actual):.1f}%")
    csv.add("fig7/pipeline_sim_us_per_gemm", t_sim, "hwsim oracle (vectorized, NOT cycle-accurate)")
    # the paper's Fig 7 compares against cycle-accurate simulators that are
    # 3-7 orders slower; hwsim is deliberately fast, so we additionally report
    # the projected ratio vs a 10 ms/kernel cycle-accurate tool (AMALI-class)
    csv.add("fig7/speed_ratio_vs_hwsim", 0.0, f"{t_sim/max(t_pred,1e-9):.2f}x")
    csv.add("fig7/speed_ratio_vs_cycle_accurate_10ms", 0.0, f"{1e4/max(t_pred,1e-9):.0f}x")
