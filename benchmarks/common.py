"""Shared benchmark infrastructure: dataset/model caching so the suite can
run module-by-module without retraining, and CSV emission helpers."""
from __future__ import annotations

import json
import os
import pickle
import time


from repro.core.dataset import KERNELS, build_dataset
from repro.core.estimator import PipeWeave, train_pipeweave
from repro.core.hardware import TPUSpec
from repro.predict import CommRegressor, FeatureCache, get_predictor

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")
# dataset sizes tuned for the single-CPU-core container; the paper's full
# sweep is the same code with n_workloads scaled up
N_WORKLOADS = int(os.environ.get("REPRO_BENCH_WORKLOADS", "220"))
MAX_EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "250"))


def _path(name: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, name)


def get_dataset(kind: str):
    p = _path(f"ds_{kind}_{N_WORKLOADS}.pkl")
    if os.path.exists(p):
        with open(p, "rb") as f:
            return pickle.load(f)
    ds = build_dataset(kind, n_workloads=N_WORKLOADS, seed=hash(kind) % 2**31)
    with open(p, "wb") as f:
        pickle.dump(ds, f)
    return ds


def get_all_datasets():
    return {k: get_dataset(k) for k in KERNELS}


def get_pipeweave() -> PipeWeave:
    p = _path(f"pipeweave_{N_WORKLOADS}_{MAX_EPOCHS}.pkl")
    if os.path.exists(p):
        try:
            return PipeWeave.load(p)
        except RuntimeError as e:  # stale / pre-versioning cache: retrain
            print(f"# discarding stale estimator cache: {e}")
            os.remove(p)
    pw = train_pipeweave(get_all_datasets(), max_epochs=MAX_EPOCHS)
    pw.save(p)
    return pw


def get_baseline(name: str, kind: str):
    from repro.core.baselines import BASELINES

    p = _path(f"baseline_{name}_{kind}_{N_WORKLOADS}.pkl")
    if os.path.exists(p):
        with open(p, "rb") as f:
            return pickle.load(f)
    b = BASELINES[name]().fit(get_dataset(kind))
    with open(p, "wb") as f:
        pickle.dump(b, f)
    return b


_COMMS: dict = {}


def get_comm(hw: TPUSpec) -> CommRegressor:
    """Per-hardware fitted CommRegressor, memoized for the process."""
    if hw.name not in _COMMS:
        _COMMS[hw.name] = CommRegressor().fit(hw)
    return _COMMS[hw.name]


# baseline backends that wrap fitted per-family models; "roofline" is
# analytic and needs none
E2E_KERNELS = ("gemm", "attention", "rmsnorm", "silu_mul", "fused_moe")
FITTED_BACKENDS = ("linear", "habitat", "neusight")


_BACKENDS: dict = {}
# FeatureCache keys on (kind, hw.name, workload), so one shared cache
# serves every backend on every hardware
_FEAT_CACHE = FeatureCache()


def get_backend(name: str, hw: TPUSpec, **kw):
    """A registered predictor backend wired to the cached fitted artifacts
    (PipeWeave / per-family baselines / comm regressor). Instances are
    memoized per (name, hw) and share one FeatureCache so repeated
    benchmark cells never re-featurize a shape."""
    key = (name, hw.name, tuple(sorted(kw.items())))
    if key in _BACKENDS:
        return _BACKENDS[key]
    kw.setdefault("comm", get_comm(hw))
    kw.setdefault("cache", _FEAT_CACHE)
    if name == "synperf":
        backend = get_predictor(name, hw, estimator=get_pipeweave(), **kw)
    elif name in FITTED_BACKENDS:
        models = {k: get_baseline(name, k) for k in E2E_KERNELS}
        backend = get_predictor(name, hw, models=models, **kw)
    else:
        backend = get_predictor(name, hw, **kw)
    _BACKENDS[key] = backend
    return backend


def decode_sweep_trace(cfg, B: int = 8, lin: int = 256, steps: int = 48) -> list:
    """The unrolled kernel-invocation trace of a lock-step decode sweep:
    one ``model_calls`` group per generated token with growing KV, fully
    flattened to unit-count calls (~12k calls at the default shape for
    qwen3-0.6b) — the workload the batched/sweep predictors are scored on."""
    from repro.core.e2e import model_calls
    from repro.predict import KernelCall, flatten_calls

    nested = [
        (f"decode@{lin + i}", 1.0, model_calls(cfg, B, 1, lin + i, tp=1))
        for i in range(steps)
    ]
    trace = []
    for call, w in flatten_calls(nested):
        # unit-count copies: flatten already folded call.count into w
        trace += [KernelCall(call.kind, call.X)] * int(round(w))
    return trace


def write_bench_json(path: str, csv: "Csv", declared=None, **extra):
    """Dump a benchmark's CSV rows (plus structured extras) as the
    ``BENCH_*.json`` artifact the CI bench job uploads and gates on.

    ``declared=`` is the writer's schema (its module-level ``BENCH_KEYS``
    tuple): every declared key must actually be in the payload, so a
    renamed metric fails the writer loudly instead of silently dropping
    out of the ``benchmarks.compare`` trajectory gate
    (``tests/test_bench_schemas.py`` checks the other direction — that
    every gated key is declared). Smoke-failure payloads (``error=...``)
    skip the check: they are intentionally partial."""
    if declared is not None and "error" not in extra:
        missing = [k for k in declared if k not in extra]
        if missing:
            raise KeyError(
                f"bench artifact {path!r} is missing declared schema keys "
                f"{missing}; update the writer or its BENCH_KEYS"
            )
    payload = {
        "rows": [
            {"name": n, "us_per_call": u, "derived": d} for n, u, d in csv.rows
        ],
        **extra,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


class Csv:
    """Collects ``name,us_per_call,derived`` rows (the run.py contract)."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}")

    def timed(self, name: str, fn, derived_fn):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        self.add(name, us, derived_fn(out))
        return out
