"""Beyond simulation (paper §VII): use the P80 quantile ceiling to find
underperforming fused-MoE configurations and close the gap with the
predictor-driven autotuner (``repro.tune``) — the 1.7x-speedup workflow.

The search space is derived from the kernel's actual ops signature
(``block_m``/``block_f``), every candidate is pre-filtered through the
static SP2xx geometry lint, and the predictor ranks survivors so only the
top-k are measured.

Run: PYTHONPATH=src python examples/optimize_kernel.py
"""

from repro.core.dataset import build_dataset
from repro.core.quantile import perf_gap, train_ceiling
from repro.tune import block_params, geomean_speedup, pearson, tune_underperformers


def main():
    print("building fused-MoE dataset across 11 hardware variants...")
    ds = build_dataset("fused_moe", n_workloads=120, seed=42)

    print("training the P80 ceiling model (pinball loss)...")
    ceiling = train_ceiling(ds, quantile=0.8)
    # this seed's dataset tracks its ceiling closely; 0.05 is the gap
    # threshold that surfaces a meaningful underperformer population
    threshold = 0.05
    report = perf_gap(ceiling, ds, threshold=threshold)

    print(f"\ngap <= {threshold} for "
          f"{(report.gaps <= threshold).mean()*100:.0f}% of points")
    print("underperforming points by hardware (the A40-story analogue):")
    for hw, c in sorted(report.per_hw_counts.items(), key=lambda kv: -kv[1]):
        print(f"  {hw:16s} {c:4d}  ({100*report.per_hw_frac[hw]:.1f}%)")

    knobs = block_params("fused_moe")
    print(f"\nsearch space (from the kernel's ops signature): {sorted(knobs)}")
    print("autotuning up to 20 underperformers per hardware...")
    tuned = tune_underperformers(ds, report.underperforming, per_hw_limit=20)
    counts, gains = [], []
    for hw, results in sorted(tuned.items(), key=lambda kv: -len(kv[1])):
        if not results:
            continue
        g = geomean_speedup(results)
        best = max(r.speedup for r in results)
        counts.append(report.per_hw_counts[hw])
        gains.append(g)
        cfgs = {}
        for r in results:
            key = tuple(sorted(r.best_config.items()))
            cfgs[key] = cfgs.get(key, 0) + 1
        top_cfg = max(cfgs, key=cfgs.get) if cfgs else ()
        print(f"  {hw:16s} geomean {g:.2f}x  best {best:.2f}x  "
              f"most-chosen config {dict(top_cfg)}")
    print(f"\nPearson(underperforming count, geomean speedup) = "
          f"{pearson(counts, gains):.2f}  (paper: 0.86)")
    print("\nto tune the real Pallas kernel with timed execution:")
    print("  PYTHONPATH=src python -m repro.tune --kernel fused_moe --hw tpu-v4")


if __name__ == "__main__":
    main()
