"""Quickstart: the PipeWeave workflow end to end in one minute.

1. decompose a kernel into tasks, schedule it, inspect pipeline demands;
2. train a small estimator and predict latency on unseen hardware;
3. predict an end-to-end serving request through the unified
   ``repro.predict`` API: one batched ``request_estimate`` per backend,
   with per-family breakdown, the analytical ceiling, and an *explicit*
   fallback for kernel families the estimator was not trained on (here we
   only train the gemm family, so everything else is visibly served by the
   oracle — nothing falls back silently);
4. (``--sweep``) price the same request across the whole hardware
   registry in one ``request_sweep`` pass and score it against the oracle
   over the paper's seen/unseen generalization split;
5. (``--route``) close the loop: rank the fleet for the request with
   ``place_request`` under the latency and cost objectives (the
   registry's ``usd_per_chip_hour`` prices) and print who wins each.

Run: PYTHONPATH=src python examples/quickstart.py [--n-workloads 120]
     [--sweep] [--route]
"""
import argparse

import numpy as np

from repro.core import hwsim
from repro.core.dataset import build_dataset, featurize, mape, SEEN, UNSEEN
from repro.core.e2e import place_request, request_calls, request_estimate, request_sweep
from repro.core.estimator import train_pipeweave
from repro.core.hardware import get_hw
from repro.configs import get_arch
from repro.predict import SweepPredictor, get_predictor


def main(n_workloads: int = 120, max_epochs: int = 250, sweep: bool = False,
         route: bool = False):
    hw_seen = get_hw("tpu-v5e")
    hw_unseen = get_hw("tpu-v6e")

    # --- 1. analytical decomposition ------------------------------------
    gemm = {"M": 4096, "N": 8192, "K": 4096}
    fs = featurize("gemm", gemm, hw_seen)
    print("== kernel decomposition (gemm 4096x8192x4096 on tpu-v5e) ==")
    print(f"  tasks={fs.n_tasks}  chips_used={fs.n_chips_used}")
    for p in ("mxu", "hbm"):
        print(f"  {p}: total={fs.totals[p]:.3e}  slice-cycles={fs.total_cycles[p]:.3e}")
    print(f"  theoretical={fs.theoretical_s*1e6:.1f}us  "
          f"hwsim={hwsim.simulate('gemm', gemm, hw_seen)*1e6:.1f}us")

    # --- 2. train a small estimator -------------------------------------
    print("\n== training a small per-kernel MLP (gemm) ==")
    ds = build_dataset("gemm", n_workloads=n_workloads, seed=0)
    pw = train_pipeweave({"gemm": ds}, max_epochs=max_epochs)
    pred = pw.predict_dataset(ds)
    seen = np.array([h in SEEN for h in ds.hw_names])
    print(f"  MAPE seen={mape(pred[seen], ds.actual_s[seen]):.1f}%  "
          f"unseen={mape(pred[~seen], ds.actual_s[~seen]):.1f}%")
    t = pw.predict_latency("gemm", gemm, hw_unseen)
    print(f"  predicted on UNSEEN tpu-v6e: {t*1e6:.1f}us "
          f"(oracle {hwsim.simulate('gemm', gemm, hw_unseen)*1e6:.1f}us)")

    # --- 3. end-to-end request prediction --------------------------------
    print("\n== E2E: qwen3-0.6b, batch 8, 982-token prompts, 64 new tokens ==")
    cfg = get_arch("qwen3-0.6b")
    oracle = get_predictor("oracle", hw_seen)
    actual = request_estimate(cfg, 8, 982, 64, tp=1, predictor=oracle)
    # the estimator only knows gemm here; fallback="oracle" substitutes the
    # hwsim oracle for the untrained families and records it in the
    # Estimate (the default fallback="error" would raise instead). The comm
    # half (a CommRegressor) is auto-fitted lazily on the first CommCall.
    predictor = get_predictor("synperf", hw_seen, estimator=pw, fallback="oracle")
    est = request_estimate(cfg, 8, 982, 64, tp=1, predictor=predictor)
    print(f"  oracle={actual.total_s*1e3:.1f}ms  predicted={est.total_s*1e3:.1f}ms  "
          f"err={abs(est.total_s-actual.total_s)/actual.total_s*100:.1f}%")
    print(f"  analytical ceiling: {est.theoretical_s*1e3:.1f}ms")
    print("  per-family breakdown: "
          + "  ".join(f"{f}={t*1e3:.1f}ms" for f, t in
                      sorted(est.by_family.items(), key=lambda kv: -kv[1])))
    print(f"  families served by fallback: {est.fallbacks or 'none'}")

    # --- 4. multi-hardware sweep (optional) ------------------------------
    if sweep:
        print("\n== sweep: same request across the whole hardware registry ==")
        sp = SweepPredictor(estimator=pw, fallback="oracle")
        res = request_sweep(cfg, 8, 982, 64, tp=1, sweep=sp)
        print(res.table())
        cmp = sp.compare(request_calls(cfg, 8, 982, 64, tp=1))
        print("\n  measured (oracle) vs predicted:")
        print(cmp.table())

    # --- 5. fleet placement (optional) -----------------------------------
    if route:
        print("\n== placement: which hardware should serve this request? ==")
        from repro.serve.placement import FleetRouter

        router = FleetRouter(estimator=pw, fallback="oracle")
        by_lat = place_request(cfg, 8, 982, 64, objective="latency", router=router)
        print(by_lat.table())
        by_cost = place_request(cfg, 8, 982, 64, objective="cost", router=router)
        print(f"  fastest: {by_lat.best}   cheapest: {by_cost.best}  "
              f"(${by_cost.rows[0].cost_usd:.3g} vs "
              f"${by_cost[by_lat.best].cost_usd:.3g} on the fastest)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-workloads", type=int, default=120,
                    help="dataset size for the demo estimator (CI uses a small value)")
    ap.add_argument("--max-epochs", type=int, default=250)
    ap.add_argument("--sweep", action="store_true",
                    help="also price the E2E request on every registry "
                         "hardware (seen/unseen generalization table)")
    ap.add_argument("--route", action="store_true",
                    help="also rank the fleet for the request under the "
                         "latency and cost objectives (place_request)")
    args = ap.parse_args()
    main(n_workloads=args.n_workloads, max_epochs=args.max_epochs,
         sweep=args.sweep, route=args.route)
