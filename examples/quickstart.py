"""Quickstart: the PipeWeave workflow end to end in one minute.

1. decompose a kernel into tasks, schedule it, inspect pipeline demands;
2. train a small estimator and predict latency on unseen hardware;
3. predict an end-to-end serving step for one of the assigned architectures.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import hwsim
from repro.core.dataset import build_dataset, featurize, mape, SEEN, UNSEEN
from repro.core.e2e import CommRegressor, oracle_times, request_latency
from repro.core.estimator import train_pipeweave
from repro.core.hardware import get_hw
from repro.configs import get_arch


def main():
    hw_seen = get_hw("tpu-v5e")
    hw_unseen = get_hw("tpu-v6e")

    # --- 1. analytical decomposition ------------------------------------
    gemm = {"M": 4096, "N": 8192, "K": 4096}
    fs = featurize("gemm", gemm, hw_seen)
    print("== kernel decomposition (gemm 4096x8192x4096 on tpu-v5e) ==")
    print(f"  tasks={fs.n_tasks}  chips_used={fs.n_chips_used}")
    for p in ("mxu", "hbm"):
        print(f"  {p}: total={fs.totals[p]:.3e}  slice-cycles={fs.total_cycles[p]:.3e}")
    print(f"  theoretical={fs.theoretical_s*1e6:.1f}us  "
          f"hwsim={hwsim.simulate('gemm', gemm, hw_seen)*1e6:.1f}us")

    # --- 2. train a small estimator -------------------------------------
    print("\n== training a small per-kernel MLP (gemm) ==")
    ds = build_dataset("gemm", n_workloads=120, seed=0)
    pw = train_pipeweave({"gemm": ds})
    pred = pw.predict_dataset(ds)
    seen = np.array([h in SEEN for h in ds.hw_names])
    print(f"  MAPE seen={mape(pred[seen], ds.actual_s[seen]):.1f}%  "
          f"unseen={mape(pred[~seen], ds.actual_s[~seen]):.1f}%")
    t = pw.predict_latency("gemm", gemm, hw_unseen)
    print(f"  predicted on UNSEEN tpu-v6e: {t*1e6:.1f}us "
          f"(oracle {hwsim.simulate('gemm', gemm, hw_unseen)*1e6:.1f}us)")

    # --- 3. end-to-end request prediction --------------------------------
    print("\n== E2E: qwen3-0.6b, batch 8, 982-token prompts, 64 new tokens ==")
    cfg = get_arch("qwen3-0.6b")
    comm = CommRegressor().fit(hw_seen)
    kt, ct = oracle_times(hw_seen)
    actual = request_latency(cfg, 8, 982, 64, tp=1, kernel_time=kt, comm_time=ct)
    predicted = request_latency(
        cfg, 8, 982, 64, tp=1,
        kernel_time=lambda k, X: pw.predict_latency(k, X, hw_seen)
        if k in pw.models else hwsim.simulate(k, X, hw_seen),
        comm_time=comm.predict,
    )
    print(f"  oracle={actual*1e3:.1f}ms  predicted={predicted*1e3:.1f}ms  "
          f"err={abs(predicted-actual)/actual*100:.1f}%")


if __name__ == "__main__":
    main()
