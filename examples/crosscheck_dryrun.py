"""Cross-check: PipeWeave's analytical E2E machinery vs the REAL compiled
XLA dry-run artifacts.

For each (arch, shape) cell with a dry-run JSON, compare the workload
generator's per-device FLOP estimate against the loop-aware walk of the
compiled SPMD module, and print the roofline bound next to the hwsim-oracle
step-time estimate. This ties the paper's predictor to the framework's real
compiled artifacts (the validation the paper does with NCU, done here with
XLA).

Run: PYTHONPATH=src python examples/crosscheck_dryrun.py [--dir results/dryrun]
"""
import argparse

from repro.configs import SHAPES, get_arch
from repro.core.e2e import KernelCall, model_calls
from repro.roofline.analysis import load_rows


def analytic_flops_per_device(arch, shape_name, n_devices):
    """Forward FLOPs from the workload generator (kernel-call sum)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    qlen = 1 if shape.kind == "decode" else S
    kvlen = S
    total = 0.0
    for _, reps, seq in model_calls(cfg, B, qlen, kvlen, tp=1):
        for c in seq:
            if not isinstance(c, KernelCall):
                continue
            X = c.X
            if c.kind in ("gemm", "scaled_mm"):
                f = 2.0 * X["M"] * X["N"] * X["K"]
            elif c.kind == "attention":
                f = 4.0 * X["bs"] * X["nkv"] * X["group"] * X["qlen"] * X["kvlen"] * X["hd"]
                if X.get("causal") and X["qlen"] > 1:
                    f *= 0.5
            elif c.kind == "fused_moe":
                f = 2.0 * X["M"] * X["topk"] * 3 * X["H"] * X["N"]
            else:
                f = 0.0
            total += reps * c.count * f
    mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[shape.kind]
    return total * mult / n_devices


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = {(r.arch, r.shape): r for r in load_rows(args.dir) if r.mesh == "16x16"}
    if not rows:
        print("no dry-run artifacts; run repro.launch.dryrun --all first")
        return
    print(f"{'cell':38s} {'HLO TF/dev':>11s} {'analytic':>9s} {'ratio':>6s} "
          f"{'bound(s)':>9s} {'dominant':>10s}")
    for (arch, shape), r in sorted(rows.items()):
        try:
            est = analytic_flops_per_device(arch, shape, r.n_devices)
        except Exception:  # noqa: BLE001
            continue
        ratio = r.hlo_flops_dev / max(est, 1.0)
        print(f"{arch+'/'+shape:38s} {r.hlo_flops_dev/1e12:11.2f} "
              f"{est/1e12:9.2f} {ratio:6.2f} {r.bound_s:9.2f} {r.dominant:>10s}")
    print("\nratio ~1-2 = compiled compute within causal/remat overhead of the "
          "analytical model;\nhigher ratios flag dispatch/recompute waste "
          "(see EXPERIMENTS.md §Roofline).")


if __name__ == "__main__":
    main()
