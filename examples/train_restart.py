"""Fault-tolerance drill: train, simulate a preemption, restart from the
latest atomic checkpoint and verify the loss trajectory continues exactly
where it left off.

Run: PYTHONPATH=src python examples/train_restart.py
"""
import tempfile

import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import DataConfig
from repro.train.step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_restart_")
    cfg = get_arch("qwen3-0.6b").smoke()

    def make(total):
        return Trainer(
            cfg,
            DataConfig(batch=4, seq_len=32, seed=0),
            TrainConfig(lr=1e-3, warmup=2, total_steps=total),
            TrainerConfig(total_steps=total, ckpt_every=5, ckpt_dir=ckpt_dir, log_every=5),
        )

    print("run A: training 20 steps, preempted after 10 ...")
    a = make(20)
    step, _, losses_a = a.run(seed=0, preempt_after=10)
    print(f"  preempted at step {step}, checkpoint saved")

    print("run B: restarting from the checkpoint ...")
    b = make(20)
    step, _, losses_b = b.run(seed=0)
    print(f"  finished at step {step}")

    print("reference: uninterrupted 20-step run ...")
    import tempfile as tf

    c = Trainer(
        cfg,
        DataConfig(batch=4, seq_len=32, seed=0),
        TrainConfig(lr=1e-3, warmup=2, total_steps=20),
        TrainerConfig(total_steps=20, ckpt_every=50, ckpt_dir=tf.mkdtemp(), log_every=5),
    )
    _, _, losses_full = c.run(seed=0)

    resumed = losses_a + losses_b
    drift = np.max(np.abs(np.array(resumed) - np.array(losses_full)))
    print(f"max |loss drift| between preempted+resumed and uninterrupted: {drift:.2e}")
    assert drift < 1e-4
    print("bitwise-continuation check PASSED")


if __name__ == "__main__":
    main()
