"""End-to-end driver (the paper's kind is inference): serve a small model
with batched requests through the real JAX serving stack — prefill, KV cache,
lock-step batched decode, sampling — and compare the measured phase split
with the PipeWeave E2E prediction for the same workload.

Run: PYTHONPATH=src python examples/serve_batch.py [--arch gemma2-2b]
"""
import argparse
import time

import numpy as np

from repro.configs import get_arch
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    engine = ServeEngine(cfg, max_batch=4)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        L = int(rng.integers(16, 48))
        engine.submit(
            Request(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=L).astype(np.int32),
                max_new=args.max_new,
                temperature=0.7 if i % 2 else 0.0,
            )
        )
    t0 = time.perf_counter()
    results = []
    while engine.queue:
        results += engine.step_batch()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"arch={args.arch}(smoke) served {len(results)} reqs, {toks} tokens "
          f"in {wall:.2f}s -> {toks/wall:.1f} tok/s")
    pre = np.mean([r.prefill_s for r in results])
    dec = np.mean([r.decode_s for r in results])
    print(f"mean prefill {pre*1e3:.1f}ms | mean decode loop {dec*1e3:.1f}ms "
          f"({dec/args.max_new*1e3:.1f}ms/token)")
    sample = results[0]
    print(f"sample output (req 0): {sample.tokens}")


if __name__ == "__main__":
    main()
